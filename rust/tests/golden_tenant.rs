//! Golden pins for the multi-tenant cluster layer (PRs 5-7 discipline:
//! every number below was derived in this PR's executable Python mirror
//! of the event loop and the write-cost model, then frozen here).
//!
//! 1. the ReRAM row-write constants and the derived whole-model
//!    reprogram costs (rows / latency cycles / energy) for VGG-A (both
//!    plans), VGG-E Fig. 7 and ResNet-18 — the price of a model swap;
//! 2. a fully hand-checkable alternating two-tenant trace on one
//!    reprogram node, where every request's latency decomposes exactly
//!    into queueing + swap + backlog + fill and the swap/energy ledgers
//!    are pinned;
//! 3. the same trace on two nodes, where jsq residency affinity makes
//!    *both* policies swap-free with identical latency;
//! 4. the saturated-fleet energy point: completion/swap counts and the
//!    exactly-representable weight-write energy, plus the JSON surface.

use smart_pim::cluster::{
    simulate_tenants, ArrivalProcess, EnergyProfile, MixMode, Residency, TenantConfig,
    TenantRoute, TenantWorkload,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
use smart_pim::power::{WriteCost, ROW_WRITE_ENERGY_J, ROW_WRITE_LATENCY_S};

#[test]
fn row_write_constants_are_pinned() {
    // The trip evaluation model's program-and-verify row costs; every
    // derived anchor below scales from these two numbers.
    assert_eq!(ROW_WRITE_LATENCY_S, 1.76e-4);
    assert_eq!(ROW_WRITE_ENERGY_J, 6.76e-7);
}

fn cost_of(net_name: &str, fig7: bool) -> WriteCost {
    let arch = ArchConfig::paper_node();
    let net = smart_pim::cnn::workload(net_name).unwrap();
    let plan = if fig7 {
        ReplicationPlan::fig7(net_name.parse::<VggVariant>().unwrap())
    } else {
        ReplicationPlan::none(&net)
    };
    let mapping = NetworkMapping::build(&net, &arch, &plan).unwrap();
    WriteCost::of_mapping(&net, &mapping, &arch)
}

#[test]
fn model_reprogram_costs_are_pinned() {
    // rows = Σ resident subarrays x 128; latency = busiest core's rows
    // (serial program-and-verify per core, cores parallel) at 1.76e-4 s
    // per row over the 306 ns logical cycle; energy = rows x 6.76e-7 J.
    // All four models share the latency bottleneck: the fc1 reload
    // round's rows on its tile allocation.
    for (name, fig7, rows, latency_cycles, energy_j) in [
        ("vggA", false, 1_543_168u64, 588_968u64, 1.0431815679999998f64),
        ("vggA", true, 1_973_760, 588_968, 1.33426176),
        ("vggE", true, 3_268_096, 588_968, 2.209232896),
        ("resnet18", false, 704_512, 588_968, 0.476250112),
    ] {
        let w = cost_of(name, fig7);
        let plan = if fig7 { "fig7" } else { "none" };
        assert_eq!(w.rows, rows, "{name} {plan} rows");
        assert_eq!(w.latency_cycles, latency_cycles, "{name} {plan} latency");
        assert_eq!(w.energy_j, energy_j, "{name} {plan} energy");
        // ~0.18 wall seconds per swap at the paper node's cycle.
        let s = w.latency_s(306.0);
        assert!((s - 0.180224208).abs() < 1e-9, "{name} {plan}: {s} s");
    }
}

/// The hand-checkable pair: tenant a {interval 100, fill 500, swap 1000
/// cycles / 0.5 J}, tenant b {interval 300, fill 700, swap 2000 cycles /
/// 0.25 J}.
fn ab() -> Vec<TenantWorkload> {
    let wc = |latency_cycles, energy_j| WriteCost {
        rows: 0,
        latency_cycles,
        energy_j,
    };
    vec![
        TenantWorkload::new("a", 1.0, 100, 500, wc(1_000, 0.5)),
        TenantWorkload::new("b", 1.0, 300, 700, wc(2_000, 0.25)),
    ]
}

fn trace_cfg(nodes: usize, residency: Residency) -> TenantConfig {
    TenantConfig {
        nodes,
        residency,
        route: TenantRoute::ShortestQueue,
        pattern: ArrivalProcess::Trace(vec![0, 50, 100, 150, 200, 250]),
        mix: MixMode::Alternate,
        max_queue: 1_000,
        seed: 0,
        ..TenantConfig::default()
    }
}

#[test]
fn alternating_trace_on_one_reprogram_node() {
    // Arrivals alternate a,b,a,b,a,b at cycles 0..250. Node 0 starts
    // resident for a, so request 1 hits (latency = fill = 500) and every
    // later request misses: it waits for the pipeline to drain
    // (queueing), pays its tenant's full write latency (swap), then
    // fills. Hand-derived per-request (tenant, total, queueing, swap,
    // backlog):
    //   (a,   500,    0,    0, 0)   (b,  3150,  450, 2000, 0)
    //   (a,  4600, 3100, 1000, 0)   (b,  7250, 4550, 2000, 0)
    //   (a,  8700, 7200, 1000, 0)   (b, 11350, 8650, 2000, 0)
    let s = simulate_tenants(&ab(), &trace_cfg(1, Residency::Reprogram)).unwrap();
    assert_eq!(s.offered, 6);
    assert_eq!(s.completed, 6);
    assert_eq!(s.rejected, 0);
    assert_eq!(s.events_processed, 12);
    assert_eq!(s.peak_calendar_depth, 6);
    assert_eq!(s.drained_at, 11_600);
    // Effective horizon: last trace arrival + 1, clipped below the
    // default window.
    assert_eq!(s.horizon_cycles, 251);
    assert!(s.partition.is_none());

    let a = &s.tenants[0];
    assert_eq!((a.offered, a.completed, a.rejected), (3, 3, 0));
    assert_eq!((a.swaps, a.misses), (2, 2));
    assert_eq!(a.swap_energy_j, 1.0);
    assert_eq!(a.latency.p50(), 4_600);
    assert_eq!(a.latency.p99(), 8_700);
    assert_eq!(a.latency.max(), 8_700);
    assert_eq!(a.total_latency_cycles, 500 + 4_600 + 8_700);
    assert_eq!(a.queueing_cycles, 10_300);
    assert_eq!(a.swap_cycles, 2_000);
    assert_eq!(a.backlog_cycles, 0);
    assert_eq!(a.fill, 500);

    let b = &s.tenants[1];
    assert_eq!((b.offered, b.completed, b.rejected), (3, 3, 0));
    assert_eq!((b.swaps, b.misses), (3, 3));
    assert_eq!(b.swap_energy_j, 0.75);
    assert_eq!(b.latency.p50(), 7_250);
    assert_eq!(b.latency.max(), 11_350);
    assert_eq!(b.total_latency_cycles, 3_150 + 7_250 + 11_350);
    assert_eq!(b.queueing_cycles, 13_650);
    assert_eq!(b.swap_cycles, 6_000);
    assert_eq!(b.backlog_cycles, 0);

    // The decomposition closes exactly for both tenants.
    for t in &s.tenants {
        assert_eq!(
            t.total_latency_cycles,
            t.queueing_cycles + t.swap_cycles + t.backlog_cycles + t.completed * t.fill
        );
    }
    assert_eq!(s.total_swaps(), 5);
    assert_eq!(s.total_swap_energy_j(), 1.75);
    assert_eq!(s.per_node_swaps, vec![5]);
    assert_eq!(s.per_node_injected, vec![6]);
}

#[test]
fn two_nodes_make_the_trace_swap_free_under_both_policies() {
    // With a node per tenant, jsq residency affinity sends every request
    // to its home node under reprogram, and the partition pins it there:
    // identical latencies, zero swaps, for both policies. Tenant a's
    // 100-cycle interval absorbs the 50-cycle arrival gaps (three flat
    // 500s); tenant b's 300-cycle interval backlogs (700, 900, 1100).
    for residency in [Residency::Partition, Residency::Reprogram] {
        let s = simulate_tenants(&ab(), &trace_cfg(2, residency)).unwrap();
        let name = residency.name();
        assert_eq!(s.completed, 6, "{name}");
        assert_eq!(s.rejected, 0, "{name}");
        assert_eq!(s.total_swaps(), 0, "{name}");
        assert_eq!(s.total_swap_energy_j(), 0.0, "{name}");
        assert_eq!(s.drained_at, 1_350, "{name}");
        assert_eq!(s.events_processed, 12, "{name}");
        assert_eq!(s.peak_calendar_depth, 6, "{name}");
        let a = &s.tenants[0];
        assert_eq!(a.total_latency_cycles, 1_500, "{name}");
        assert_eq!((a.latency.p50(), a.latency.max()), (500, 500), "{name}");
        assert_eq!(a.backlog_cycles, 0, "{name}");
        let b = &s.tenants[1];
        assert_eq!(b.total_latency_cycles, 700 + 900 + 1_100, "{name}");
        assert_eq!((b.latency.p50(), b.latency.max()), (900, 1_100), "{name}");
        assert_eq!(b.backlog_cycles, 200 + 400, "{name}");
        match residency {
            Residency::Partition => assert_eq!(s.partition, Some(vec![1, 1]), "{name}"),
            Residency::Reprogram => assert!(s.partition.is_none(), "{name}"),
        }
    }
}

#[test]
fn saturated_fleet_energy_point_is_pinned() {
    // The 2-node point of the monotonicity ladder (mirror-derived):
    // alternate mix, reprogram, rate 0.05/cycle, 8000 fixed arrivals,
    // admission bound 32. Counts are exact; the weight-write energy is a
    // dyadic sum (swaps x 0.5 J + swaps x 0.25 J), so it is pinned
    // bit-exactly too. The float identity total = dynamic + idle +
    // writes is exact by construction.
    let priced = |name: &str, interval, fill, write, image_mj, ops| {
        let mut t = TenantWorkload::new(name, 1.0, interval, fill, write);
        t.energy = Some(EnergyProfile {
            image_mj,
            active_power_w: 0.0,
            idle_power_w: 2.0,
            ops_per_image: ops,
            logical_cycle_ns: 306.0,
        });
        t
    };
    let wc = |latency_cycles, energy_j| WriteCost {
        rows: 0,
        latency_cycles,
        energy_j,
    };
    let tenants = vec![
        priced("a", 100, 500, wc(50_000, 0.5), 10.0, 1_000),
        priced("b", 300, 700, wc(80_000, 0.25), 20.0, 2_000),
    ];
    let s = simulate_tenants(
        &tenants,
        &TenantConfig {
            nodes: 2,
            residency: Residency::Reprogram,
            route: TenantRoute::ShortestQueue,
            rate_per_cycle: 0.05,
            mix: MixMode::Alternate,
            max_queue: 32,
            fixed_requests: Some(8_000),
            seed: 42,
            ..TenantConfig::default()
        },
    )
    .unwrap();
    assert_eq!(s.offered, 8_000);
    assert_eq!(s.completed, 153);
    assert_eq!(s.total_swaps(), 38);
    let e = s.energy.as_ref().unwrap();
    assert_eq!(e.weight_writes_j, 14.25);
    assert_eq!(e.total_j(), e.dynamic_j + e.idle_j + e.weight_writes_j);
    assert!((e.joules_per_image() - 0.11910529950326797).abs() < 1e-12);

    // The JSON surface carries the tenant grid and the new energy term.
    let doc = s.to_json(306.0).render();
    assert!(doc.contains("\"energy_weight_writes_j\":14.25"), "{doc}");
    assert!(doc.contains("\"swaps\":38"), "{doc}");
    assert!(doc.contains("\"tenant\":\"a\""), "{doc}");
    assert!(doc.contains("\"tenant\":\"b\""), "{doc}");
    assert!(doc.contains("\"residency\":\"reprogram\""), "{doc}");
}

#[test]
fn real_model_swap_prices_flow_into_the_run() {
    // End to end with real workloads: VGG-A (Fig. 7) + ResNet-18 on one
    // reprogram node, alternating trace. Each miss charges the *mapped*
    // model's pinned write cost, so fleet swap energy is an exact
    // multiple of the per-model anchors.
    let arch = ArchConfig::paper_node();
    let build = |name: &str, fig7: bool| {
        let net = smart_pim::cnn::workload(name).unwrap();
        let plan = if fig7 {
            ReplicationPlan::fig7(net.name.parse::<VggVariant>().unwrap())
        } else {
            ReplicationPlan::none(&net)
        };
        let model =
            smart_pim::cluster::NodeModel::from_workload(&net, &arch, &plan).unwrap();
        let mapping = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let write = WriteCost::of_mapping(&net, &mapping, &arch);
        TenantWorkload::from_model(&net.name, 1.0, &model, write)
    };
    let tenants = vec![build("vggA", true), build("resnet18", false)];
    assert_eq!(tenants[0].write.energy_j, 1.33426176);
    assert_eq!(tenants[1].write.energy_j, 0.476250112);
    assert_eq!(vgg::build(VggVariant::A).name, "vggA");

    let s = simulate_tenants(&tenants, &trace_cfg(1, Residency::Reprogram)).unwrap();
    assert_eq!(s.completed, 6);
    // a,b,a,b,a,b on an a-resident node: a misses 2, resnet misses 3.
    assert_eq!(s.tenants[0].swaps, 2);
    assert_eq!(s.tenants[1].swaps, 3);
    assert_eq!(s.tenants[0].swap_energy_j, 2.0 * 1.33426176);
    assert_eq!(s.tenants[1].swap_energy_j, 3.0 * 0.476250112);
    // Each swap stalls the node for the pinned 588,968-cycle reprogram.
    assert_eq!(s.tenants[0].swap_cycles, 2 * 588_968);
    assert_eq!(s.tenants[1].swap_cycles, 3 * 588_968);
}
