//! Integration: network → mapping → stage plans → cycle engine → metrics,
//! across the whole benchmark grid machinery (no artifacts needed).

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::mapping::{NetworkMapping, Placement, ReplicationPlan};
use smart_pim::metrics::Grid;
use smart_pim::pipeline::{build_plans, max_occupancy};
use smart_pim::sim::engine::{Engine, NocAdjust};
use smart_pim::sim::evaluate;

#[test]
fn all_variants_all_scenarios_ideal_noc() {
    // The 20 processing-side benchmarks (ideal NoC): ordering invariants
    // (4) >= (3) and (2) >= (1) must hold for every VGG.
    let arch = ArchConfig::paper_node();
    let grid = Grid::run(&arch, &VggVariant::ALL, &Scenario::ALL, &[NocKind::Ideal]);
    for v in VggVariant::ALL {
        let f = |s| grid.get(v, s, NocKind::Ideal).fps;
        let (f1, f2, f3, f4) = (
            f(Scenario::Baseline),
            f(Scenario::BatchOnly),
            f(Scenario::ReplicationOnly),
            f(Scenario::ReplicationBatch),
        );
        assert!(f2 >= f1 * 0.999, "{}: batch slower than baseline", v.name());
        assert!(f3 >= f1 * 4.0, "{}: replication gave < 4x", v.name());
        assert!(f4 >= f3 * 0.999, "{}: (4) < (3)", v.name());
        assert!(f4 >= f2 * 4.0, "{}: (4) < 4x (2)", v.name());
    }
}

#[test]
fn fig5_geomeans_in_paper_band() {
    // Paper: 1.0309 / 10.1788 / 13.6903. Accept the same order:
    // batch-only within [1.0, 1.15], repl-only in [8, 16], both in [11, 20].
    let arch = ArchConfig::paper_node();
    let grid = Grid::run(&arch, &VggVariant::ALL, &Scenario::ALL, &[NocKind::Smart]);
    let (_, geo) = grid.fig5_table(NocKind::Smart, &VggVariant::ALL);
    assert!((1.0..1.15).contains(&geo[0]), "batch geomean {}", geo[0]);
    assert!((8.0..16.0).contains(&geo[1]), "repl geomean {}", geo[1]);
    assert!((11.0..20.0).contains(&geo[2]), "both geomean {}", geo[2]);
    assert!(geo[2] > geo[1], "(4) must beat (3)");
}

#[test]
fn vgg_e_ideal_hits_calibration_anchor() {
    // DESIGN.md §5: the single calibrated constant must put ideal VGG-E
    // scenario (4) at the paper's 1042 FPS / 40.9 TOPS.
    let arch = ArchConfig::paper_node();
    let r = evaluate(
        VggVariant::E,
        Scenario::ReplicationBatch,
        NocKind::Ideal,
        &arch,
    );
    assert!((r.fps - 1042.0).abs() < 40.0, "fps {}", r.fps);
    assert!((r.tops - 40.91).abs() < 1.6, "tops {}", r.tops);
}

#[test]
fn batch_interval_equals_busiest_stage_for_all_vggs() {
    let arch = ArchConfig::paper_node();
    for v in VggVariant::ALL {
        let net = vgg::build(v);
        let plan = ReplicationPlan::fig7(v);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let sim = Engine::new(&plans, &adj, true, 8).run();
        let want = max_occupancy(&plans) as f64;
        let got = sim.steady_interval().expect("8 images give an interval");
        assert!(
            (got - want).abs() <= want * 0.05 + 32.0,
            "{}: interval {got} vs occupancy {want}",
            v.name()
        );
    }
}

#[test]
fn latency_invariant_under_batching() {
    // Batch pipelining must not change the first image's latency.
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::B);
    let plan = ReplicationPlan::fig7(VggVariant::B);
    let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
    let plans = build_plans(&net, &m, &arch);
    let adj = NocAdjust::identity(plans.len());
    let serial = Engine::new(&plans, &adj, false, 3).run();
    let batched = Engine::new(&plans, &adj, true, 3).run();
    assert_eq!(serial.latencies()[0], batched.latencies()[0]);
}

#[test]
fn noc_ordering_wormhole_smart_ideal() {
    let arch = ArchConfig::paper_node();
    let grid = Grid::run(
        &arch,
        &[VggVariant::D],
        &[Scenario::ReplicationBatch],
        &NocKind::ALL,
    );
    let w = grid
        .get(VggVariant::D, Scenario::ReplicationBatch, NocKind::Wormhole)
        .fps;
    let s = grid
        .get(VggVariant::D, Scenario::ReplicationBatch, NocKind::Smart)
        .fps;
    let i = grid
        .get(VggVariant::D, Scenario::ReplicationBatch, NocKind::Ideal)
        .fps;
    assert!(w <= s * 1.01, "wormhole {w} > smart {s}");
    assert!(s <= i * 1.01, "smart {s} > ideal {i}");
    // The gap is single-digit percent, not an order of magnitude.
    assert!(i / w < 1.5, "ideal/wormhole {} implausibly large", i / w);
}

#[test]
fn placement_variants_affect_traffic_not_compute() {
    // Row-major placement (longer hops) must not change the ideal-NoC
    // result at all.
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::A);
    let plan = ReplicationPlan::fig7(VggVariant::A);
    let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
    let snake = Placement::snake(&arch);
    let row = Placement::row_major(&arch);
    let plans = build_plans(&net, &m, &arch);
    // Hop counts differ ...
    let lf_s = smart_pim::sim::extract_flows(&net, &m, &snake, &plans, &arch);
    let lf_r = smart_pim::sim::extract_flows(&net, &m, &row, &plans, &arch);
    let hops = |lf: &[smart_pim::sim::LayerFlows]| -> Vec<f64> {
        lf.iter().map(|l| l.mean_hops).collect()
    };
    // Placement changes the traffic geometry ...
    assert_ne!(hops(&lf_s), hops(&lf_r), "placements produced identical hops");
    // ... but the engine result with identity adjust is identical.
    let adj = NocAdjust::identity(plans.len());
    let a = Engine::new(&plans, &adj, true, 4).run();
    let b = Engine::new(&plans, &adj, true, 4).run();
    assert_eq!(a.completions, b.completions);
}

#[test]
fn energy_breakdown_scales_with_ops() {
    let arch = ArchConfig::paper_node();
    let ra = evaluate(VggVariant::A, Scenario::Baseline, NocKind::Ideal, &arch);
    let re = evaluate(VggVariant::E, Scenario::Baseline, NocKind::Ideal, &arch);
    // VGG-E does ~2.6x the MACs of VGG-A; energy should scale roughly.
    let ratio = re.energy.total_mj() / ra.energy.total_mj();
    assert!((1.5..4.0).contains(&ratio), "energy ratio {ratio}");
}
