//! Golden + property tests for the layer-DAG core and the ResNet
//! workloads.
//!
//! Golden: ResNet-18/34 topology, MAC/parameter counts, the unreplicated
//! critical-path interval/fill skeleton, and the searched plan's budget
//! feasibility at the paper's 320-tile node. All constants were derived in
//! an executable arithmetic mirror before these tests were written.
//!
//! Property: a linear DAG built through `Network::from_graph` reproduces
//! the seed VGG chain numbers **bit-identically** — stage plans, occupancy,
//! pipeline shape, and the cycle-accurate engine schedule — so the DAG
//! generalization provably did not move any pre-refactor golden.

use smart_pim::cnn::{resnet, vgg, Network, ResNetVariant, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::PipelineShape;
use smart_pim::mapping::{validate_plan, NetworkMapping, ReplicationPlan};
use smart_pim::pipeline::{build_plans, max_occupancy};
use smart_pim::planner::{evaluate_candidates, plan_for};
use smart_pim::sim::{Engine, NocAdjust};
use smart_pim::sweep::SweepRunner;

const PAPER_BUDGET: usize = 320;

fn arch() -> ArchConfig {
    ArchConfig::paper_node()
}

#[test]
fn golden_resnet_topology_and_op_counts() {
    // Mirror-derived exact constants (conv+fc weights, no BN/bias).
    let r18 = resnet::build(ResNetVariant::R18);
    assert_eq!((r18.len(), r18.n_edges()), (30, 37));
    assert_eq!(r18.macs(), 1_814_073_344);
    assert_eq!(r18.weights(), 11_678_912);

    let r34 = resnet::build(ResNetVariant::R34);
    assert_eq!((r34.len(), r34.n_edges()), (54, 69));
    assert_eq!(r34.macs(), 3_663_761_408);
    assert_eq!(r34.weights(), 21_779_648);
}

#[test]
fn golden_resnet18_critical_path_fill_and_interval() {
    let a = arch();
    let net = resnet::build(ResNetVariant::R18);
    let plan = ReplicationPlan::none(&net);
    let m = NetworkMapping::build(&net, &a, &plan).unwrap();
    assert_eq!(m.total_tiles, 75, "unreplicated footprint");
    let plans = build_plans(&net, &m, &a);
    // The stem streams 112*112 pre-pool positions — the busiest stage.
    assert_eq!(max_occupancy(&plans), 12544);
    // Critical-path fill skeleton (longest path through the DAG).
    let shape = PipelineShape::from_plans(&plans);
    let last = shape.n_layers() - 1;
    assert_eq!(shape.offsets[last] + shape.occupancy[last], 1956);
    // Spot-check offsets along the path (mirror-derived).
    let name = |i: usize| plans[i].name.as_str();
    assert_eq!((name(0), shape.offsets[0]), ("conv1", 0));
    assert_eq!((name(3), shape.offsets[3]), ("s1b1.add", 765));
    assert_eq!((name(12), shape.offsets[12]), ("s2b2.conv_b", 1415));
    assert_eq!((name(last), shape.offsets[last]), ("fc", 1948));
}

#[test]
fn golden_resnet34_critical_path() {
    let a = arch();
    let net = resnet::build(ResNetVariant::R34);
    let plan = ReplicationPlan::none(&net);
    let m = NetworkMapping::build(&net, &a, &plan).unwrap();
    assert_eq!(m.total_tiles, 137);
    let plans = build_plans(&net, &m, &a);
    assert_eq!(max_occupancy(&plans), 12544);
    let shape = PipelineShape::from_plans(&plans);
    let last = shape.n_layers() - 1;
    assert_eq!(shape.offsets[last] + shape.occupancy[last], 3132);
}

#[test]
fn golden_searched_resnet18_plan_is_budget_feasible() {
    // The acceptance bar for `smart-pim plan --network resnet18`: a
    // searched plan that fits the paper's node and lifts the stem
    // bottleneck by well over an order of magnitude (the arithmetic mirror's
    // plain greedy already reaches interval 49 in 313 tiles).
    let a = arch();
    let net = resnet::build(ResNetVariant::R18);
    let result = plan_for(&net, &a, PAPER_BUDGET).unwrap();
    let best = &result.best.assessment;
    assert!(best.tiles <= PAPER_BUDGET, "{} tiles over budget", best.tiles);
    assert!(
        best.interval <= 196,
        "searched interval {} did not lift the 12544 stem bottleneck",
        best.interval
    );
    assert!(result.best.plan.factors.iter().all(|&f| f.is_power_of_two()));
    validate_plan(&net, &a, &result.best.plan).unwrap();

    // The cycle-accurate engine must confirm the modeled interval.
    let mut cands = vec![result.best];
    evaluate_candidates(&net, &a, &SweepRunner::new(), &mut cands, 10);
    let measured = cands[0].measured_interval.expect("engine ran");
    let modeled = cands[0].assessment.interval as f64;
    assert!(
        (measured - modeled).abs() <= modeled * 0.10 + 64.0,
        "engine {measured} far from model {modeled}"
    );
}

#[test]
fn golden_resnet34_searched_plan_fits_too() {
    let a = arch();
    let net = resnet::build(ResNetVariant::R34);
    let result = plan_for(&net, &a, PAPER_BUDGET).unwrap();
    assert!(result.best.assessment.tiles <= PAPER_BUDGET);
    assert!(
        result.best.assessment.interval <= 392,
        "interval {}",
        result.best.assessment.interval
    );
}

#[test]
fn engine_runs_resnet18_and_converges_to_bottleneck() {
    let a = arch();
    let net = resnet::build(ResNetVariant::R18);
    let plan = ReplicationPlan::none(&net);
    let m = NetworkMapping::build(&net, &a, &plan).unwrap();
    let plans = build_plans(&net, &m, &a);
    let adj = NocAdjust::identity(plans.len());
    let sim = Engine::new(&plans, &adj, true, 8).run();
    for w in sim.completions.windows(2) {
        assert!(w[0] < w[1], "completions not monotone");
    }
    let interval = sim.steady_interval().expect("8 images");
    assert!(
        (interval - 12544.0).abs() <= 64.0,
        "interval {interval} != ~12544"
    );
}

/// Rebuild a linear network through the explicit-graph constructor.
fn as_graph(net: &Network) -> Network {
    let edges: Vec<(usize, usize)> = (1..net.len()).map(|i| (i - 1, i)).collect();
    Network::from_graph(net.name.clone(), net.layers().to_vec(), edges).unwrap()
}

#[test]
fn prop_linear_dag_reproduces_vgg_chain_bit_identically() {
    // For every VGG variant and both canonical plans, the from_graph
    // construction must yield identical stage plans, pipeline shape, and
    // engine schedule — the seed chain numbers are untouched by the DAG
    // refactor.
    let a = arch();
    for v in VggVariant::ALL {
        let chain = vgg::build(v);
        let dag = as_graph(&chain);
        assert!(chain.is_linear() && dag.is_linear());
        assert_eq!(chain.macs(), dag.macs());
        assert_eq!(chain.weights(), dag.weights());
        for plan in [ReplicationPlan::none(&chain), ReplicationPlan::fig7(v)] {
            let mc = NetworkMapping::build(&chain, &a, &plan).unwrap();
            let md = NetworkMapping::build(&dag, &a, &plan).unwrap();
            assert_eq!(mc.total_tiles, md.total_tiles, "{}", v.name());
            let pc = build_plans(&chain, &mc, &a);
            let pd = build_plans(&dag, &md, &a);
            assert_eq!(pc.len(), pd.len());
            for (x, y) in pc.iter().zip(&pd) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.p_total, y.p_total, "{}", x.name);
                assert_eq!(x.rate, y.rate, "{}", x.name);
                assert_eq!(x.depth, y.depth, "{}", x.name);
                assert_eq!(x.preds, y.preds, "{}", x.name);
                assert_eq!(x.demands, y.demands, "{}", x.name);
            }
            assert_eq!(max_occupancy(&pc), max_occupancy(&pd));
            let sc = PipelineShape::from_plans(&pc);
            let sd = PipelineShape::from_plans(&pd);
            assert_eq!(sc.offsets, sd.offsets, "{}", v.name());
            assert_eq!(sc.occupancy, sd.occupancy, "{}", v.name());
            // Cycle-accurate schedules are identical, image for image.
            let adj = NocAdjust::identity(pc.len());
            let rc = Engine::new(&pc, &adj, true, 4).run();
            let rd = Engine::new(&pd, &adj, true, 4).run();
            assert_eq!(rc.completions, rd.completions, "{}", v.name());
            assert_eq!(rc.injections, rd.injections, "{}", v.name());
            assert_eq!(rc.cycles, rd.cycles, "{}", v.name());
        }
    }
}

#[test]
fn prop_vgg_e_fig7_fill_matches_mirror() {
    // The chain fill constant (mirror-derived 1331) pins the critical-path
    // arithmetic on the degenerate DAG: offsets accumulate exactly as the
    // seed's cumulative-sum recurrence did.
    let a = arch();
    let net = vgg::build(VggVariant::E);
    let m = NetworkMapping::build(&net, &a, &ReplicationPlan::fig7(VggVariant::E)).unwrap();
    let plans = build_plans(&net, &m, &a);
    let shape = PipelineShape::from_plans(&plans);
    let last = shape.n_layers() - 1;
    assert_eq!(shape.offsets[last] + shape.occupancy[last], 1331);
}
