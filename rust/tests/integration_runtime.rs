//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! message) when the artifacts are absent so `cargo test` works on a fresh
//! checkout.

use std::path::Path;

use smart_pim::runtime::vgg_tiny::{load_golden, CLASSES, IMAGE_LEN};
use smart_pim::runtime::{literal_i32, Runtime, VggTiny};

fn artifacts() -> Option<Runtime> {
    if !Path::new("artifacts/vgg_tiny_b1.hlo.txt").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new("artifacts").expect("PJRT CPU client"))
}

#[test]
fn crossbar_gemm_artifact_matches_cpu_reference() {
    let Some(rt) = artifacts() else { return };
    let exe = rt.load("crossbar_gemm_128").unwrap();
    // Deterministic integer inputs; compute the expected signed GEMM in
    // rust (the kernel is lossless at the default 10-bit ADC).
    let x: Vec<i32> = (0..128 * 128).map(|i| (i * 31 + 7) % 65536).map(|v| v as i32).collect();
    let w: Vec<i32> = (0..128 * 128)
        .map(|i| ((i * 97 + 13) % 65536) as i32 - 32768)
        .collect();
    let xl = literal_i32(&x, &[128, 128]).unwrap();
    let wl = literal_i32(&w, &[128, 128]).unwrap();
    let got = exe.run_i32(&[xl, wl]).unwrap();
    // Reference: i64 GEMM wrapped to the kernel's int32 accumulator
    // semantics (full-range 16-bit inputs overflow 32 bits by design).
    for m in [0usize, 1, 63, 127] {
        for n in [0usize, 17, 127] {
            let mut acc: i64 = 0;
            for k in 0..128 {
                acc += x[m * 128 + k] as i64 * w[k * 128 + n] as i64;
            }
            assert_eq!(
                got[m * 128 + n],
                acc as i32, // wrapping cast == int32 accumulator
                "mismatch at ({m},{n})"
            );
        }
    }
}

#[test]
fn vgg_tiny_b1_matches_golden_logits() {
    let Some(rt) = artifacts() else { return };
    let model = VggTiny::load(&rt).unwrap();
    let (img, want) = load_golden(&rt, 1).unwrap();
    assert_eq!(img.len(), IMAGE_LEN);
    assert_eq!(want.len(), CLASSES);
    let got = model.infer(&img).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!(
            (g - w).abs() < 1e-3,
            "logit mismatch: rust {g} vs python {w}"
        );
    }
}

#[test]
fn vgg_tiny_b4_matches_golden_logits() {
    let Some(rt) = artifacts() else { return };
    let model = VggTiny::load(&rt).unwrap();
    let (img, want) = load_golden(&rt, 4).unwrap();
    assert_eq!(img.len(), 4 * IMAGE_LEN);
    let got = model.infer(&img).unwrap();
    assert_eq!(got.len(), 4 * CLASSES);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-3, "logit {i}: rust {g} vs python {w}");
    }
}

#[test]
fn batch_consistency_b4_vs_b1() {
    // The same image served through the b1 and b4 executables must agree:
    // the batcher's padding path depends on this.
    let Some(rt) = artifacts() else { return };
    let model = VggTiny::load(&rt).unwrap();
    let (img, _) = load_golden(&rt, 1).unwrap();
    let single = model.infer(&img).unwrap();
    let mut four = Vec::new();
    for _ in 0..4 {
        four.extend_from_slice(&img);
    }
    let batched = model.infer(&four).unwrap();
    for b in 0..4 {
        for c in 0..CLASSES {
            let d = (batched[b * CLASSES + c] - single[c]).abs();
            assert!(d < 1e-4, "batch row {b} class {c} differs by {d}");
        }
    }
}

#[test]
fn classify_is_argmax() {
    let Some(rt) = artifacts() else { return };
    let model = VggTiny::load(&rt).unwrap();
    let (img, want) = load_golden(&rt, 1).unwrap();
    let class = model.classify(&img).unwrap()[0];
    let want_class = want
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(class, want_class);
}

#[test]
fn wrong_batch_size_rejected() {
    let Some(rt) = artifacts() else { return };
    let model = VggTiny::load(&rt).unwrap();
    let err = model.infer(&vec![0.0; 2 * IMAGE_LEN]).unwrap_err();
    assert!(err.to_string().contains("unsupported batch"), "{err}");
    let err = model.infer(&vec![0.0; 100]).unwrap_err();
    assert!(err.to_string().contains("whole batch"), "{err}");
}

#[test]
fn weights_file_contents_sane() {
    let Some(rt) = artifacts() else { return };
    let w = rt.load_weights("weights_vgg_tiny.bin").unwrap();
    assert_eq!(w.tensors.len(), 5);
    // Q3.12 signed 16-bit range.
    for t in &w.tensors {
        let max = t.data.iter().map(|v| v.abs()).max().unwrap();
        assert!(max < 1 << 15, "{}: weight {max} out of int16", t.name);
        assert!(t.elements() > 0);
    }
    // Layer shapes chain: conv K = in_ch * 9.
    assert_eq!(w.tensors[0].dims, vec![27, 16]);
    assert_eq!(w.tensors[1].dims, vec![144, 32]);
    assert_eq!(w.tensors[2].dims, vec![288, 32]);
    assert_eq!(w.tensors[3].dims, vec![512, 64]);
    assert_eq!(w.tensors[4].dims, vec![64, 10]);
}
