//! Golden + law tests for the mapping-backend refactor.
//!
//! Three layers of pinning:
//! 1. **Seed parity** — the `Im2col` backend (and every `*_with` API at its
//!    im2col default) reproduces the pre-refactor goldens bit-identically:
//!    Fig. 7 tile footprints, the 3136-cycle VGG beat, the unreplicated
//!    intervals and pipeline fills.
//! 2. **The column-conservation law** — on the paper node's 128-column
//!    subarrays VW-SDK *exactly ties* im2col's subarrays-per-rate on every
//!    conv layer of every workload (`mapping::backend` module doc), and
//!    wins strictly only on a column-slack geometry (192 columns).
//! 3. **Joint search domination** — the VW-SDK / auto planner searches
//!    never lose to the im2col-only search at the paper's 320-tile budget,
//!    confirmed through the cycle-accurate engine.

use smart_pim::cnn::{resnet, vgg, workload, workload_names, ResNetVariant, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{
    pack_layer, plan_tiles, plan_tiles_with, MappingKind, MappingMode, MappingSelection,
    NetworkMapping, ReplicationPlan,
};
use smart_pim::planner::{evaluate_candidates, plan_for, plan_for_mapped, CostModel};
use smart_pim::sweep::SweepRunner;

const PAPER_BUDGET: usize = 320;

/// Pre-refactor Fig. 7 tile footprints, A..E (seed golden).
const FIG7_TILES: [usize; 5] = [163, 173, 180, 221, 269];
/// Fig. 7 footprints under the uniform VW-SDK selection, A..E: the stem's
/// 16-window copy adds 10 tiles (16x replicated 64-subarray copies) and
/// still fits the 320-tile node.
const FIG7_TILES_VWSDK: [usize; 5] = [173, 183, 190, 231, 279];

#[test]
fn golden_im2col_backend_keeps_seed_tile_footprints() {
    let arch = ArchConfig::paper_node();
    for (v, (&seed, &vw)) in VggVariant::ALL
        .iter()
        .zip(FIG7_TILES.iter().zip(&FIG7_TILES_VWSDK))
    {
        let net = vgg::build(*v);
        let plan = ReplicationPlan::fig7(*v);
        assert_eq!(plan_tiles(&net, &arch, &plan.factors), seed, "{}", v.name());
        assert_eq!(
            plan_tiles_with(
                &net,
                &arch,
                &plan.factors,
                &MappingSelection::im2col(net.len())
            ),
            seed,
            "{}: *_with at the im2col default must be bit-identical",
            v.name()
        );
        let vwsdk = plan_tiles_with(
            &net,
            &arch,
            &plan.factors,
            &MappingSelection::uniform(MappingKind::VwSdk, net.len()),
        );
        assert_eq!(vwsdk, vw, "{}", v.name());
        assert!(vwsdk <= PAPER_BUDGET, "{}: vwsdk fig7 over budget", v.name());
    }
}

#[test]
fn golden_im2col_intervals_and_fills_are_the_seed_values() {
    // The pre-refactor anchors: Fig. 7's 3136 beat + fill 1331 (VGG-E),
    // the unreplicated intervals (VGG-A 50176, ResNets 12544) and fills
    // (ResNet-18 1956, ResNet-34 3132).
    let arch = ArchConfig::paper_node();
    let e = vgg::build(VggVariant::E);
    let fig7 = CostModel::new(&e, &arch)
        .assess(&ReplicationPlan::fig7(VggVariant::E))
        .unwrap();
    assert_eq!(fig7.interval, 3136);
    assert_eq!(fig7.fill_cycles, 1331);
    for (name, interval, fill) in [
        ("vggA", 50176, None),
        ("resnet18", 12544, Some(1956)),
        ("resnet34", 12544, Some(3132)),
    ] {
        let net = workload(name).unwrap();
        let a = CostModel::new(&net, &arch)
            .assess(&ReplicationPlan::none(&net))
            .unwrap();
        assert_eq!(a.interval, interval, "{name}");
        if let Some(f) = fill {
            assert_eq!(a.fill_cycles, f, "{name}");
        }
    }
}

#[test]
fn golden_build_with_im2col_is_build() {
    // Per-layer bit parity of the delegating API across every workload and
    // plan shape the repo uses.
    let arch = ArchConfig::paper_node();
    for name in workload_names() {
        let net = workload(name).unwrap();
        let mut plans = vec![ReplicationPlan::none(&net)];
        if let Ok(v) = name.parse::<VggVariant>() {
            plans.push(ReplicationPlan::fig7(v));
        }
        for plan in &plans {
            let seed = NetworkMapping::build(&net, &arch, plan).unwrap();
            let with = NetworkMapping::build_with(
                &net,
                &arch,
                plan,
                &MappingSelection::im2col(net.len()),
            )
            .unwrap();
            assert_eq!(seed.total_tiles, with.total_tiles, "{name}");
            for (a, b) in seed.layers.iter().zip(&with.layers) {
                assert_eq!(a.demand, b.demand, "{name}/{}", a.name);
                assert_eq!(a.replication, b.replication, "{name}/{}", a.name);
                assert_eq!(a.tile_ids, b.tile_ids, "{name}/{}", a.name);
                assert_eq!(a.reload_rounds, b.reload_rounds, "{name}/{}", a.name);
                assert_eq!(b.mapping, MappingKind::Im2col, "{name}/{}", a.name);
                assert_eq!(b.parallel_windows, 1, "{name}/{}", a.name);
            }
        }
    }
}

#[test]
fn law_vwsdk_exactly_ties_per_rate_on_the_paper_node() {
    // The column-conservation law: every channel count is a multiple of
    // 16, so the 128-column packing is exact and VW-SDK can tie but never
    // strictly beat im2col per unit emission rate — on any conv layer of
    // any workload.
    let arch = ArchConfig::paper_node();
    for name in workload_names() {
        let net = workload(name).unwrap();
        for l in net.layers().iter().filter(|l| l.is_conv()) {
            let i = pack_layer(MappingKind::Im2col, l, &arch);
            let v = pack_layer(MappingKind::VwSdk, l, &arch);
            assert_eq!(
                v.demand.subarrays() as u64,
                i.demand.subarrays() as u64 * v.parallel_windows,
                "{name}/{}: law violated (vwsdk {} subs @ pw {}, im2col {})",
                l.name,
                v.demand.subarrays(),
                v.parallel_windows,
                i.demand.subarrays()
            );
        }
    }
}

#[test]
fn law_vwsdk_wins_strictly_on_every_vgg_under_column_slack() {
    // Where VW-SDK's advertised savings actually live: a geometry with
    // column slack (192 columns; 8N = 512 leaves 64 idle per block). There
    // the stem conv of every VGG variant takes strictly fewer subarrays
    // per rate than im2col.
    let mut arch = ArchConfig::paper_node();
    arch.subarray_cols = 192;
    arch.validate().expect("192-column node validates");
    for v in VggVariant::ALL {
        let net = vgg::build(v);
        let stem = net.layers().iter().find(|l| l.is_conv()).unwrap();
        let i = pack_layer(MappingKind::Im2col, stem, &arch);
        let w = pack_layer(MappingKind::VwSdk, stem, &arch);
        assert!(w.parallel_windows > 1, "{}", v.name());
        assert!(
            (w.demand.subarrays() as u64) < i.demand.subarrays() as u64 * w.parallel_windows,
            "{}: no strict win ({} subs @ pw {} vs {})",
            v.name(),
            w.demand.subarrays(),
            w.parallel_windows,
            i.demand.subarrays()
        );
    }
}

#[test]
fn golden_vwsdk_stem_packings() {
    let arch = ArchConfig::paper_node();
    // VGG stem (3ch 3x3 s1 over 224x224): (2,8) windows -> 4x10 IFM
    // window, one row block, 16 pixels/cycle.
    let vgg_net = vgg::build(VggVariant::A);
    let p = pack_layer(MappingKind::VwSdk, &vgg_net.layers()[0], &arch);
    assert_eq!(p.parallel_windows, 16);
    assert_eq!(p.window, (4, 10));
    assert_eq!(p.demand.row_blocks, 1);
    assert_eq!(p.demand.subarrays(), 64);
    // ResNet stem (3ch 7x7 s2 over 224x224): (2,2) windows -> 9x9 window,
    // two row blocks, 4 pixels/cycle.
    let r18 = resnet::build(ResNetVariant::R18);
    let stem = r18.layers().iter().find(|l| l.is_conv()).unwrap();
    let p = pack_layer(MappingKind::VwSdk, stem, &arch);
    assert_eq!(p.parallel_windows, 4);
    assert_eq!(p.window, (9, 9));
    assert_eq!(p.demand.row_blocks, 2);
    assert_eq!(p.demand.subarrays(), 32);
}

#[test]
fn golden_vwsdk_unreplicated_intervals_and_fills() {
    // The tie is still worth taking: with *no* replication the VW-SDK
    // packing alone cuts the steady-state beat (stem emits pq pixels per
    // cycle from one copy) and shortens the pipeline fill.
    let arch = ArchConfig::paper_node();
    for (name, interval, fill) in [
        ("vggA", 12544, 1793),
        ("resnet18", 3136, 1527),
        ("resnet34", 3136, 2703),
    ] {
        let net = workload(name).unwrap();
        let cm = CostModel::new(&net, &arch);
        let a = cm
            .assess_with(
                &ReplicationPlan::none(&net),
                &MappingSelection::uniform(MappingKind::VwSdk, net.len()),
            )
            .unwrap();
        assert_eq!(a.interval, interval, "{name}");
        assert_eq!(a.fill_cycles, fill, "{name}");
    }
}

#[test]
fn golden_joint_search_never_loses_to_im2col_search() {
    // The ISSUE's acceptance bar: at the paper budget the VW-SDK and the
    // joint (auto) searches reach a modeled interval <= the im2col-only
    // search for every workload, inside the same tile budget.
    let arch = ArchConfig::paper_node();
    for name in workload_names() {
        let net = workload(name).unwrap();
        let seed = plan_for(&net, &arch, PAPER_BUDGET).unwrap();
        for mode in [MappingMode::VwSdk, MappingMode::Auto] {
            let r = plan_for_mapped(&net, &arch, PAPER_BUDGET, mode).unwrap();
            assert!(
                r.best.assessment.interval <= seed.best.assessment.interval,
                "{name} ({mode}): {} > im2col {}",
                r.best.assessment.interval,
                seed.best.assessment.interval
            );
            assert!(
                r.best.assessment.tiles <= PAPER_BUDGET,
                "{name} ({mode}): over budget"
            );
        }
    }
}

#[test]
fn golden_engine_confirms_vwsdk_search() {
    // Model -> engine consistency for the new backend: the VW-SDK searched
    // plan's measured steady-state interval tracks its model and never
    // loses to the im2col searched plan's measurement.
    let arch = ArchConfig::paper_node();
    let runner = SweepRunner::new();
    for name in ["vggA", "resnet18"] {
        let net = workload(name).unwrap();
        let mut pair = vec![
            plan_for(&net, &arch, PAPER_BUDGET).unwrap().best,
            plan_for_mapped(&net, &arch, PAPER_BUDGET, MappingMode::VwSdk)
                .unwrap()
                .best,
        ];
        evaluate_candidates(&net, &arch, &runner, &mut pair, 10);
        let seed = pair[0].measured_interval.expect("im2col engine run");
        let vw = pair[1].measured_interval.expect("vwsdk engine run");
        assert!(
            vw <= seed * 1.01 + 32.0,
            "{name}: engine says vwsdk {vw} > im2col {seed}"
        );
        let modeled = pair[1].assessment.interval as f64;
        assert!(
            (vw - modeled).abs() <= modeled * 0.10 + 64.0,
            "{name}: engine {vw} far from model {modeled}"
        );
    }
}
