//! Observability parity suite: the hard contract of the `obs` layer.
//!
//! 1. Running any engine through its `*_with_sink` / `*_traced` entry
//!    point with the [`NullSink`] produces **bit-identical** stats to the
//!    legacy entry point (which now merely delegates) — instrumentation
//!    with tracing off costs one branch and changes nothing observable.
//! 2. Attaching a [`RecordingSink`] still changes nothing observable:
//!    recorded runs report the same stats, percentiles, perf gauges, and
//!    metrics registries as un-recorded runs.
//! 3. The Chrome trace-event export round-trips through the in-tree JSON
//!    parser, keeps per-track timestamps monotone, spans at least three
//!    subsystems for a cluster run, and is byte-deterministic per seed.

use smart_pim::cluster::{
    rate_from_qps, simulate, simulate_tenants, simulate_tenants_with_sink, simulate_with_sink,
    ClusterConfig, ClusterStats, NodeModel, Residency, TenantConfig, TenantWorkload,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::{ArchConfig, NocKind};
use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
use smart_pim::noc::{
    run_synthetic_traced, run_synthetic_with, Mesh, StepMode, SyntheticConfig,
};
use smart_pim::obs::trace::{NullSink, RecordingSink, SharedSink, TracePhase};
use smart_pim::power::WriteCost;
use smart_pim::sim::{Engine, NocAdjust};
use smart_pim::util::Json;

// ---- NoC event engine ----------------------------------------------------

#[test]
fn noc_stats_are_bit_identical_across_sinks() {
    let arch = ArchConfig::paper_node();
    let mesh = Mesh::new(8, 8);
    let cfg = SyntheticConfig {
        injection_rate: 0.08,
        measure: 3_000,
        ..Default::default()
    };
    for kind in NocKind::ALL {
        for mode in [StepMode::EventDriven, StepMode::CycleStepped] {
            let base = run_synthetic_with(kind, mesh, &cfg, arch.hpc_max, mode);
            let null = run_synthetic_traced(kind, mesh, &cfg, arch.hpc_max, mode, None);
            let rec = RecordingSink::new().shared();
            let traced = run_synthetic_traced(
                kind,
                mesh,
                &cfg,
                arch.hpc_max,
                mode,
                Some(rec.clone() as SharedSink),
            );
            assert_eq!(base, null, "{kind:?} {mode:?}: NullSink perturbed stats");
            assert_eq!(base, traced, "{kind:?} {mode:?}: recording perturbed stats");
            let sink = rec.borrow();
            assert!(
                !sink.events_for("noc").is_empty(),
                "{kind:?} {mode:?}: no noc events recorded"
            );
            for name in ["inject", "eject"] {
                assert!(
                    sink.events().iter().any(|e| e.name == name),
                    "{kind:?} {mode:?}: missing {name:?} events"
                );
            }
        }
    }
}

#[test]
fn smart_noc_records_bypass_events_at_low_load() {
    let arch = ArchConfig::paper_node();
    let cfg = SyntheticConfig {
        injection_rate: 0.02,
        measure: 3_000,
        ..Default::default()
    };
    let rec = RecordingSink::new().shared();
    let _ = run_synthetic_traced(
        NocKind::Smart,
        Mesh::new(8, 8),
        &cfg,
        arch.hpc_max,
        StepMode::EventDriven,
        Some(rec.clone() as SharedSink),
    );
    // SMART's whole point: multi-hop bypass under low contention.
    assert!(
        rec.borrow().events().iter().any(|e| e.name == "bypass"),
        "no SMART bypass events at 2% load"
    );
}

// ---- pipeline engine -----------------------------------------------------

#[test]
fn pipeline_engine_schedule_is_bit_identical_across_sinks() {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::A);
    let plan = ReplicationPlan::none(&net);
    let mapping = NetworkMapping::build(&net, &arch, &plan).expect("VGG-A maps");
    let plans = smart_pim::pipeline::build_plans(&net, &mapping, &arch);
    let adjust = NocAdjust::identity(plans.len());
    let images = 4u64;

    let base = Engine::new(&plans, &adjust, true, images).run();
    let mut null = NullSink;
    let with_null = Engine::new(&plans, &adjust, true, images).run_with_sink(&mut null);
    let mut rec = RecordingSink::new();
    let traced = Engine::new(&plans, &adjust, true, images).run_with_sink(&mut rec);

    for r in [&with_null, &traced] {
        assert_eq!(base.completions, r.completions);
        assert_eq!(base.injections, r.injections);
        assert_eq!(base.cycles, r.cycles);
    }
    // Exactly one emission-window span per (stage, image), one inject and
    // one complete instant per image.
    let spans = rec
        .events()
        .iter()
        .filter(|e| e.name == "stage" && matches!(e.phase, TracePhase::Span { .. }))
        .count();
    assert_eq!(spans, plans.len() * images as usize);
    for name in ["inject", "complete"] {
        let n = rec.events().iter().filter(|e| e.name == name).count();
        assert_eq!(n, images as usize, "{name} instants");
    }
}

// ---- cluster event loop --------------------------------------------------

fn cluster_fixture() -> (NodeModel, ClusterConfig) {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let model = NodeModel::from_workload(&net, &arch, &plan).expect("VGG-E fig7 maps");
    let cfg = ClusterConfig {
        nodes: 3,
        rate_per_cycle: rate_from_qps(2_500.0, arch.logical_cycle_ns),
        fixed_requests: Some(2_000),
        seed: 0x0B5_CAFE,
        ..ClusterConfig::default()
    };
    (model, cfg)
}

fn cluster_identical(a: &ClusterStats, b: &ClusterStats) {
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.drained_at, b.drained_at);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.peak_calendar_depth, b.peak_calendar_depth);
    assert_eq!(a.latency.p50(), b.latency.p50());
    assert_eq!(a.latency.p99(), b.latency.p99());
    assert_eq!(a.latency.p999(), b.latency.p999());
    assert_eq!(a.latency.mean(), b.latency.mean());
    assert_eq!(a.queueing.p99(), b.queueing.p99());
    assert_eq!(a.node_utilization, b.node_utilization);
    assert_eq!(a.per_node_completed, b.per_node_completed);
    assert_eq!(a.metrics, b.metrics);
}

#[test]
fn cluster_stats_are_bit_identical_across_sinks() {
    let (model, cfg) = cluster_fixture();
    let base = simulate(&model, &cfg);
    let with_null = simulate_with_sink(&model, &cfg, &mut NullSink);
    let mut rec = RecordingSink::new();
    let traced = simulate_with_sink(&model, &cfg, &mut rec);
    cluster_identical(&base, &with_null);
    cluster_identical(&base, &traced);
    assert!(!base.metrics.is_empty(), "cluster metrics registry empty");

    // The recorded stream covers the route / batch / node subsystems.
    for sub in ["cluster.route", "cluster.batch", "cluster.node"] {
        assert!(
            !rec.events_for(sub).is_empty(),
            "no {sub} events in a loaded run"
        );
    }
    let services = rec
        .events_for("cluster.node")
        .iter()
        .filter(|e| e.name == "service")
        .count();
    assert_eq!(services as u64, base.completed);
}

// ---- multi-tenant loop ---------------------------------------------------

fn tenant_fixture() -> (Vec<TenantWorkload>, TenantConfig) {
    let arch = ArchConfig::paper_node();
    let build = |name: &str| -> TenantWorkload {
        let net = smart_pim::cnn::workload(name).expect("known workload");
        let plan = match net.name.parse::<VggVariant>() {
            Ok(v) => ReplicationPlan::fig7(v),
            Err(_) => ReplicationPlan::none(&net),
        };
        let model = NodeModel::from_workload(&net, &arch, &plan).expect("plan maps");
        let mapping = NetworkMapping::build(&net, &arch, &plan).expect("plan maps");
        TenantWorkload::from_model(
            &net.name,
            1.0,
            &model,
            WriteCost::of_mapping(&net, &mapping, &arch),
        )
    };
    let tenants = vec![build("vggE"), build("resnet18")];
    let cfg = TenantConfig {
        nodes: 3,
        residency: Residency::Reprogram,
        mix: smart_pim::cluster::MixMode::Alternate,
        rate_per_cycle: 0.01,
        fixed_requests: Some(1_500),
        seed: 0x0B5_CAFE,
        ..TenantConfig::default()
    };
    (tenants, cfg)
}

#[test]
fn tenant_stats_are_bit_identical_across_sinks() {
    let (tenants, cfg) = tenant_fixture();
    let base = simulate_tenants(&tenants, &cfg).expect("tenant sim runs");
    let with_null =
        simulate_tenants_with_sink(&tenants, &cfg, &mut NullSink).expect("tenant sim runs");
    let mut rec = RecordingSink::new();
    let traced = simulate_tenants_with_sink(&tenants, &cfg, &mut rec).expect("tenant sim runs");

    for r in [&with_null, &traced] {
        assert_eq!(base.offered, r.offered);
        assert_eq!(base.completed, r.completed);
        assert_eq!(base.rejected, r.rejected);
        assert_eq!(base.drained_at, r.drained_at);
        assert_eq!(base.events_processed, r.events_processed);
        assert_eq!(base.peak_calendar_depth, r.peak_calendar_depth);
        assert_eq!(base.per_node_swaps, r.per_node_swaps);
        assert_eq!(base.node_utilization, r.node_utilization);
        assert_eq!(base.metrics, r.metrics);
        for (x, y) in base.tenants.iter().zip(&r.tenants) {
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.swaps, y.swaps);
            assert_eq!(x.latency.p99(), y.latency.p99());
        }
    }
    // An alternating two-tenant mix on a reprogram fleet must swap, and
    // every swap leaves a reprogram span with the write cost attached.
    assert!(base.total_swaps() > 0, "fixture produced no swaps");
    let reprograms: Vec<_> = rec
        .events_for("tenant")
        .into_iter()
        .filter(|e| e.name == "reprogram")
        .collect();
    assert_eq!(reprograms.len() as u64, base.total_swaps());
    assert!(reprograms
        .iter()
        .all(|e| e.args.iter().any(|&(k, v)| k == "write_cycles" && v > 0)));
    let services = rec
        .events_for("tenant")
        .iter()
        .filter(|e| e.name == "service")
        .count();
    assert_eq!(services as u64, base.completed);
}

// ---- Chrome export -------------------------------------------------------

#[test]
fn chrome_export_round_trips_and_is_deterministic() {
    let (model, cfg) = cluster_fixture();
    let render = || {
        let mut rec = RecordingSink::new();
        let _ = simulate_with_sink(&model, &cfg, &mut rec);
        rec.chrome_trace().render_pretty()
    };
    let text = render();
    assert_eq!(text, render(), "trace export not deterministic per seed");

    let doc = Json::parse(&text).expect("export parses");
    let events = doc
        .get("traceEvents")
        .expect("traceEvents envelope")
        .as_arr()
        .expect("traceEvents is an array");
    assert!(!events.is_empty());

    let mut pids = std::collections::BTreeSet::new();
    let mut phases = std::collections::BTreeSet::new();
    let mut last_ts: std::collections::BTreeMap<(u64, u64), f64> =
        std::collections::BTreeMap::new();
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
        if ph == "M" {
            continue;
        }
        phases.insert(ph.to_string());
        let pid = e.get("pid").and_then(|p| p.as_f64()).expect("pid") as u64;
        let tid = e.get("tid").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64;
        let ts = e.get("ts").and_then(|t| t.as_f64()).expect("ts");
        pids.insert(pid);
        let prev = last_ts.insert((pid, tid), ts).unwrap_or(f64::MIN);
        assert!(ts >= prev, "track ({pid},{tid}) went backwards: {prev} -> {ts}");
    }
    assert!(pids.len() >= 3, "expected >=3 subsystems, got {pids:?}");
    assert!(phases.contains("X") && phases.contains("i"), "{phases:?}");
}

// ---- metrics surface -----------------------------------------------------

#[test]
fn cluster_json_carries_the_metrics_block() {
    let (model, cfg) = cluster_fixture();
    let stats = simulate(&model, &cfg);
    let text = stats.to_json(ArchConfig::paper_node().logical_cycle_ns).render_pretty();
    let doc = Json::parse(&text).expect("stats JSON parses");
    let metrics = doc.get("metrics").expect("metrics block");
    for name in [
        "cluster.events.arrival",
        "cluster.events.completion",
        "cluster.events.processed",
    ] {
        assert!(
            metrics.get("counters").and_then(|c| c.get(name)).is_some(),
            "missing counter {name}"
        );
    }
    assert!(
        metrics
            .get("gauges")
            .and_then(|g| g.get("cluster.calendar.peak_depth"))
            .is_some(),
        "missing peak-depth gauge"
    );
    assert!(
        metrics
            .get("histograms")
            .and_then(|h| h.get("cluster.batch.released"))
            .and_then(|h| h.get("count"))
            .is_some(),
        "missing released-batch histogram"
    );
}
