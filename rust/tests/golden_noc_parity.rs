//! Golden parity: the event-driven NoC engine must report *identical*
//! `NocStats` to the seed cycle-stepped engine — same latency means, same
//! reception rates, same completed/dropped counts — for every synthetic
//! pattern, at low / mid / saturating injection rates, for both wormhole
//! and SMART. Any skipped cycle or skipped router in the event engine must
//! therefore be a provable no-op (see `noc/network.rs` module docs).

use smart_pim::config::NocKind;
use smart_pim::noc::{run_synthetic_with, Mesh, Pattern, StepMode, SyntheticConfig};

fn cfg(pattern: Pattern, rate: f64) -> SyntheticConfig {
    SyntheticConfig {
        pattern,
        injection_rate: rate,
        packet_len: 4,
        warmup: 400,
        measure: 1_600,
        drain: 6_000,
        seed: 0xC0FFEE,
        ..Default::default()
    }
}

#[test]
fn event_engine_matches_seed_engine_on_full_grid() {
    // 6 patterns x 3 rates x 2 flow controls = 36 paired runs. The 0.10
    // point saturates the wormhole baseline, so the parity check covers
    // dropped packets and source-queue backlog too, not just happy paths.
    let mesh = Mesh::new(8, 8);
    for pattern in Pattern::ALL {
        for rate in [0.02, 0.06, 0.10] {
            for kind in [NocKind::Wormhole, NocKind::Smart] {
                let c = cfg(pattern, rate);
                let event = run_synthetic_with(kind, mesh, &c, 14, StepMode::EventDriven);
                let seed = run_synthetic_with(kind, mesh, &c, 14, StepMode::CycleStepped);
                assert_eq!(
                    event,
                    seed,
                    "engines diverged: {kind:?} / {} @ {rate}",
                    pattern.name()
                );
            }
        }
    }
}

#[test]
fn parity_holds_on_rectangular_mesh_and_small_hpc() {
    // The CNN co-simulation runs a 16x20 mesh; parity must not be an
    // 8x8-only artifact, and must hold for partial bypass reach.
    let mesh = Mesh::new(16, 20);
    for (kind, hpc) in [(NocKind::Wormhole, 1), (NocKind::Smart, 4)] {
        let c = SyntheticConfig {
            pattern: Pattern::UniformRandom,
            injection_rate: 0.04,
            warmup: 300,
            measure: 1_000,
            drain: 5_000,
            seed: 0xF00D,
            ..Default::default()
        };
        let event = run_synthetic_with(kind, mesh, &c, hpc, StepMode::EventDriven);
        let seed = run_synthetic_with(kind, mesh, &c, hpc, StepMode::CycleStepped);
        assert_eq!(event, seed, "{kind:?} hpc={hpc} diverged on 16x20");
    }
}

#[test]
fn parity_holds_for_long_packets_and_deep_pipelines() {
    // Multi-flit wormhole segments + a 4-cycle router pipeline exercise the
    // body-flit replay and the event calendar's ready_at jumps.
    let mesh = Mesh::new(8, 8);
    let c = SyntheticConfig {
        pattern: Pattern::Tornado,
        injection_rate: 0.05,
        packet_len: 8,
        warmup: 200,
        measure: 1_000,
        drain: 8_000,
        seed: 0xBADA55,
        wormhole_router: (4, 2),
        smart_router: (2, 4),
        ..Default::default()
    };
    for kind in [NocKind::Wormhole, NocKind::Smart] {
        let event = run_synthetic_with(kind, mesh, &c, 14, StepMode::EventDriven);
        let seed = run_synthetic_with(kind, mesh, &c, 14, StepMode::CycleStepped);
        assert_eq!(event, seed, "{kind:?} diverged (len 8, deep pipeline)");
    }
}
