//! Property tests over the cluster serving simulator:
//!
//! 1. mean end-to-end latency is monotone **non-decreasing in offered
//!    QPS** at fixed fleet size (same unit-rate arrival stream, FIFO
//!    singles: Lindley's recurrence under gap compression);
//! 2. mean latency is monotone **non-increasing in fleet size** at fixed
//!    QPS (round-robin subsampling stretches every node-local gap);
//! 3. **conservation**: arrivals = completions + rejections at drain, for
//!    every routing policy, batching shape, and admission bound;
//! 4. **determinism**: identical seeds give bit-identical stats.
//!
//! The monotonicity properties hold pointwise per request for FIFO
//! single-image batches (`sizes = [1]`) and round-robin routing — the
//! configuration the capacity planner's section search relies on; see
//! DESIGN.md §4a for why hoarding batchers can locally invert them.

use smart_pim::cluster::{
    simulate, ArrivalProcess, ClusterConfig, NodeModel, RouteImpl, RoutePolicy,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::BatchPolicy;
use smart_pim::mapping::ReplicationPlan;
use smart_pim::prop_assert;
use smart_pim::util::prop::{check, Config, Gen};

fn model() -> NodeModel {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    NodeModel::from_workload(&net, &arch, &plan).unwrap()
}

/// FIFO singles: the configuration under which per-request waits are
/// provably monotone (no hoarding, no padding).
fn singles() -> BatchPolicy {
    BatchPolicy {
        sizes: vec![1],
        max_wait: 0,
        min_fill: 1.0,
    }
}

/// Fixed-population scenario: `n` requests from the seeded unit stream.
fn fixed_cfg(nodes: usize, rate: f64, requests: usize, seed: u64) -> ClusterConfig {
    ClusterConfig {
        nodes,
        rate_per_cycle: rate,
        pattern: ArrivalProcess::Poisson,
        route: RoutePolicy::RoundRobin,
        max_queue: u64::MAX,
        horizon_cycles: 0, // unused with fixed_requests
        fixed_requests: Some(requests),
        policy: singles(),
        seed,
        route_impl: RouteImpl::Indexed,
    }
}

#[test]
fn mean_latency_monotone_in_offered_qps() {
    let m = model();
    let cases = Config {
        cases: 24,
        ..Config::default()
    };
    check("cluster-qps-monotone", &cases, |g| {
        let nodes = 1 + g.rng.below_usize(4);
        let requests = 20 + g.scaled(120);
        let seed = g.rng.next_u64();
        // A ladder of offered rates from light to past saturation.
        let base = (0.2 + g.rng.next_f64() * 0.4) * nodes as f64 / m.interval as f64;
        let rates = [base, base * 1.7, base * 2.9, base * 5.0];
        let mut prev = -1.0f64;
        for &rate in &rates {
            let s = simulate(&m, &fixed_cfg(nodes, rate, requests, seed));
            prop_assert!(s.completed == s.offered, "no rejections configured");
            let mean = s.latency.mean();
            // Tolerance 2.0: arrival cycles are floor(S_k / rate), and the
            // floor errors telescope to under one cycle of wait
            // perturbation per request between two rates of the same unit
            // stream (exact monotonicity holds in real-valued time).
            prop_assert!(
                mean >= prev - 2.0,
                "mean latency fell from {prev} to {mean} when the offered \
                 rate rose to {rate} ({nodes} nodes, {requests} requests)"
            );
            prev = mean;
        }
        Ok(())
    });
}

#[test]
fn mean_latency_monotone_in_fleet_size() {
    let m = model();
    let cases = Config {
        cases: 24,
        ..Config::default()
    };
    check("cluster-fleet-monotone", &cases, |g| {
        let requests = 20 + g.scaled(120);
        let seed = g.rng.next_u64();
        // A load around one-to-three nodes' worth of capacity.
        let rate = (0.5 + g.rng.next_f64() * 2.5) / m.interval as f64;
        let mut prev = f64::INFINITY;
        for nodes in [1usize, 2, 3, 5, 8] {
            let s = simulate(&m, &fixed_cfg(nodes, rate, requests, seed));
            prop_assert!(s.completed == s.offered, "no rejections configured");
            let mean = s.latency.mean();
            prop_assert!(
                mean <= prev + 1e-6,
                "mean latency rose from {prev} to {mean} when the fleet \
                 grew to {nodes} nodes (rate {rate}, {requests} requests)"
            );
            prev = mean;
        }
        Ok(())
    });
}

#[test]
fn conservation_for_any_policy_mix() {
    let m = model();
    let cases = Config {
        cases: 32,
        ..Config::default()
    };
    check("cluster-conservation", &cases, |g| {
        let nodes = 1 + g.rng.below_usize(5);
        let route = RoutePolicy::ALL[g.rng.below_usize(3)];
        let pattern = match g.rng.below(4) {
            0 => ArrivalProcess::Poisson,
            1 => ArrivalProcess::from_name("bursty").unwrap(),
            2 => ArrivalProcess::from_name("diurnal").unwrap(),
            _ => {
                // A short random trace, unsorted on purpose (the loader
                // sorts; raw Trace values must already be sorted).
                let mut t: Vec<u64> =
                    (0..g.scaled(60)).map(|_| g.rng.below(400_000)).collect();
                t.sort_unstable();
                ArrivalProcess::Trace(t)
            }
        };
        let policy = if g.rng.chance(0.5) {
            BatchPolicy {
                sizes: vec![4, 1],
                max_wait: 1 + g.rng.below(8_000),
                min_fill: 0.25 + g.rng.next_f64() * 0.5,
            }
        } else {
            singles()
        };
        let cfg = ClusterConfig {
            nodes,
            rate_per_cycle: (0.2 + g.rng.next_f64() * 3.0) * nodes as f64
                / m.interval as f64,
            pattern,
            route,
            // Small bounds force rejections in some draws.
            max_queue: 1 + g.rng.below(24),
            horizon_cycles: 200_000 + g.rng.below(400_000),
            fixed_requests: None,
            policy,
            seed: g.rng.next_u64(),
            // Conservation must hold on both implementations.
            route_impl: if g.rng.chance(0.5) {
                RouteImpl::Indexed
            } else {
                RouteImpl::LinearScan
            },
        };
        let s = simulate(&m, &cfg);
        prop_assert!(
            s.completed + s.rejected == s.offered,
            "conservation broke: {} + {} != {} ({:?})",
            s.completed,
            s.rejected,
            s.offered,
            cfg.route
        );
        let node_sum: u64 = s.per_node_completed.iter().sum();
        prop_assert!(
            node_sum == s.completed,
            "per-node completions {node_sum} != total {}",
            s.completed
        );
        let reject_sum: u64 = s.per_node_rejected.iter().sum();
        prop_assert!(
            reject_sum == s.rejected,
            "per-node rejections {reject_sum} != total {}",
            s.rejected
        );
        prop_assert!(
            s.latency.count() as u64 == s.completed,
            "one latency sample per completion"
        );
        // Every latency is at least the pipeline fill (the nearest-rank
        // 0.001-percentile of u64 samples is the minimum).
        if s.completed > 0 {
            prop_assert!(
                s.latency.percentile(0.001) >= m.fill,
                "latency {} below pipeline fill {}",
                s.latency.percentile(0.001),
                m.fill
            );
        }
        Ok(())
    });
}

#[test]
fn identical_seed_is_bit_identical() {
    let m = model();
    let cases = Config {
        cases: 16,
        ..Config::default()
    };
    check("cluster-determinism", &cases, |g| {
        let cfg = ClusterConfig {
            nodes: 1 + g.rng.below_usize(4),
            rate_per_cycle: (0.3 + g.rng.next_f64() * 2.0) / m.interval as f64,
            pattern: ArrivalProcess::Poisson,
            route: RoutePolicy::ALL[g.rng.below_usize(3)],
            max_queue: 4 + g.rng.below(60),
            horizon_cycles: 300_000,
            fixed_requests: None,
            policy: BatchPolicy {
                sizes: vec![4, 1],
                max_wait: 1 + g.rng.below(5_000),
                min_fill: 0.5,
            },
            seed: g.rng.next_u64(),
            route_impl: RouteImpl::Indexed,
        };
        let a = simulate(&m, &cfg);
        let b = simulate(&m, &cfg);
        prop_assert!(a.offered == b.offered, "offered differs");
        prop_assert!(a.completed == b.completed, "completed differs");
        prop_assert!(a.rejected == b.rejected, "rejected differs");
        prop_assert!(a.drained_at == b.drained_at, "drain cycle differs");
        for p in [50.0, 95.0, 99.0, 99.9] {
            prop_assert!(
                a.latency.percentile(p) == b.latency.percentile(p),
                "p{p} differs"
            );
        }
        prop_assert!(
            a.node_utilization == b.node_utilization,
            "utilization differs"
        );
        prop_assert!(
            a.per_node_completed == b.per_node_completed,
            "per-node counts differ"
        );
        Ok(())
    });
}
