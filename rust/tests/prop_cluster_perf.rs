//! Properties of the flattened cluster event loop (PR 6):
//!
//! 1. **routing parity** — indexed jsq/least-work routing produces
//!    bit-identical [`ClusterStats`] to the linear-scan reference across
//!    random policy/routing/admission/seed mixes (the tie-break contract
//!    "lowest index wins on equal signal" is part of each index's key);
//! 2. **calendar bound** — deadline suppression keeps the heap's
//!    high-water mark at O(nodes + in-flight batches), independent of how
//!    many requests stream through;
//! 3. **streamed arrivals** — pulling arrivals one at a time reproduces
//!    the materialized generator's runs exactly (`offered`, latencies,
//!    the effective horizon), pinned here at the stats level on top of
//!    the per-pattern stream-vs-vec equality in `arrival.rs`.
//!
//! [`ClusterStats`]: smart_pim::cluster::ClusterStats

use smart_pim::cluster::{
    simulate, ArrivalProcess, ClusterConfig, ClusterStats, NodeModel, RouteImpl, RoutePolicy,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::BatchPolicy;
use smart_pim::mapping::ReplicationPlan;
use smart_pim::prop_assert;
use smart_pim::util::prop::{check, Config, Gen};

fn model() -> NodeModel {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    NodeModel::from_workload(&net, &arch, &plan).unwrap()
}

/// A random scenario mixing every axis the routing indexes must survive:
/// fleet size, load level, arrival shape, admission bound, batching
/// policy (hoarding and singles) and seed.
fn random_cfg(g: &mut Gen, m: &NodeModel, route: RoutePolicy) -> ClusterConfig {
    let nodes = 1 + g.rng.below_usize(6);
    let pattern = match g.rng.below(4) {
        0 => ArrivalProcess::Poisson,
        1 => ArrivalProcess::from_name("bursty").unwrap(),
        2 => ArrivalProcess::from_name("diurnal").unwrap(),
        _ => {
            let mut t: Vec<u64> = (0..g.scaled(80)).map(|_| g.rng.below(400_000)).collect();
            t.sort_unstable();
            ArrivalProcess::Trace(t)
        }
    };
    let policy = if g.rng.chance(0.5) {
        BatchPolicy {
            sizes: vec![4, 1],
            max_wait: 1 + g.rng.below(8_000),
            min_fill: 0.25 + g.rng.next_f64() * 0.5,
        }
    } else {
        BatchPolicy {
            sizes: vec![1],
            max_wait: 0,
            min_fill: 1.0,
        }
    };
    ClusterConfig {
        nodes,
        // From light load to ~3x fleet capacity, so the mixes cover idle
        // fleets, rejection storms and everything between.
        rate_per_cycle: (0.2 + g.rng.next_f64() * 3.0) * nodes as f64 / m.interval as f64,
        pattern,
        route,
        max_queue: 1 + g.rng.below(24),
        horizon_cycles: 150_000 + g.rng.below(350_000),
        fixed_requests: if g.rng.chance(0.25) {
            Some(10 + g.rng.below_usize(120))
        } else {
            None
        },
        policy,
        seed: g.rng.next_u64(),
        route_impl: RouteImpl::Indexed,
    }
}

/// Every field of two runs must match exactly — latency distributions,
/// per-node vectors, energy, even the perf gauges.
fn assert_identical(a: &ClusterStats, b: &ClusterStats, what: &str) -> Result<(), String> {
    prop_assert!(a.offered == b.offered, "{what}: offered {} != {}", a.offered, b.offered);
    prop_assert!(a.completed == b.completed, "{what}: completed differs");
    prop_assert!(a.rejected == b.rejected, "{what}: rejected differs");
    prop_assert!(
        a.horizon_cycles == b.horizon_cycles,
        "{what}: effective horizon differs"
    );
    prop_assert!(a.drained_at == b.drained_at, "{what}: drain cycle differs");
    prop_assert!(
        a.events_processed == b.events_processed,
        "{what}: event count differs ({} vs {})",
        a.events_processed,
        b.events_processed
    );
    prop_assert!(
        a.peak_calendar_depth == b.peak_calendar_depth,
        "{what}: peak depth differs"
    );
    prop_assert!(a.latency.count() == b.latency.count(), "{what}: sample counts");
    for p in [0.001, 25.0, 50.0, 95.0, 99.0, 99.9, 100.0] {
        prop_assert!(
            a.latency.percentile(p) == b.latency.percentile(p),
            "{what}: latency p{p} differs"
        );
        prop_assert!(
            a.queueing.percentile(p) == b.queueing.percentile(p),
            "{what}: queueing p{p} differs"
        );
    }
    prop_assert!(a.latency.mean() == b.latency.mean(), "{what}: latency mean");
    prop_assert!(a.latency.max() == b.latency.max(), "{what}: latency max");
    prop_assert!(
        a.node_utilization == b.node_utilization,
        "{what}: utilization differs"
    );
    prop_assert!(
        a.per_node_completed == b.per_node_completed,
        "{what}: per-node completions differ"
    );
    prop_assert!(
        a.per_node_rejected == b.per_node_rejected,
        "{what}: per-node rejections differ"
    );
    prop_assert!(
        a.per_node_injected == b.per_node_injected,
        "{what}: per-node injections differ"
    );
    match (&a.energy, &b.energy) {
        (Some(x), Some(y)) => {
            prop_assert!(
                x.dynamic_j == y.dynamic_j
                    && x.idle_j == y.idle_j
                    && x.padding_waste_j == y.padding_waste_j
                    && x.span_s == y.span_s
                    && x.completed_ops == y.completed_ops,
                "{what}: energy differs"
            );
        }
        (None, None) => {}
        _ => return Err(format!("{what}: energy presence differs")),
    }
    Ok(())
}

#[test]
fn indexed_routing_is_bit_identical_to_the_scan_reference() {
    let m = model();
    let cases = Config {
        cases: 28,
        ..Config::default()
    };
    check("cluster-route-impl-parity", &cases, |g| {
        // jsq and least-work have real indexes; round-robin shares one
        // code path but rides along as a control.
        let route = RoutePolicy::ALL[g.rng.below_usize(3)];
        let cfg = random_cfg(g, &m, route);
        let indexed = simulate(&m, &cfg);
        let scanned = simulate(
            &m,
            &ClusterConfig {
                route_impl: RouteImpl::LinearScan,
                ..cfg.clone()
            },
        );
        assert_identical(&indexed, &scanned, route.name())?;
        prop_assert!(
            indexed.completed + indexed.rejected == indexed.offered,
            "conservation rides along"
        );
        Ok(())
    });
}

#[test]
fn calendar_depth_is_bounded_by_fleet_and_admission() {
    // With at most one live deadline per node, the heap holds: 1 pending
    // arrival + per-node completion events (<= max_queue outstanding
    // admissions) + live deadlines (<= 1 per node) + superseded deadline
    // strays. Constraining max_wait <= pipeline fill makes every stray
    // expire before its batch completes, so strays are also <= in-flight
    // admissions — the bound is 1 + nodes + 2*nodes*max_queue no matter
    // how many requests stream through.
    let m = model();
    let cases = Config {
        cases: 12,
        ..Config::default()
    };
    check("cluster-calendar-bound", &cases, |g| {
        let nodes = 1 + g.rng.below_usize(6);
        let max_queue = 2 + g.rng.below(14);
        let cfg = ClusterConfig {
            nodes,
            // Up to ~4x capacity: deep queues, heavy deadline churn.
            rate_per_cycle: (1.0 + g.rng.next_f64() * 3.0) * nodes as f64
                / m.interval as f64,
            route: RoutePolicy::ALL[g.rng.below_usize(3)],
            max_queue,
            horizon_cycles: 400_000,
            policy: BatchPolicy {
                sizes: vec![4, 1],
                max_wait: 1 + g.rng.below(m.fill),
                min_fill: 0.25 + g.rng.next_f64() * 0.7,
            },
            seed: g.rng.next_u64(),
            ..ClusterConfig::default()
        };
        let s = simulate(&m, &cfg);
        let bound = 1 + nodes as u64 + 2 * nodes as u64 * max_queue;
        prop_assert!(
            s.peak_calendar_depth <= bound,
            "peak {} exceeds bound {bound} ({nodes} nodes, max_queue {max_queue})",
            s.peak_calendar_depth
        );
        prop_assert!(
            s.peak_calendar_depth >= 1,
            "a run with arrivals must use the calendar"
        );
        Ok(())
    });
}

#[test]
fn streamed_arrivals_reproduce_materialized_runs() {
    // The loop pulls from ArrivalStream; `generate`/`generate_n` are the
    // materializing reference. Feeding the materialized vec back through
    // a Trace replay must give the same offered count, completions and
    // latency distribution (the effective horizon is compared against the
    // extent, which is what a trace reports).
    let m = model();
    let cases = Config {
        cases: 16,
        ..Config::default()
    };
    check("cluster-streamed-arrivals", &cases, |g| {
        let route = RoutePolicy::ALL[g.rng.below_usize(3)];
        let mut cfg = random_cfg(g, &m, route);
        if matches!(cfg.pattern, ArrivalProcess::Trace(_)) {
            cfg.pattern = ArrivalProcess::Poisson;
        }
        let live = simulate(&m, &cfg);
        let materialized = match cfg.fixed_requests {
            Some(n) => cfg.pattern.generate_n(cfg.rate_per_cycle, n, cfg.seed),
            None => cfg
                .pattern
                .generate(cfg.rate_per_cycle, cfg.horizon_cycles, cfg.seed),
        };
        let extent = materialized.last().map_or(0, |&c| c + 1);
        let replay = simulate(
            &m,
            &ClusterConfig {
                pattern: ArrivalProcess::Trace(materialized),
                fixed_requests: None,
                horizon_cycles: u64::MAX,
                ..cfg.clone()
            },
        );
        prop_assert!(live.offered == replay.offered, "offered differs");
        prop_assert!(live.completed == replay.completed, "completed differs");
        prop_assert!(live.rejected == replay.rejected, "rejected differs");
        prop_assert!(live.drained_at == replay.drained_at, "drain differs");
        prop_assert!(
            live.latency.mean() == replay.latency.mean()
                && live.latency.max() == replay.latency.max(),
            "latency distribution differs"
        );
        prop_assert!(
            live.horizon_cycles >= extent || cfg.fixed_requests.is_none(),
            "fixed-request span is the arrival extent"
        );
        if cfg.fixed_requests.is_some() {
            prop_assert!(
                live.horizon_cycles == replay.horizon_cycles,
                "fixed-request span {} != trace extent {}",
                live.horizon_cycles,
                replay.horizon_cycles
            );
        }
        Ok(())
    });
}
