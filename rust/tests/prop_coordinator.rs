//! Property tests over coordinator invariants: the batcher never loses,
//! duplicates or reorders requests; the dispatcher never violates the
//! paper's structural-hazard and fixed-offset rules (Sec. IV-C); the
//! engine's schedule respects dependencies for every image.

use std::collections::VecDeque;

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::batcher::BatchPolicy;
use smart_pim::coordinator::dispatch::{Dispatcher, PipelineShape};
use smart_pim::coordinator::request::Request;
use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
use smart_pim::pipeline::build_plans;
use smart_pim::sim::engine::{Engine, NocAdjust};
use smart_pim::util::prop::{check, Config, Gen};
use smart_pim::{prop_assert, prop_assert_eq};

/// A queue of requests submitted up to 20 000 ticks before `now` (ticks are
/// µs under the server's wall clock — the batcher only sees integers).
fn random_queue(g: &mut Gen, now: u64) -> VecDeque<Request> {
    let n = g.scaled(40);
    (0..n as u64)
        .map(|id| Request {
            id,
            image: vec![0.0; 4],
            submitted: now.saturating_sub(g.rng.below(20_000)),
        })
        .collect()
}

fn random_policy(g: &mut Gen) -> BatchPolicy {
    BatchPolicy {
        sizes: vec![4, 1],
        max_wait: 1 + g.rng.below(10_000),
        min_fill: 0.25 + g.rng.next_f64() * 0.5,
    }
}

#[test]
fn batcher_never_loses_duplicates_or_reorders() {
    check("batcher-conservation", &Config::default(), |g| {
        let now = 100_000u64;
        let mut q = random_queue(g, now);
        let total = q.len();
        let policy = random_policy(g);
        let mut seen = Vec::new();
        let mut guard = 0u64;
        while !q.is_empty() {
            guard += 1;
            prop_assert!(guard < 10_000, "batcher stalled");
            // Advance time far enough that timeouts always fire eventually.
            let t = now + guard * 1_000_000;
            if let Some(b) = policy.form(&mut q, t) {
                prop_assert!(b.size() <= 4, "batch size {}", b.size());
                prop_assert!(!b.requests.is_empty(), "empty batch");
                seen.extend(b.requests.iter().map(|r| r.id));
            }
        }
        prop_assert_eq!(seen.len(), total);
        // FIFO: ids must come out in submission order.
        for w in seen.windows(2) {
            prop_assert!(w[0] < w[1], "reordered: {:?}", w);
        }
        Ok(())
    });
}

#[test]
fn batcher_padding_bounded_by_min_fill() {
    check("batcher-padding", &Config::default(), |g| {
        let now = 100_000u64;
        let mut q = random_queue(g, now);
        let policy = random_policy(g);
        let t = now + 1_000_000;
        while let Some(b) = policy.form(&mut q, t) {
            if b.padding > 0 {
                let fill = b.requests.len() as f64 / b.size() as f64;
                prop_assert!(
                    fill >= policy.min_fill - 1e-9,
                    "padded batch fill {fill} < min {}",
                    policy.min_fill
                );
            }
        }
        Ok(())
    });
}

fn random_shape(g: &mut Gen) -> PipelineShape {
    let n = 2 + g.scaled(10);
    let mut offsets = Vec::with_capacity(n);
    let mut occupancy = Vec::with_capacity(n);
    let mut off = 0u64;
    for _ in 0..n {
        offsets.push(off);
        occupancy.push(1 + g.rng.below(500));
        off += 1 + g.rng.below(300);
    }
    PipelineShape { offsets, occupancy }
}

#[test]
fn dispatcher_no_structural_hazard_for_any_arrival_pattern() {
    check("dispatch-hazard", &Config::default(), |g| {
        let shape = random_shape(g);
        let mut d = Dispatcher::new(shape);
        let n = g.scaled(60);
        let mut now = 0u64;
        for _ in 0..n {
            now += g.rng.below(400);
            d.admit(now);
        }
        d.verify_no_hazard()?;
        d.verify_fixed_offsets()?;
        Ok(())
    });
}

#[test]
fn dispatcher_work_conserving() {
    check("dispatch-work-conserving", &Config::default(), |g| {
        let shape = random_shape(g);
        let interval = shape.min_interval();
        let mut d = Dispatcher::new(shape);
        // Saturating arrivals: every admission must be exactly `interval`
        // after the previous (no idle gaps inserted).
        let n = g.scaled(50);
        for _ in 0..n {
            d.admit(0);
        }
        let inj = d.injections();
        for w in inj.windows(2) {
            prop_assert_eq!(w[1] - w[0], interval);
        }
        Ok(())
    });
}

#[test]
fn engine_schedule_respects_dependencies_and_hazards() {
    // The cycle-accurate engine itself: random VGG + replication plan; the
    // resulting schedule must keep images ordered and respect injection.
    let cfg = Config {
        cases: 12, // engine runs are heavier than the pure checks
        ..Config::default()
    };
    check("engine-dependencies", &cfg, |g| {
        let arch = ArchConfig::paper_node();
        let variants = VggVariant::ALL;
        let v = variants[g.rng.below_usize(variants.len())];
        let net = vgg::build(v);
        let plan = if g.rng.chance(0.5) {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).map_err(|e| e.to_string())?;
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let batch = g.rng.chance(0.5);
        let images = 2 + g.rng.below(4);
        let sim = Engine::new(&plans, &adj, batch, images).run();
        // Completions strictly increase, injections non-decreasing, and
        // every latency is at least the total pipeline depth.
        let min_depth: u64 = plans.iter().map(|p| p.depth).sum();
        for w in sim.completions.windows(2) {
            prop_assert!(w[0] < w[1], "completions not monotone");
        }
        for w in sim.injections.windows(2) {
            prop_assert!(w[0] <= w[1], "injections not monotone");
        }
        for (inj, comp) in sim.injections.iter().zip(&sim.completions) {
            prop_assert!(
                comp - inj >= min_depth,
                "latency {} below pipeline depth {min_depth}",
                comp - inj
            );
        }
        if !batch {
            // Without batch pipelining, image k injects only after k-1
            // completes.
            for i in 1..sim.injections.len() {
                prop_assert!(
                    sim.injections[i] >= sim.completions[i - 1],
                    "no-batch violated: inject {} < completion {}",
                    sim.injections[i],
                    sim.completions[i - 1]
                );
            }
        }
        Ok(())
    });
}
