//! Golden + property tests for the searched replication/batch planner.
//!
//! Golden: at the paper's own 320-tile budget the searched plan must
//! reproduce or dominate (by modeled steady-state interval) the hand-tuned
//! Fig. 7 plan for every VGG variant, and the cycle-accurate engine must
//! confirm the modeled interval. Property: searched plans never exceed
//! their tile budget, for any variant x budget x batch depth.

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{validate_plan, ReplicationPlan};
use smart_pim::planner::{
    evaluate_candidates, plan_for, CostModel, Planner, PlannerConfig,
};
use smart_pim::sweep::SweepRunner;
use smart_pim::util::prop::{check, Config};
use smart_pim::{prop_assert, prop_assert_eq};

const PAPER_BUDGET: usize = 320;

#[test]
fn golden_searched_dominates_fig7_for_all_vggs() {
    // Sec. VI-C hand-tunes Fig. 7 so every VGG fits 320 tiles at a 3136-
    // cycle beat; the search must never do worse under the same budget.
    let arch = ArchConfig::paper_node();
    for v in VggVariant::ALL {
        let net = vgg::build(v);
        let cm = CostModel::new(&net, &arch);
        let fig7 = cm.assess(&ReplicationPlan::fig7(v)).unwrap();
        let result = plan_for(&net, &arch, PAPER_BUDGET)
            .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        let best = &result.best.assessment;
        assert!(
            best.interval <= fig7.interval,
            "{}: searched interval {} > fig7 {}",
            v.name(),
            best.interval,
            fig7.interval
        );
        assert!(
            best.tiles <= PAPER_BUDGET,
            "{}: {} tiles over budget",
            v.name(),
            best.tiles
        );
        let tiles = validate_plan(&net, &arch, &result.best.plan)
            .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
        assert_eq!(tiles, best.tiles, "{}", v.name());
    }
}

#[test]
fn golden_engine_confirms_searched_beats_fig7() {
    // Modeled domination must survive contact with the cycle-accurate
    // engine: measured steady-state interval of the searched plan <= the
    // Fig. 7 plan's, for the extreme variants (A smallest, E largest).
    let arch = ArchConfig::paper_node();
    let runner = SweepRunner::new();
    for v in [VggVariant::A, VggVariant::E] {
        let net = vgg::build(v);
        let cm = CostModel::new(&net, &arch);
        let mut pair = vec![
            smart_pim::planner::PlanCandidate {
                plan: ReplicationPlan::fig7(v),
                assessment: cm.assess(&ReplicationPlan::fig7(v)).unwrap(),
                measured_interval: None,
                mapping: smart_pim::mapping::MappingSelection::im2col(net.len()),
            },
            plan_for(&net, &arch, PAPER_BUDGET).unwrap().best,
        ];
        evaluate_candidates(&net, &arch, &runner, &mut pair, 10);
        let fig7 = pair[0].measured_interval.expect("fig7 engine run");
        let searched = pair[1].measured_interval.expect("searched engine run");
        assert!(
            searched <= fig7 * 1.01 + 32.0,
            "{}: engine says searched {searched} > fig7 {fig7}",
            v.name()
        );
        // And the engine agrees with the model for the searched plan.
        let modeled = pair[1].assessment.interval as f64;
        assert!(
            (searched - modeled).abs() <= modeled * 0.10 + 64.0,
            "{}: engine {searched} far from model {modeled}",
            v.name()
        );
    }
}

#[test]
fn golden_fig7_interval_is_the_3136_beat() {
    // The anchor the searched plans are compared against (DESIGN.md §5):
    // every Fig. 7 plan's modeled interval is conv1's 224*224/16 beat.
    let arch = ArchConfig::paper_node();
    for v in VggVariant::ALL {
        let net = vgg::build(v);
        let a = CostModel::new(&net, &arch)
            .assess(&ReplicationPlan::fig7(v))
            .unwrap();
        assert_eq!(a.interval, 3136, "{}", v.name());
    }
}

#[test]
fn prop_searched_plans_respect_any_budget() {
    check("planner-budget", &Config::default(), |g| {
        let arch = ArchConfig::paper_node();
        let v = VggVariant::ALL[g.rng.below_usize(VggVariant::ALL.len())];
        let net = vgg::build(v);
        // Smallest feasible budget: the unreplicated plan's footprint.
        let floor = smart_pim::mapping::plan_tiles(
            &net,
            &arch,
            &ReplicationPlan::none(&net).factors,
        );
        let budget = floor + g.rng.below_usize(arch.total_tiles() - floor + 1);
        let batch_depth = 1 + g.rng.below(16);
        let beam_width = 1 + g.rng.below_usize(4);
        let planner = Planner::new(
            &net,
            &arch,
            PlannerConfig {
                tile_budget: budget,
                batch_depth,
                beam_width,
                ..PlannerConfig::default()
            },
        );
        let result = planner.search().map_err(|e| e.to_string())?;
        prop_assert!(
            result.best.assessment.tiles <= budget,
            "{}: {} tiles > budget {budget}",
            v.name(),
            result.best.assessment.tiles
        );
        // Never worse than not replicating at all.
        let none = CostModel::new(&net, &arch)
            .assess(&ReplicationPlan::none(&net))
            .map_err(|e| e.to_string())?;
        prop_assert!(
            result.best.assessment.interval <= none.interval,
            "{}: searched {} > unreplicated {}",
            v.name(),
            result.best.assessment.interval,
            none.interval
        );
        // Every frontier member fits too, and the frontier is non-empty.
        prop_assert!(!result.frontier.is_empty(), "empty frontier");
        for c in &result.frontier {
            prop_assert!(c.assessment.tiles <= budget, "frontier over budget");
            validate_plan(&net, &arch, &c.plan).map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

#[test]
fn prop_search_is_deterministic() {
    check("planner-determinism", &Config::default(), |g| {
        let arch = ArchConfig::paper_node();
        let v = VggVariant::ALL[g.rng.below_usize(VggVariant::ALL.len())];
        let net = vgg::build(v);
        let budget = 200 + g.rng.below_usize(121); // 200..=320
        let a = plan_for(&net, &arch, budget).map_err(|e| e.to_string())?;
        let b = plan_for(&net, &arch, budget).map_err(|e| e.to_string())?;
        prop_assert_eq!(&a.best.plan.factors, &b.best.plan.factors);
        prop_assert_eq!(a.explored, b.explored);
        Ok(())
    });
}

#[test]
fn searched_via_replication_api_round_trips() {
    // The mapping-layer convenience constructor must agree with the full
    // planner result.
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::D);
    let via_mapping = ReplicationPlan::searched(&net, &arch, PAPER_BUDGET).unwrap();
    let via_planner = plan_for(&net, &arch, PAPER_BUDGET).unwrap().best.plan;
    assert_eq!(via_mapping, via_planner);
}
