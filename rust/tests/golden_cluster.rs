//! Golden: the cluster model must reproduce the validated single-node
//! numbers when reduced to a single node.
//!
//! - VGG-E + Fig. 7 plan: saturating arrivals inject exactly every 3136
//!   cycles (the paper's best-case beat, pinned since the seed);
//! - ResNet-18 + no replication: interval 12544 and critical-path fill
//!   1956 (pinned by `golden_resnet.rs` since PR 3);
//! - one-request-at-a-time arrivals complete in exactly the pipeline fill
//!   — the cluster layer adds zero latency when there is no contention.

use smart_pim::cluster::{
    simulate, ArrivalProcess, ClusterConfig, NodeModel, RouteImpl, RoutePolicy,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::BatchPolicy;
use smart_pim::mapping::ReplicationPlan;

fn vgg_e_fig7() -> NodeModel {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    NodeModel::from_workload(&net, &arch, &ReplicationPlan::fig7(VggVariant::E)).unwrap()
}

fn resnet18_none() -> NodeModel {
    let arch = ArchConfig::paper_node();
    let net = smart_pim::cnn::workload("resnet18").unwrap();
    NodeModel::from_workload(&net, &arch, &ReplicationPlan::none(&net)).unwrap()
}

fn singles() -> BatchPolicy {
    BatchPolicy {
        sizes: vec![1],
        max_wait: 0,
        min_fill: 1.0,
    }
}

/// One-node scenario driven by an explicit arrival trace.
fn trace_cfg(trace: Vec<u64>) -> ClusterConfig {
    ClusterConfig {
        nodes: 1,
        rate_per_cycle: 1.0, // unused by traces
        pattern: ArrivalProcess::Trace(trace),
        route: RoutePolicy::RoundRobin,
        max_queue: u64::MAX,
        horizon_cycles: u64::MAX,
        fixed_requests: None,
        policy: singles(),
        seed: 0,
        route_impl: RouteImpl::Indexed,
    }
}

#[test]
fn vgg_e_fig7_interval_constant_survives_the_cluster_layer() {
    let m = vgg_e_fig7();
    assert_eq!(m.interval, 3136, "the paper's best-case beat");
    assert!(
        m.fill > 0 && m.fill < 2 * m.interval,
        "VGG-E Fig. 7 fill {} should be under two beats",
        m.fill
    );
}

#[test]
fn resnet18_none_plan_constants_survive_the_cluster_layer() {
    let m = resnet18_none();
    assert_eq!(m.interval, 12544, "ResNet-18 stem bottleneck (PR 3 golden)");
    assert_eq!(m.fill, 1956, "ResNet-18 critical-path fill (PR 3 golden)");
}

#[test]
fn sparse_singles_cost_exactly_the_fill_vgg() {
    // Deterministic one-request-at-a-time arrivals, spaced far beyond the
    // fill: every request must see latency == fill, nothing more.
    let m = vgg_e_fig7();
    let arrivals: Vec<u64> = (0..10).map(|i| i * 100_000).collect();
    let s = simulate(&m, &trace_cfg(arrivals));
    assert_eq!(s.offered, 10);
    assert_eq!(s.completed, 10);
    assert_eq!(s.rejected, 0);
    assert_eq!(s.latency.p50(), m.fill);
    assert_eq!(s.latency.max(), m.fill, "no queueing on an idle fleet");
    assert_eq!(s.queueing.max(), 0);
}

#[test]
fn sparse_singles_cost_exactly_the_fill_resnet() {
    let m = resnet18_none();
    let arrivals: Vec<u64> = (0..8).map(|i| i * 200_000).collect();
    let s = simulate(&m, &trace_cfg(arrivals));
    assert_eq!(s.completed, 8);
    assert_eq!(s.latency.p50(), 1956, "fill constant end-to-end");
    assert_eq!(s.latency.max(), 1956);
}

#[test]
fn saturating_burst_paces_at_the_interval_vgg() {
    // All requests arrive at cycle 0: completions must be spaced exactly
    // one 3136-cycle beat apart — request k completes at fill + k*3136.
    let m = vgg_e_fig7();
    let k = 12u64;
    let s = simulate(&m, &trace_cfg(vec![0; k as usize]));
    assert_eq!(s.completed, k);
    assert_eq!(s.latency.percentile(0.001), m.fill, "first request");
    assert_eq!(
        s.latency.max(),
        m.fill + (k - 1) * 3136,
        "last request paid k-1 beats of pipeline backlog"
    );
    assert_eq!(s.drained_at, m.fill + (k - 1) * 3136);
    // Mean of fill + {0..k-1}*interval.
    let want_mean = m.fill as f64 + (k - 1) as f64 / 2.0 * 3136.0;
    assert!((s.latency.mean() - want_mean).abs() < 1e-9);
    // A saturating burst keeps the bottleneck stage busy back-to-back:
    // with fill < interval the span is exactly k reserved slots, so the
    // node reports 100% utilization — never more.
    assert!((s.node_utilization[0] - 1.0).abs() < 1e-12, "{}", s.node_utilization[0]);
}

#[test]
fn saturating_burst_paces_at_the_interval_resnet() {
    let m = resnet18_none();
    let k = 6u64;
    let s = simulate(&m, &trace_cfg(vec![0; k as usize]));
    assert_eq!(s.completed, k);
    assert_eq!(s.latency.max(), 1956 + (k - 1) * 12544);
    // Fill (1956) < interval (12544): the last completion lands before the
    // bottleneck frees its final slot. Utilization must still be exactly
    // 100% of the busy span, never above it.
    assert!(
        (s.node_utilization[0] - 1.0).abs() < 1e-12,
        "{}",
        s.node_utilization[0]
    );
}

#[test]
fn two_nodes_halve_the_backlog_pacing() {
    // The same saturating burst over 2 nodes (round-robin): each node
    // serves every other request, so request k completes at
    // fill + floor(k/2)*interval — the fleet-level pacing halves.
    let m = vgg_e_fig7();
    let k = 8u64;
    let mut cfg = trace_cfg(vec![0; k as usize]);
    cfg.nodes = 2;
    let s = simulate(&m, &cfg);
    assert_eq!(s.completed, k);
    assert_eq!(s.latency.max(), m.fill + (k / 2 - 1) * 3136);
    assert_eq!(s.drained_at, m.fill + (k / 2 - 1) * 3136);
}
