//! Golden pins for the topology layer (ISSUE 10).
//!
//! - `Mesh2D` must be byte-identical to the seed's hard-coded XY mesh: the
//!   routing functions are re-derived here from scratch (coordinate
//!   arithmetic only, no calls back into the crate's mesh code) and
//!   compared exhaustively.
//! - Every fabric must be deterministic: two identical synthetic runs
//!   produce identical stats.
//! - The torus/prism all-pairs mean hop distances are pinned to the values
//!   an independent reference implementation produced, and the torus must
//!   beat the mesh (the ISSUE 10 acceptance inequality).

use smart_pim::config::{NocKind, TopologyKind};
use smart_pim::noc::{run_synthetic, AnyTopology, Dir, Mesh2D, Pattern, SyntheticConfig};

/// Independently re-derived XY mesh math (deliberately NOT calling
/// `Mesh2D`): node id = `y * w + x`, route X-first then Y, Manhattan hops.
struct RefMesh {
    w: usize,
    h: usize,
}

impl RefMesh {
    fn xy(&self, n: usize) -> (isize, isize) {
        ((n % self.w) as isize, (n / self.w) as isize)
    }

    fn route(&self, node: usize, dst: usize) -> Dir {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        match () {
            _ if x < dx => Dir::East,
            _ if x > dx => Dir::West,
            _ if y < dy => Dir::South,
            _ if y > dy => Dir::North,
            _ => Dir::Local,
        }
    }

    fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.xy(a);
        let (bx, by) = self.xy(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as usize
    }

    fn straight_run(&self, node: usize, dst: usize) -> usize {
        let (x, y) = self.xy(node);
        let (dx, dy) = self.xy(dst);
        if x != dx {
            x.abs_diff(dx)
        } else {
            y.abs_diff(dy)
        }
    }

    fn neighbor(&self, node: usize, d: Dir) -> Option<usize> {
        let (x, y) = self.xy(node);
        let (nx, ny) = match d {
            Dir::East => (x + 1, y),
            Dir::West => (x - 1, y),
            Dir::South => (x, y + 1),
            Dir::North => (x, y - 1),
            Dir::Local => return None,
        };
        (nx >= 0 && (nx as usize) < self.w && ny >= 0 && (ny as usize) < self.h)
            .then(|| ny as usize * self.w + nx as usize)
    }
}

#[test]
fn mesh2d_matches_rederived_xy_math_exhaustively() {
    for (w, h) in [(8, 8), (16, 20), (1, 5), (5, 1), (3, 7)] {
        let mesh = Mesh2D::new(w, h);
        let reference = RefMesh { w, h };
        assert_eq!(mesh.nodes(), w * h);
        for src in 0..mesh.nodes() {
            for d in Dir::SIDES {
                assert_eq!(
                    mesh.neighbor(src, d),
                    reference.neighbor(src, d),
                    "{w}x{h} neighbor({src}, {d:?})"
                );
            }
            for dst in 0..mesh.nodes() {
                assert_eq!(
                    mesh.xy_route(src, dst),
                    reference.route(src, dst),
                    "{w}x{h} route({src}, {dst})"
                );
                assert_eq!(
                    mesh.hops(src, dst),
                    reference.hops(src, dst),
                    "{w}x{h} hops({src}, {dst})"
                );
                assert_eq!(
                    mesh.straight_run(src, dst),
                    reference.straight_run(src, dst),
                    "{w}x{h} straight_run({src}, {dst})"
                );
            }
        }
    }
}

/// All-pairs mean hop distance (ordered pairs, self excluded).
fn avg_hops(topo: &AnyTopology) -> f64 {
    let n = topo.nodes();
    let mut sum = 0u64;
    for a in 0..n {
        for b in 0..n {
            sum += topo.hops(a, b) as u64;
        }
    }
    sum as f64 / (n * (n - 1)) as f64
}

#[test]
fn all_pairs_hop_means_match_reference_implementation() {
    // Pinned against an independent (non-Rust) reference implementation of
    // all three fabrics, run exhaustively on these geometries.
    let pins = [
        (8, 8, [5.3333, 4.0635, 4.7222]),
        (16, 20, [12.0000, 9.0282, 10.7194]),
    ];
    for (w, h, want) in pins {
        for (tk, want) in TopologyKind::ALL.into_iter().zip(want) {
            let got = avg_hops(&AnyTopology::new(tk, w, h));
            assert!(
                (got - want).abs() < 5e-4,
                "{tk:?} {w}x{h}: avg hops {got:.4} != pinned {want:.4}"
            );
        }
    }
}

#[test]
fn torus_beats_mesh_on_average_hops() {
    // ISSUE 10 acceptance: torus average hop count < mesh average (uniform
    // random traffic samples src/dst uniformly, so the all-pairs mean is
    // exactly the expected per-packet distance).
    for (w, h) in [(8, 8), (16, 20), (4, 4), (2, 9)] {
        let mesh = avg_hops(&AnyTopology::new(TopologyKind::Mesh, w, h));
        let torus = avg_hops(&AnyTopology::new(TopologyKind::Torus, w, h));
        assert!(torus < mesh, "{w}x{h}: torus {torus:.4} >= mesh {mesh:.4}");
    }
}

#[test]
fn synthetic_runs_are_deterministic_on_every_topology() {
    let cfg = SyntheticConfig {
        pattern: Pattern::UniformRandom,
        injection_rate: 0.05,
        warmup: 200,
        measure: 800,
        drain: 4_000,
        seed: 0x70D0,
        ..Default::default()
    };
    for tk in TopologyKind::ALL {
        let topo = AnyTopology::new(tk, 8, 8);
        for kind in [NocKind::Wormhole, NocKind::Smart, NocKind::Ideal] {
            let a = run_synthetic(kind, topo, &cfg, 14);
            let b = run_synthetic(kind, topo, &cfg, 14);
            assert_eq!(a, b, "{tk:?}/{kind:?} not deterministic");
            assert!(a.completed > 0, "{tk:?}/{kind:?} delivered nothing");
        }
    }
}
