//! Property tests over the multi-tenant cluster layer:
//!
//! 1. **per-tenant conservation**: for every residency x route x arrival
//!    pattern x tenant mix, each tenant's arrivals = completions +
//!    rejections at drain, swaps == routing misses (reprogram-on-miss
//!    swaps on exactly the misses), and the per-tenant latency sum
//!    decomposes exactly into queueing + swap + backlog + fill;
//! 2. **partition is swap-free**: dedicated partitions never reprogram,
//!    and the weighted apportionment covers the fleet with >= 1 node per
//!    tenant;
//! 3. **a lone tenant never swaps** under reprogram-on-miss (its weights
//!    are resident everywhere from the start);
//! 4. **fleet energy identity**: dynamic + idle + weight-writes sums
//!    exactly (bit-equal, one accumulation order), and joules/image is
//!    monotone non-increasing in fleet size for the pinned saturated
//!    regime (write storms amortize over proportionally more
//!    completions);
//! 5. **determinism + routing parity**: identical seeds give bit-identical
//!    per-tenant stats, and the indexed router matches the linear-scan
//!    reference exactly on random scenarios.

use smart_pim::cluster::{
    partition_counts, simulate_tenants, ArrivalProcess, EnergyProfile, MixMode, Residency,
    RouteImpl, TenantClusterStats, TenantConfig, TenantRoute, TenantWorkload,
};
use smart_pim::power::WriteCost;
use smart_pim::prop_assert;
use smart_pim::util::prop::{check, Config};

fn wc(latency_cycles: u64, energy_j: f64) -> WriteCost {
    WriteCost {
        rows: 0,
        latency_cycles,
        energy_j,
    }
}

/// The two-tenant grid fixture: a fast cheap-to-program model and a slow
/// expensive one, weighted 2:1.
fn pair() -> Vec<TenantWorkload> {
    vec![
        TenantWorkload::new("a", 2.0, 100, 500, wc(5_000, 0.5)),
        TenantWorkload::new("b", 1.0, 300, 700, wc(8_000, 0.25)),
    ]
}

/// Bit-exact equality over every observable of a tenant run.
fn identical(a: &TenantClusterStats, b: &TenantClusterStats) -> bool {
    a.offered == b.offered
        && a.completed == b.completed
        && a.rejected == b.rejected
        && a.horizon_cycles == b.horizon_cycles
        && a.drained_at == b.drained_at
        && a.events_processed == b.events_processed
        && a.peak_calendar_depth == b.peak_calendar_depth
        && a.node_utilization == b.node_utilization
        && a.per_node_swaps == b.per_node_swaps
        && a.per_node_injected == b.per_node_injected
        && a.partition == b.partition
        && a.tenants.len() == b.tenants.len()
        && a.tenants.iter().zip(&b.tenants).all(|(x, y)| {
            x.offered == y.offered
                && x.completed == y.completed
                && x.rejected == y.rejected
                && x.swaps == y.swaps
                && x.misses == y.misses
                && x.swap_energy_j == y.swap_energy_j
                && x.total_latency_cycles == y.total_latency_cycles
                && x.queueing_cycles == y.queueing_cycles
                && x.swap_cycles == y.swap_cycles
                && x.backlog_cycles == y.backlog_cycles
                && x.latency.mean() == y.latency.mean()
                && x.latency.p50() == y.latency.p50()
                && x.latency.p99() == y.latency.p99()
                && x.latency.max() == y.latency.max()
        })
}

#[test]
fn per_tenant_conservation_across_the_policy_grid() {
    let tenants = pair();
    let patterns = [
        ArrivalProcess::Poisson,
        ArrivalProcess::Bursty {
            on_mean: 20_000,
            off_mean: 20_000,
        },
        ArrivalProcess::Diurnal { period: 100_000 },
    ];
    let mixes = [
        MixMode::Static,
        MixMode::Alternate,
        MixMode::Diurnal { period: 50_000 },
    ];
    for residency in [Residency::Reprogram, Residency::Partition] {
        for route in [TenantRoute::RoundRobin, TenantRoute::ShortestQueue] {
            for pattern in &patterns {
                for mix in mixes {
                    let s = simulate_tenants(
                        &tenants,
                        &TenantConfig {
                            nodes: 4,
                            residency,
                            route,
                            pattern: pattern.clone(),
                            rate_per_cycle: 0.03,
                            mix,
                            max_queue: 8,
                            horizon_cycles: 150_000,
                            seed: 11,
                            ..TenantConfig::default()
                        },
                    )
                    .unwrap();
                    let ctx = format!(
                        "{}/{}/{}/{}",
                        residency.name(),
                        route.name(),
                        pattern.name(),
                        mix.name()
                    );
                    assert!(s.offered > 0, "{ctx}: no arrivals generated");
                    for ts in &s.tenants {
                        assert_eq!(
                            ts.offered,
                            ts.completed + ts.rejected,
                            "{ctx}: tenant {} leaks requests",
                            ts.name
                        );
                        assert_eq!(
                            ts.swaps, ts.misses,
                            "{ctx}: tenant {} swaps != misses",
                            ts.name
                        );
                        assert_eq!(
                            ts.total_latency_cycles,
                            ts.queueing_cycles
                                + ts.swap_cycles
                                + ts.backlog_cycles
                                + ts.completed * ts.fill,
                            "{ctx}: tenant {} latency decomposition broke",
                            ts.name
                        );
                        if residency == Residency::Partition {
                            assert_eq!(ts.swaps, 0, "{ctx}: partition swapped");
                        }
                    }
                    let per: u64 = s.tenants.iter().map(|t| t.offered).sum();
                    assert_eq!(s.offered, per, "{ctx}: fleet offered != tenant sum");
                    let per: u64 = s.tenants.iter().map(|t| t.completed).sum();
                    assert_eq!(s.completed, per, "{ctx}");
                    let per: u64 = s.tenants.iter().map(|t| t.rejected).sum();
                    assert_eq!(s.rejected, per, "{ctx}");
                    assert_eq!(
                        s.per_node_swaps.iter().sum::<u64>(),
                        s.total_swaps(),
                        "{ctx}: node swap counts != tenant swap counts"
                    );
                }
            }
        }
    }
}

#[test]
fn single_tenant_reprogram_never_swaps() {
    let one = vec![TenantWorkload::new("a", 1.0, 100, 500, wc(1_000, 0.5))];
    let s = simulate_tenants(
        &one,
        &TenantConfig {
            rate_per_cycle: 0.02,
            max_queue: 8,
            horizon_cycles: 200_000,
            seed: 7,
            ..TenantConfig::default()
        },
    )
    .unwrap();
    assert!(s.offered > 0);
    assert_eq!(s.tenants[0].swaps, 0, "lone tenant should never swap");
    assert_eq!(s.tenants[0].misses, 0);
    assert_eq!(s.total_swap_energy_j(), 0.0);
    assert_eq!(s.offered, s.completed + s.rejected);
}

/// A priced synthetic tenant for the energy properties (306 ns cycle, the
/// paper node's).
fn priced(
    name: &str,
    interval: u64,
    fill: u64,
    write: WriteCost,
    image_mj: f64,
    ops: u64,
) -> TenantWorkload {
    let mut t = TenantWorkload::new(name, 1.0, interval, fill, write);
    t.energy = Some(EnergyProfile {
        image_mj,
        active_power_w: 0.0,
        idle_power_w: 2.0,
        ops_per_image: ops,
        logical_cycle_ns: 306.0,
    });
    t
}

#[test]
fn fleet_energy_identity_and_monotone_joules_per_image() {
    // Pinned saturated regime (mirror-derived): heavy write costs and a
    // tight admission bound, so swap energy dominates at small fleets and
    // amortizes away as each tenant's node share grows.
    let tenants = vec![
        priced("a", 100, 500, wc(50_000, 0.5), 10.0, 1_000),
        priced("b", 300, 700, wc(80_000, 0.25), 20.0, 2_000),
    ];
    let mut prev = f64::INFINITY;
    for nodes in [2usize, 4, 8, 16] {
        let s = simulate_tenants(
            &tenants,
            &TenantConfig {
                nodes,
                residency: Residency::Reprogram,
                route: TenantRoute::ShortestQueue,
                rate_per_cycle: 0.05,
                mix: MixMode::Alternate,
                max_queue: 32,
                fixed_requests: Some(8_000),
                seed: 42,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        let e = s.energy.as_ref().expect("every tenant is priced");
        // Exact by construction: one accumulation order, no re-summation.
        assert_eq!(e.total_j(), e.dynamic_j + e.idle_j + e.weight_writes_j);
        assert_eq!(
            e.weight_writes_j,
            s.total_swap_energy_j(),
            "fleet write energy != tenant swap energy at {nodes} nodes"
        );
        assert!(s.completed > 0, "{nodes} nodes completed nothing");
        let j = e.joules_per_image();
        assert!(
            j <= prev,
            "joules/image rose from {prev} to {j} at {nodes} nodes"
        );
        prev = j;
    }
}

#[test]
fn energy_absent_unless_every_tenant_is_priced() {
    // One priced + one unpriced tenant: the fleet split would be
    // meaningless, so no energy is reported.
    let tenants = vec![
        priced("a", 100, 500, wc(1_000, 0.5), 10.0, 1_000),
        TenantWorkload::new("b", 1.0, 300, 700, wc(2_000, 0.25)),
    ];
    let s = simulate_tenants(
        &tenants,
        &TenantConfig {
            horizon_cycles: 50_000,
            rate_per_cycle: 0.01,
            ..TenantConfig::default()
        },
    )
    .unwrap();
    assert!(s.energy.is_none());
}

#[test]
fn partition_counts_cover_the_fleet() {
    check("partition-apportionment", &Config::default(), |g| {
        let t = 1 + g.rng.below(6) as usize;
        let weights: Vec<f64> = (0..t)
            .map(|_| 1.0 + g.rng.below(100) as f64 / 10.0)
            .collect();
        let nodes = t + g.rng.below(20) as usize;
        let counts = partition_counts(nodes, &weights)?;
        prop_assert!(
            counts.iter().sum::<usize>() == nodes,
            "counts {counts:?} do not sum to {nodes}"
        );
        prop_assert!(
            counts.iter().all(|&c| c >= 1),
            "a tenant got zero nodes: {counts:?}"
        );
        prop_assert!(
            partition_counts(t - 1, &weights).is_err() || t == 1,
            "undersized fleet must be rejected"
        );
        Ok(())
    });
}

#[test]
fn determinism_and_route_parity_on_random_scenarios() {
    let tenants = pair();
    check("tenant-determinism-parity", &Config::default(), |g| {
        let nodes = 2 + g.rng.below(5) as usize;
        let residency = if g.rng.below(2) == 0 {
            Residency::Partition
        } else {
            Residency::Reprogram
        };
        let route = if g.rng.below(2) == 0 {
            TenantRoute::RoundRobin
        } else {
            TenantRoute::ShortestQueue
        };
        let cfg = TenantConfig {
            nodes,
            residency,
            route,
            rate_per_cycle: 0.005 + g.rng.below(30) as f64 / 1_000.0,
            mix: MixMode::Diurnal { period: 40_000 },
            max_queue: 1 + g.rng.below(8),
            horizon_cycles: 60_000,
            seed: g.rng.next_u64(),
            ..TenantConfig::default()
        };
        let a = simulate_tenants(&tenants, &cfg)?;
        let b = simulate_tenants(&tenants, &cfg)?;
        prop_assert!(
            identical(&a, &b),
            "same seed diverged ({} {} {} nodes)",
            residency.name(),
            route.name(),
            nodes
        );
        let scan = TenantConfig {
            route_impl: RouteImpl::LinearScan,
            ..cfg
        };
        let c = simulate_tenants(&tenants, &scan)?;
        prop_assert!(
            identical(&a, &c),
            "indexed and linear-scan routers diverged ({} {} {} nodes)",
            residency.name(),
            route.name(),
            nodes
        );
        Ok(())
    });
}
