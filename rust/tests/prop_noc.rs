//! Property tests over the NoC simulator (hand-rolled harness in
//! `util::prop` — the vendored crate set has no proptest).

use smart_pim::noc::{Mesh, Network};
use smart_pim::util::prop::{check, Config, Gen};
use smart_pim::{prop_assert, prop_assert_eq};

fn random_net(g: &mut Gen) -> (Network, Mesh) {
    let w = 2 + g.rng.below_usize(7); // 2..8
    let h = 2 + g.rng.below_usize(7);
    let mesh = Mesh::new(w, h);
    let hpc = 1 + g.rng.below_usize(14);
    let rl = 1 + g.rng.below(4);
    let depth = 1 + g.rng.below_usize(4);
    (Network::new(mesh, hpc, rl, depth), mesh)
}

fn random_packets(g: &mut Gen, net: &mut Network, mesh: Mesh) -> Vec<u32> {
    let n_pkts = g.scaled(120);
    let mut ids = Vec::new();
    for _ in 0..n_pkts {
        let src = g.rng.below_usize(mesh.nodes());
        let dst = g.rng.below_usize(mesh.nodes());
        if src == dst {
            continue;
        }
        let len = 1 + g.rng.below(6) as u16;
        ids.push(net.enqueue(src, dst, len));
        // Interleave injection with stepping to vary occupancy.
        if g.rng.chance(0.5) {
            net.step();
        }
    }
    ids
}

#[test]
fn every_packet_delivered_exactly_once() {
    check("noc-delivery", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        let cycles = net.drain(2_000_000);
        prop_assert!(
            net.quiescent(),
            "network not quiescent after {cycles} cycles ({} flits stuck)",
            net.in_flight_flits()
        );
        for id in ids {
            let p = net.table.get(id);
            prop_assert!(p.is_done(), "packet {id} undelivered");
            prop_assert_eq!(p.delivered, p.len);
        }
        Ok(())
    });
}

#[test]
fn stop_lists_are_minimal_xy_routes() {
    check("noc-minimal-routes", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            if !p.is_done() {
                continue;
            }
            prop_assert_eq!(p.stops[0], p.src);
            prop_assert_eq!(*p.stops.last().unwrap(), p.dst);
            let mut remaining = mesh.hops(p.src as usize, p.dst as usize);
            for w in p.stops.windows(2) {
                let step = mesh.hops(w[0] as usize, w[1] as usize);
                prop_assert!(step >= 1, "zero-length segment in {:?}", p.stops);
                let after = mesh.hops(w[1] as usize, p.dst as usize);
                prop_assert_eq!(after + step, remaining);
                remaining = after;
            }
            prop_assert_eq!(remaining, 0usize);
        }
        Ok(())
    });
}

#[test]
fn segments_respect_hpc_max() {
    check("noc-hpc-bound", &Config::default(), |g| {
        let hpc = 1 + g.rng.below_usize(6);
        let mesh = Mesh::new(8, 8);
        let mut net = Network::new(mesh, hpc, 1, 4);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            for w in p.stops.windows(2) {
                let step = mesh.hops(w[0] as usize, w[1] as usize);
                prop_assert!(
                    step <= hpc,
                    "segment of {step} hops exceeds HPC_max {hpc}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn latency_at_least_distance_plus_serialization() {
    check("noc-latency-bound", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            if !p.is_done() {
                continue;
            }
            // Tail must at minimum traverse the stops and serialize.
            let min = (p.stops.len() - 1) as u64 + (p.len - 1) as u64;
            prop_assert!(
                p.net_latency() >= min,
                "packet {id}: latency {} < floor {min}",
                p.net_latency()
            );
        }
        Ok(())
    });
}

#[test]
fn conservation_flits_in_equals_out() {
    check("noc-conservation", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        prop_assert!(net.quiescent(), "not quiescent");
        prop_assert_eq!(net.flits_injected, net.flits_ejected);
        Ok(())
    });
}
