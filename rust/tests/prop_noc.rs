//! Property tests over the NoC simulator (hand-rolled harness in
//! `util::prop` — the vendored crate set has no proptest). The mesh-only
//! properties above the fold are the seed set; the topology-generic block
//! at the bottom replays conservation / minimality / latency-ordering on
//! the torus and Parallel-Prism fabrics too (ISSUE 10).

use smart_pim::config::{NocKind, TopologyKind};
use smart_pim::noc::{build_backend, run_flows, AnyTopology, Flow, Mesh, Network, Topology, Torus2D};
use smart_pim::util::prop::{check, Config, Gen};
use smart_pim::{prop_assert, prop_assert_eq};

fn random_net(g: &mut Gen) -> (Network, Mesh) {
    let w = 2 + g.rng.below_usize(7); // 2..8
    let h = 2 + g.rng.below_usize(7);
    let mesh = Mesh::new(w, h);
    let hpc = 1 + g.rng.below_usize(14);
    let rl = 1 + g.rng.below(4);
    let depth = 1 + g.rng.below_usize(4);
    (Network::new(mesh, hpc, rl, depth), mesh)
}

fn random_packets(g: &mut Gen, net: &mut Network, mesh: Mesh) -> Vec<u32> {
    let n_pkts = g.scaled(120);
    let mut ids = Vec::new();
    for _ in 0..n_pkts {
        let src = g.rng.below_usize(mesh.nodes());
        let dst = g.rng.below_usize(mesh.nodes());
        if src == dst {
            continue;
        }
        let len = 1 + g.rng.below(6) as u16;
        ids.push(net.enqueue(src, dst, len));
        // Interleave injection with stepping to vary occupancy.
        if g.rng.chance(0.5) {
            net.step();
        }
    }
    ids
}

#[test]
fn every_packet_delivered_exactly_once() {
    check("noc-delivery", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        let cycles = net.drain(2_000_000);
        prop_assert!(
            net.quiescent(),
            "network not quiescent after {cycles} cycles ({} flits stuck)",
            net.in_flight_flits()
        );
        for id in ids {
            let p = net.table.get(id);
            prop_assert!(p.is_done(), "packet {id} undelivered");
            prop_assert_eq!(p.delivered, p.len);
        }
        Ok(())
    });
}

#[test]
fn stop_lists_are_minimal_xy_routes() {
    check("noc-minimal-routes", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            if !p.is_done() {
                continue;
            }
            prop_assert_eq!(p.stops[0], p.src);
            prop_assert_eq!(*p.stops.last().unwrap(), p.dst);
            let mut remaining = mesh.hops(p.src as usize, p.dst as usize);
            for w in p.stops.windows(2) {
                let step = mesh.hops(w[0] as usize, w[1] as usize);
                prop_assert!(step >= 1, "zero-length segment in {:?}", p.stops);
                let after = mesh.hops(w[1] as usize, p.dst as usize);
                prop_assert_eq!(after + step, remaining);
                remaining = after;
            }
            prop_assert_eq!(remaining, 0usize);
        }
        Ok(())
    });
}

#[test]
fn segments_respect_hpc_max() {
    check("noc-hpc-bound", &Config::default(), |g| {
        let hpc = 1 + g.rng.below_usize(6);
        let mesh = Mesh::new(8, 8);
        let mut net = Network::new(mesh, hpc, 1, 4);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            for w in p.stops.windows(2) {
                let step = mesh.hops(w[0] as usize, w[1] as usize);
                prop_assert!(
                    step <= hpc,
                    "segment of {step} hops exceeds HPC_max {hpc}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn latency_at_least_distance_plus_serialization() {
    check("noc-latency-bound", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        let ids = random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        for id in ids {
            let p = net.table.get(id);
            if !p.is_done() {
                continue;
            }
            // Tail must at minimum traverse the stops and serialize.
            let min = (p.stops.len() - 1) as u64 + (p.len - 1) as u64;
            prop_assert!(
                p.net_latency() >= min,
                "packet {id}: latency {} < floor {min}",
                p.net_latency()
            );
        }
        Ok(())
    });
}

#[test]
fn conservation_flits_in_equals_out() {
    check("noc-conservation", &Config::default(), |g| {
        let (mut net, mesh) = random_net(g);
        random_packets(g, &mut net, mesh);
        net.drain(2_000_000);
        prop_assert!(net.quiescent(), "not quiescent");
        prop_assert_eq!(net.flits_injected, net.flits_ejected);
        Ok(())
    });
}

/// Draw one random flow set on the 8x8 mesh at a load far below every
/// backend's saturation point (so queueing noise cannot flip orderings).
fn random_flows(g: &mut Gen) -> Vec<Flow> {
    let mesh = Mesh::new(8, 8);
    let n = 1 + g.rng.below_usize(6);
    (0..n)
        .filter_map(|_| {
            let src = g.rng.below_usize(mesh.nodes());
            let dst = g.rng.below_usize(mesh.nodes());
            (src != dst).then(|| Flow {
                src,
                dst,
                packets_per_cycle: 0.002 + g.rng.next_f64() * 0.01,
                packet_len: 1 + g.rng.below(4) as u16,
            })
        })
        .collect()
}

#[test]
fn latency_order_ideal_smart_wormhole_on_identical_flows() {
    // On identical flows and seeds, mean packet latency must obey
    // ideal <= SMART <= wormhole: the ideal fabric removes all in-network
    // contention, and SMART only ever removes router-pipeline stops
    // relative to the same-parameter wormhole engine.
    check("noc-latency-order", &Config::default(), |g| {
        let flows = random_flows(g);
        if flows.is_empty() {
            return Ok(());
        }
        let mesh = Mesh::new(8, 8);
        // Identical router parameters for both mesh kinds: the comparison
        // isolates the flow-control mechanism itself.
        let run = |kind| run_flows(kind, mesh, &flows, 200, 2_000, 40_000, 14, 1, 4);
        let w = run(NocKind::Wormhole);
        let s = run(NocKind::Smart);
        let i = run(NocKind::Ideal);
        prop_assert_eq!(w.dropped, 0u64);
        prop_assert_eq!(s.dropped, 0u64);
        prop_assert_eq!(i.dropped, 0u64);
        prop_assert!(
            i.avg_net_latency <= s.avg_net_latency + 1e-9,
            "ideal {} > smart {} (flows {:?})",
            i.avg_net_latency,
            s.avg_net_latency,
            flows
        );
        prop_assert!(
            s.avg_net_latency <= w.avg_net_latency + 1e-9,
            "smart {} > wormhole {} (flows {:?})",
            s.avg_net_latency,
            w.avg_net_latency,
            flows
        );
        Ok(())
    });
}

#[test]
fn conservation_holds_for_every_backend() {
    // Flit conservation (injected == ejected after drain) through the
    // NocBackend trait, with the identical packet list replayed into all
    // three backends.
    check("backend-conservation", &Config::default(), |g| {
        let w = 2 + g.rng.below_usize(7);
        let h = 2 + g.rng.below_usize(7);
        let mesh = Mesh::new(w, h);
        let hpc = 1 + g.rng.below_usize(14);
        let rl = 1 + g.rng.below(4);
        let depth = 1 + g.rng.below_usize(4);
        let n_pkts = g.scaled(80);
        // One packet list, replayed identically into each backend.
        let pkts: Vec<(usize, usize, u16, bool)> = (0..n_pkts)
            .map(|_| {
                (
                    g.rng.below_usize(mesh.nodes()),
                    g.rng.below_usize(mesh.nodes()),
                    1 + g.rng.below(6) as u16,
                    g.rng.chance(0.5),
                )
            })
            .collect();
        for kind in NocKind::ALL {
            let mut net = build_backend(kind, mesh, hpc, rl, depth);
            let mut offered = 0u64;
            for &(src, dst, len, step) in &pkts {
                if src != dst {
                    net.enqueue(src, dst, len);
                    offered += len as u64;
                }
                if step {
                    net.step();
                }
            }
            let cycles = net.drain(2_000_000);
            prop_assert!(
                net.quiescent(),
                "{kind:?} not quiescent after {cycles} cycles"
            );
            prop_assert_eq!(net.flits_injected(), net.flits_ejected());
            prop_assert_eq!(net.flits_ejected(), offered);
        }
        Ok(())
    });
}

// ---- topology-generic properties (ISSUE 10) ----------------------------

/// Draw a random fabric: random kind on random dims (>= 2x2 so every node
/// has neighbors in both dimensions).
fn random_topo(g: &mut Gen) -> AnyTopology {
    let w = 2 + g.rng.below_usize(7);
    let h = 2 + g.rng.below_usize(7);
    let kind = TopologyKind::ALL[g.rng.below_usize(TopologyKind::ALL.len())];
    AnyTopology::new(kind, w, h)
}

/// Inject random packets into `net` (a fabric with `nodes` endpoints),
/// interleaving injection with stepping to vary occupancy.
fn random_packets_on(g: &mut Gen, net: &mut Network, nodes: usize) -> Vec<u32> {
    let n_pkts = g.scaled(120);
    let mut ids = Vec::new();
    for _ in 0..n_pkts {
        let src = g.rng.below_usize(nodes);
        let dst = g.rng.below_usize(nodes);
        if src == dst {
            continue;
        }
        let len = 1 + g.rng.below(6) as u16;
        ids.push(net.enqueue(src, dst, len));
        if g.rng.chance(0.5) {
            net.step();
        }
    }
    ids
}

#[test]
fn delivery_and_minimal_routes_on_every_topology() {
    // Conservation, exactly-once delivery, and stop-list minimality under
    // the fabric's own hop metric — the same invariants the mesh tests pin,
    // replayed on a random topology each case.
    check("topo-delivery-minimality", &Config::default(), |g| {
        let topo = random_topo(g);
        let hpc = 1 + g.rng.below_usize(14);
        let rl = 1 + g.rng.below(4);
        let depth = 1 + g.rng.below_usize(4);
        let mut net = Network::new(topo, hpc, rl, depth);
        let ids = random_packets_on(g, &mut net, topo.nodes());
        let cycles = net.drain(2_000_000);
        prop_assert!(
            net.quiescent(),
            "{topo:?} not quiescent after {cycles} cycles"
        );
        prop_assert_eq!(net.flits_injected, net.flits_ejected);
        for id in ids {
            let p = net.table.get(id);
            prop_assert!(p.is_done(), "packet {id} undelivered on {topo:?}");
            prop_assert_eq!(p.delivered, p.len);
            prop_assert_eq!(p.stops[0], p.src);
            prop_assert_eq!(*p.stops.last().unwrap(), p.dst);
            let mut remaining = topo.hops(p.src as usize, p.dst as usize);
            for w in p.stops.windows(2) {
                let step = topo.hops(w[0] as usize, w[1] as usize);
                prop_assert!(step >= 1, "zero-length segment in {:?}", p.stops);
                let after = topo.hops(w[1] as usize, p.dst as usize);
                prop_assert_eq!(after + step, remaining);
                remaining = after;
            }
            prop_assert_eq!(remaining, 0usize);
        }
        Ok(())
    });
}

#[test]
fn latency_order_holds_on_every_topology() {
    // ideal <= SMART <= wormhole is a flow-control property, not a mesh
    // property: it must survive the fabric swap.
    check("topo-latency-order", &Config::default(), |g| {
        let flows = random_flows(g);
        if flows.is_empty() {
            return Ok(());
        }
        for tk in TopologyKind::ALL {
            let topo = AnyTopology::new(tk, 8, 8);
            let run = |kind| run_flows(kind, topo, &flows, 200, 2_000, 40_000, 14, 1, 4);
            let w = run(NocKind::Wormhole);
            let s = run(NocKind::Smart);
            let i = run(NocKind::Ideal);
            prop_assert_eq!(w.dropped, 0u64);
            prop_assert_eq!(s.dropped, 0u64);
            prop_assert_eq!(i.dropped, 0u64);
            prop_assert!(
                i.avg_net_latency <= s.avg_net_latency + 1e-9,
                "{tk:?}: ideal {} > smart {} (flows {:?})",
                i.avg_net_latency,
                s.avg_net_latency,
                flows
            );
            prop_assert!(
                s.avg_net_latency <= w.avg_net_latency + 1e-9,
                "{tk:?}: smart {} > wormhole {} (flows {:?})",
                s.avg_net_latency,
                w.avg_net_latency,
                flows
            );
        }
        Ok(())
    });
}

#[test]
fn conservation_holds_for_every_backend_on_every_topology() {
    // The backend-trait conservation property, fabric-generalized: one
    // packet list replayed into all three backends on a random topology.
    check("topo-backend-conservation", &Config::default(), |g| {
        let topo = random_topo(g);
        let hpc = 1 + g.rng.below_usize(14);
        let rl = 1 + g.rng.below(4);
        let depth = 1 + g.rng.below_usize(4);
        let n_pkts = g.scaled(80);
        let pkts: Vec<(usize, usize, u16, bool)> = (0..n_pkts)
            .map(|_| {
                (
                    g.rng.below_usize(topo.nodes()),
                    g.rng.below_usize(topo.nodes()),
                    1 + g.rng.below(6) as u16,
                    g.rng.chance(0.5),
                )
            })
            .collect();
        for kind in NocKind::ALL {
            let mut net = build_backend(kind, topo, hpc, rl, depth);
            let mut offered = 0u64;
            for &(src, dst, len, step) in &pkts {
                if src != dst {
                    net.enqueue(src, dst, len);
                    offered += len as u64;
                }
                if step {
                    net.step();
                }
            }
            let cycles = net.drain(2_000_000);
            prop_assert!(
                net.quiescent(),
                "{kind:?} on {topo:?} not quiescent after {cycles} cycles"
            );
            prop_assert_eq!(net.flits_injected(), net.flits_ejected());
            prop_assert_eq!(net.flits_ejected(), offered);
        }
        Ok(())
    });
}

#[test]
fn torus_hops_symmetric_and_never_longer_than_mesh() {
    // Wrap links only ever shorten routes, and the min-wrap metric is
    // symmetric even though the routing function breaks direction ties.
    check("torus-hop-metric", &Config::default(), |g| {
        let w = 1 + g.rng.below_usize(8);
        let h = 1 + g.rng.below_usize(8);
        let torus = Torus2D::new(w, h);
        let mesh = Mesh::new(w, h);
        for a in 0..torus.nodes() {
            for b in 0..torus.nodes() {
                let t = torus.hops(a, b);
                prop_assert!(
                    t == torus.hops(b, a),
                    "torus {w}x{h}: d({a},{b}) != d({b},{a})"
                );
                prop_assert!(
                    t <= mesh.hops(a, b),
                    "torus {w}x{h}: d({a},{b}) = {t} > mesh {}",
                    mesh.hops(a, b)
                );
            }
        }
        Ok(())
    });
}
