//! Integration: the NoC simulator end-to-end — the Fig. 10/11 saturation
//! shapes and cross-flow-control comparisons on the 8x8 synthetic mesh.

use smart_pim::config::NocKind;
use smart_pim::noc::{run_flows, run_synthetic, Flow, Mesh, Pattern, SyntheticConfig};

fn cfg(pattern: Pattern, rate: f64) -> SyntheticConfig {
    SyntheticConfig {
        pattern,
        injection_rate: rate,
        packet_len: 4,
        warmup: 800,
        measure: 3_000,
        drain: 10_000,
        seed: 0xBEEF,
        ..Default::default()
    }
}

#[test]
fn fig10_wormhole_saturates_near_paper_point() {
    // Paper: wormhole saturates ~0.05 on uniform random.
    let mesh = Mesh::new(8, 8);
    let low = run_synthetic(NocKind::Wormhole, mesh, &cfg(Pattern::UniformRandom, 0.02), 14);
    let high = run_synthetic(NocKind::Wormhole, mesh, &cfg(Pattern::UniformRandom, 0.12), 14);
    assert!(!low.saturated(), "{low:?}");
    assert!(high.saturated(), "{high:?}");
}

#[test]
fn fig10_smart_saturates_much_later() {
    // Paper: SMART saturates ~0.25 on uniform random.
    let mesh = Mesh::new(8, 8);
    let mid = run_synthetic(NocKind::Smart, mesh, &cfg(Pattern::UniformRandom, 0.2), 14);
    let high = run_synthetic(NocKind::Smart, mesh, &cfg(Pattern::UniformRandom, 0.45), 14);
    assert!(!mid.saturated(), "{mid:?}");
    assert!(high.saturated(), "{high:?}");
}

#[test]
fn fig10_neighbor_is_the_easy_pattern() {
    // Paper: neighbor saturates at 0.2 (wormhole) / 0.8 (SMART).
    let mesh = Mesh::new(8, 8);
    let w = run_synthetic(NocKind::Wormhole, mesh, &cfg(Pattern::Neighbor, 0.12), 14);
    assert!(!w.saturated(), "{w:?}");
    let s = run_synthetic(NocKind::Smart, mesh, &cfg(Pattern::Neighbor, 0.7), 14);
    assert!(!s.saturated(), "{s:?}");
}

#[test]
fn fig11_reception_saturates_with_pattern_ordering() {
    // Paper Fig. 11: saturated reception — neighbor >> uniform > bit_compl.
    let mesh = Mesh::new(8, 8);
    let at = |p: Pattern| {
        run_synthetic(NocKind::Smart, mesh, &cfg(p, 0.9), 14).reception_rate
    };
    let n = at(Pattern::Neighbor);
    let u = at(Pattern::UniformRandom);
    let b = at(Pattern::BitComplement);
    assert!(n > u, "neighbor {n} !> uniform {u}");
    assert!(u > b, "uniform {u} !> bit_complement {b}");
}

#[test]
fn all_patterns_all_kinds_deliver_at_low_load() {
    let mesh = Mesh::new(8, 8);
    for pattern in Pattern::ALL {
        for kind in [NocKind::Wormhole, NocKind::Smart, NocKind::Ideal] {
            let s = run_synthetic(kind, mesh, &cfg(pattern, 0.01), 14);
            assert_eq!(
                s.dropped,
                0,
                "{kind:?}/{} dropped {}",
                pattern.name(),
                s.dropped
            );
            assert!(s.completed > 0, "{kind:?}/{}", pattern.name());
        }
    }
}

#[test]
fn hpc_max_monotone_latency() {
    // Longer bypass runs can only help zero-load latency.
    let mesh = Mesh::new(8, 8);
    let lat = |hpc| {
        run_synthetic(NocKind::Smart, mesh, &cfg(Pattern::BitComplement, 0.02), hpc)
            .avg_net_latency
    };
    let l1 = lat(1);
    let l4 = lat(4);
    let l14 = lat(14);
    assert!(l4 < l1, "hpc4 {l4} !< hpc1 {l1}");
    assert!(l14 <= l4 + 1.0, "hpc14 {l14} > hpc4 {l4}");
}

#[test]
fn smart_with_hpc1_matches_wormhole_engine() {
    // SMART degenerates to wormhole when HPC_max = 1 and the router
    // pipeline matches.
    let mesh = Mesh::new(8, 8);
    let mut c = cfg(Pattern::Transpose, 0.05);
    c.smart_router = c.wormhole_router;
    let s = run_synthetic(NocKind::Smart, mesh, &c, 1);
    let w = run_synthetic(NocKind::Wormhole, mesh, &c, 1);
    assert!(
        (s.avg_net_latency - w.avg_net_latency).abs() < 1e-9,
        "smart@hpc1 {} != wormhole {}",
        s.avg_net_latency,
        w.avg_net_latency
    );
}

#[test]
fn flow_traffic_latency_reflects_distance() {
    let mesh = Mesh::new(8, 8);
    let near = vec![Flow {
        src: 0,
        dst: 1,
        packets_per_cycle: 0.02,
        packet_len: 4,
    }];
    let far = vec![Flow {
        src: 0,
        dst: 63,
        packets_per_cycle: 0.02,
        packet_len: 4,
    }];
    let a = run_flows(NocKind::Wormhole, mesh, &near, 200, 2_000, 5_000, 14, 4, 1);
    let b = run_flows(NocKind::Wormhole, mesh, &far, 200, 2_000, 5_000, 14, 4, 1);
    assert!(
        b.avg_net_latency > a.avg_net_latency + 10.0,
        "far {} !>> near {}",
        b.avg_net_latency,
        a.avg_net_latency
    );
}

#[test]
fn ideal_latency_is_serialization_only() {
    let mesh = Mesh::new(8, 8);
    let s = run_synthetic(NocKind::Ideal, mesh, &cfg(Pattern::UniformRandom, 0.05), 14);
    // One hop + 4-flit serialization: ~4-6 cycles at low load.
    assert!(
        (4.0..8.0).contains(&s.avg_net_latency),
        "ideal latency {}",
        s.avg_net_latency
    );
}
