//! End-to-end: the serving coordinator (batcher + worker thread + PJRT
//! executable) under a synthetic request stream — the full L3 request path.
//! Skips when artifacts are absent.

use std::path::Path;

use smart_pim::coordinator::{BatchPolicy, Server};
use smart_pim::runtime::vgg_tiny::IMAGE_LEN;
use smart_pim::runtime::{Runtime, VggTiny};
use smart_pim::util::Rng;

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/vgg_tiny_b4.hlo.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
    }
    ok
}

fn image(rng: &mut Rng) -> Vec<f32> {
    (0..IMAGE_LEN).map(|_| rng.next_f64() as f32).collect()
}

#[test]
fn serve_burst_all_respond() {
    if !have_artifacts() {
        return;
    }
    let mut server = Server::start("artifacts".into(), BatchPolicy::default()).unwrap();
    let mut rng = Rng::new(11);
    let n = 8;
    let pending: Vec<_> = (0..n).map(|_| server.submit(image(&mut rng))).collect();
    let mut ids = Vec::new();
    for rx in pending {
        let resp = rx.recv().expect("worker alive").expect("inference ok");
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.class < 10);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate or missing responses");
    let stats = server.shutdown();
    assert_eq!(stats.served, n as u64);
    // A burst of 8 must have used large batches, not 8 singles.
    assert!(stats.batches <= 4, "batches {}", stats.batches);
}

#[test]
fn serve_results_match_direct_inference() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let direct = VggTiny::load(&rt).unwrap();
    let mut rng = Rng::new(23);
    let img = image(&mut rng);
    let want = direct.infer(&img).unwrap();

    let mut server = Server::start("artifacts".into(), BatchPolicy::default()).unwrap();
    let resp = server.infer(img).unwrap();
    for (g, w) in resp.logits.iter().zip(&want) {
        assert!((g - w).abs() < 1e-4, "served {g} vs direct {w}");
    }
    server.shutdown();
}

#[test]
fn serve_rejects_malformed_image() {
    if !have_artifacts() {
        return;
    }
    let mut server = Server::start("artifacts".into(), BatchPolicy::default()).unwrap();
    let err = server.infer(vec![0.0; 17]).unwrap_err();
    assert!(err.to_string().contains("floats"), "{err}");
    // The server must keep serving after a bad request.
    let mut rng = Rng::new(3);
    let ok = server.infer(image(&mut rng)).unwrap();
    assert_eq!(ok.logits.len(), 10);
    server.shutdown();
}

#[test]
fn missing_artifacts_fail_fast() {
    let err = Server::start("/definitely/not/a/dir".into(), BatchPolicy::default());
    assert!(err.is_err());
}
