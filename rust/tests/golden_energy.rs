//! Golden energy tests: per-workload TOPS/W bands for all seven shipped
//! workloads, the fleet-energy conservation law of the cluster simulator,
//! the replication-vs-energy-per-image monotonicity property, the
//! power-budgeted capacity planner, and the paper-headline scoreboard.
//!
//! The numeric anchors were derived in an independent executable mirror of
//! the mapping -> placement -> copy_hops -> energy chain (arithmetic only,
//! no engine), so a band failure means the model moved, not that a test
//! guessed wrong.

use smart_pim::cluster::{plan_capacity, rate_from_qps, simulate, ClusterConfig, NodeModel};
use smart_pim::cnn::{resnet, vgg, ResNetVariant, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::coordinator::BatchPolicy;
use smart_pim::mapping::{NetworkMapping, Placement, ReplicationPlan};
use smart_pim::metrics::scoreboard;
use smart_pim::pipeline::build_plans;
use smart_pim::power::EnergyModel;
use smart_pim::sim::extract_flows;
use smart_pim::sweep::SweepRunner;

/// TOPS/W of one workload under one plan, through the same chain
/// `sim::evaluate_network` uses (mapping -> snake placement -> fan-out
/// copy_hops -> per-layer energy) — engine-free, so the values are exact.
fn tops_per_watt(net: &smart_pim::cnn::Network, plan: &ReplicationPlan, arch: &ArchConfig) -> f64 {
    let m = NetworkMapping::build(net, arch, plan).expect("workload maps");
    let placement = Placement::snake(arch);
    let plans = build_plans(net, &m, arch);
    let flows = extract_flows(net, &m, &placement, &plans, arch);
    let hops: Vec<f64> = flows.iter().map(|l| l.copy_hops).collect();
    let em = EnergyModel::new(arch);
    let e = em.image_energy(net, &m, &hops);
    em.tops_per_watt(net, &e)
}

#[test]
fn tops_per_watt_bands_all_seven_workloads() {
    // Mirror-derived anchors (Fig. 7 plans for the VGGs, no replication
    // for the ResNets), +-0.25 band each. Paper Fig. 9 for comparison:
    // A 2.8841, B 2.5538, C 2.5846, D 3.1271, E 3.5914.
    let arch = ArchConfig::paper_node();
    let mut measured = Vec::new();
    for (v, want) in VggVariant::ALL.iter().zip([3.2131, 3.2491, 3.2641, 3.4016, 3.4956]) {
        let net = vgg::build(*v);
        let got = tops_per_watt(&net, &ReplicationPlan::fig7(*v), &arch);
        assert!(
            (got - want).abs() < 0.25,
            "{}: {got} TOPS/W, expected ~{want}",
            v.name()
        );
        measured.push(got);
    }
    for (r, want) in ResNetVariant::ALL.iter().zip([2.7399, 3.0462]) {
        let net = resnet::build(*r);
        let got = tops_per_watt(&net, &ReplicationPlan::none(&net), &arch);
        assert!(
            (got - want).abs() < 0.25,
            "{}: {got} TOPS/W, expected ~{want}",
            r.name()
        );
    }
    // Fig. 9's headline trend: VGG-E is the most efficient VGG.
    let e = measured[4];
    assert!(measured[..4].iter().all(|&x| x < e), "{measured:?}");
}

fn vgg_e_model() -> NodeModel {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    NodeModel::from_workload(&net, &arch, &ReplicationPlan::fig7(VggVariant::E)).unwrap()
}

#[test]
fn fleet_dynamic_energy_conservation() {
    // The conservation law the energy model is built on: fleet dynamic
    // energy == Σ per-node utilization x active power x span == Σ
    // injections x image energy — exactly, not approximately.
    let arch = ArchConfig::paper_node();
    let model = vgg_e_model();
    let profile = model.energy.unwrap();
    let s = simulate(
        &model,
        &ClusterConfig {
            nodes: 2,
            rate_per_cycle: rate_from_qps(1500.0, arch.logical_cycle_ns),
            horizon_cycles: 2_000_000,
            ..ClusterConfig::default()
        },
    );
    let e = s.energy.expect("workload model reports energy");
    assert!(s.completed > 100, "need a real run, got {}", s.completed);

    // (a) injections x image energy.
    let injected: u64 = s.per_node_injected.iter().sum();
    let by_injections = injected as f64 * profile.image_mj * 1e-3;
    assert!(
        (e.dynamic_j - by_injections).abs() < 1e-9 * by_injections.max(1.0),
        "dynamic {} vs injections {}",
        e.dynamic_j,
        by_injections
    );

    // (b) utilization x active power x span, per node.
    let by_utilization: f64 = s
        .node_utilization
        .iter()
        .map(|u| u * profile.active_power_w * e.span_s)
        .sum();
    assert!(
        (e.dynamic_j - by_utilization).abs() < 1e-6 * by_utilization.max(1.0),
        "dynamic {} vs utilization form {}",
        e.dynamic_j,
        by_utilization
    );

    // (c) the ledger adds up: total = dynamic + idle, padding within
    // dynamic, and padding == the per-node injected-minus-completed share.
    assert!((e.total_j() - (e.dynamic_j + e.idle_j)).abs() < 1e-12);
    let padding: u64 = s
        .per_node_injected
        .iter()
        .zip(&s.per_node_completed)
        .map(|(i, c)| i - c)
        .sum();
    let by_padding = padding as f64 * profile.image_mj * 1e-3;
    assert!(
        (e.padding_waste_j - by_padding).abs() < 1e-9 * by_padding.max(1.0),
        "padding {} vs {}",
        e.padding_waste_j,
        by_padding
    );
    assert!(e.padding_waste_j <= e.dynamic_j);
    // Average power is the ledger over the span.
    assert!((e.avg_power_w() * e.span_s - e.total_j()).abs() < 1e-9 * e.total_j());
}

#[test]
fn replication_moves_energy_per_image_monotonically() {
    // Replication vs energy-per-image is monotone at a fixed offered
    // load: with the always-on floor charged over the whole span, a
    // more-replicated (faster) node finishes the same request stream
    // sooner — its span ends at `last injection + max(interval, fill)`
    // instead of the unreplicated plan's 50176-cycle beat — and its
    // dynamic per-image energy is no larger (replicas share partially
    // filled tiles). Both terms push joules-per-image strictly DOWN as
    // replication rises, so fleet TOPS/W rises, while staying within
    // band: bounded above by the workload's dynamic-only efficiency
    // (~3.5 for VGG-E), since the floor only ever subtracts.
    // (An earlier draft charged the floor only over non-busy time, which
    // made a busy node draw less than an idle one and inverted this
    // ordering — that accounting was a bug, not a property.) Mirror
    // anchors at 40 qps x 1 node: none ~314, halved ~310.6, fig7 ~310.4
    // mJ/image.
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let fig7 = ReplicationPlan::fig7(VggVariant::E);
    let halved = ReplicationPlan {
        factors: fig7.factors.iter().map(|&f| (f / 2).max(1)).collect(),
    };
    let plans = [ReplicationPlan::none(&net), halved, fig7];
    let singles = BatchPolicy {
        sizes: vec![1],
        max_wait: 0,
        min_fill: 1.0,
    };
    let mut per_image = Vec::new();
    let mut tpw = Vec::new();
    for plan in &plans {
        let model = NodeModel::from_workload(&net, &arch, plan).unwrap();
        let s = simulate(
            &model,
            &ClusterConfig {
                nodes: 1,
                rate_per_cycle: rate_from_qps(40.0, arch.logical_cycle_ns),
                horizon_cycles: 5_000_000,
                policy: singles.clone(),
                ..ClusterConfig::default()
            },
        );
        assert_eq!(s.rejected, 0, "40 qps must be under every plan's capacity");
        assert!(s.completed > 30, "completed {}", s.completed);
        let e = s.energy.unwrap();
        per_image.push(e.joules_per_image());
        tpw.push(e.tops_per_watt());
    }
    assert!(
        per_image[0] > per_image[1] && per_image[1] > per_image[2],
        "J/image not monotone decreasing in replication: {per_image:?}"
    );
    for (j, t) in per_image.iter().zip(&tpw) {
        assert!(*j > 0.0);
        assert!(
            (0.0..=3.7).contains(t),
            "fleet TOPS/W {t} outside (0, 3.7]: per-image {j}"
        );
        assert!(*t > 0.0);
    }
    // Same ops, fewer joules: efficiency rises with replication.
    assert!(tpw[0] < tpw[1] && tpw[1] < tpw[2], "{tpw:?}");
}

#[test]
fn capacity_planner_honors_power_budget() {
    // ~2.5 nodes of offered load under a 200 W budget: the planner must
    // return a fleet that meets p99 AND draws within budget (a 16-node
    // ladder probe peaks near 16 x ~12 W idle + dynamic, so the minimal
    // SLO fleet sits comfortably inside 200 W).
    let model = vgg_e_model();
    let cfg = ClusterConfig {
        rate_per_cycle: 2.5 / 3136.0,
        horizon_cycles: 1_500_000,
        ..ClusterConfig::default()
    };
    let target = 40_000;
    let r = plan_capacity(&model, &cfg, target, 32, Some(200.0), &SweepRunner::with_threads(4))
        .expect("200 W is feasible for this load");
    assert!(r.stats.meets_slo(target));
    let power = r.stats.energy.unwrap().avg_power_w();
    assert!(power <= 200.0, "planner returned {power} W > budget");
    assert!(r.nodes >= 3, "2.5 nodes of load needs >= 3 replicas");
}

#[test]
fn headline_scoreboard_passes_all_bands() {
    // The `smart-pim reproduce` gate, as a test: the five paper-headline
    // metrics plus the VW-SDK search gate, all inside their pinned bands
    // (metrics::headline::bands).
    let board = scoreboard(&ArchConfig::paper_node(), &SweepRunner::new());
    assert_eq!(board.metrics.len(), 6);
    let keys: Vec<&str> = board.metrics.iter().map(|m| m.key).collect();
    assert_eq!(
        keys,
        [
            "best_tops",
            "best_fps",
            "best_tops_per_watt",
            "scenario_speedup",
            "smart_speedup",
            "vwsdk_search_ratio"
        ]
    );
    for m in &board.metrics {
        assert!(
            m.pass(),
            "{}: model {} outside [{}, {}] (paper {})",
            m.key,
            m.model,
            m.lo,
            m.hi,
            m.paper
        );
    }
    assert!(board.all_pass() && board.failures().is_empty());
}
