//! Bench: regenerate Fig. 8 — VGG-E throughput (TOPS and FPS) for every
//! NoC x scenario combination, with paper values side by side.

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::metrics::{paper, Grid};
use smart_pim::util::bench::Bencher;
use smart_pim::util::table::{fnum, Table};

fn main() {
    let arch = ArchConfig::paper_node();
    println!("== regenerating Fig. 8 ==");
    let grid = Grid::run(&arch, &[VggVariant::E], &Scenario::ALL, &NocKind::ALL);
    grid.fig8_table().print();

    // Paper values for the same grid (Sec. VI, Fig. 8).
    let mut t = Table::new(
        "Fig. 8 — paper reference: TOPS (FPS)",
        &["noc", "(1)", "(2)", "(3)", "(4)"],
    );
    t.row(&[
        "wormhole".into(),
        "2.7092 (69)".into(),
        "2.8270 (72)".into(),
        "23.1265 (589)".into(),
        "36.7904 (937)".into(),
    ]);
    t.row(&[
        "smart".into(),
        "2.9055 (74)".into(),
        "3.0233 (77)".into(),
        "26.9744 (687)".into(),
        "40.4027 (1029)".into(),
    ]);
    t.row(&[
        "ideal".into(),
        "2.9448 (75)".into(),
        "3.0626 (78)".into(),
        "27.9952 (713)".into(),
        "40.9131 (1042)".into(),
    ]);
    t.print();

    let best = grid.get(VggVariant::E, Scenario::ReplicationBatch, NocKind::Smart);
    println!(
        "headline: ours {} TOPS / {} FPS vs paper {} TOPS / {} FPS",
        fnum(best.tops, 4),
        fnum(best.fps, 0),
        paper::FIG8_BEST_TOPS,
        paper::FIG8_BEST_FPS
    );

    println!("\n== timing ==");
    let mut b = Bencher::macro_bench();
    b.bench("full fig8 grid (12 points)", || {
        Grid::run(&arch, &[VggVariant::E], &Scenario::ALL, &NocKind::ALL)
            .reports
            .len()
    });
}
