//! Bench: cluster-serving scalability — end-to-end latency percentiles and
//! delivered throughput across a fleet-size x offered-QPS grid of VGG-E
//! Fig. 7 replicas, plus a capacity-planning run, timed serially and
//! through the parallel sweep runner. Emits `BENCH_cluster.json`
//! (override the path with `SMART_PIM_CLUSTER_BENCH_JSON`; set
//! `SMART_PIM_BENCH_QUICK=1` for the CI-sized grid) so the cluster perf
//! trajectory is trackable across PRs.

use std::time::Instant;

use smart_pim::cluster::{
    plan_capacity, rate_from_qps, simulate, ClusterConfig, ClusterStats, NodeModel,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::ReplicationPlan;
use smart_pim::sweep::SweepRunner;
use smart_pim::util::bench::fmt_duration;
use smart_pim::util::table::{fnum, Table};
use smart_pim::util::Json;

fn main() {
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let model = NodeModel::from_workload(&net, &arch, &plan).expect("VGG-E fig7 maps");
    let quick = std::env::var("SMART_PIM_BENCH_QUICK").is_ok();

    let (fleet_sizes, qps_list, horizon): (&[usize], &[f64], u64) = if quick {
        (&[1, 2], &[500.0, 1500.0], 1_000_000)
    } else {
        (
            &[1, 2, 4, 8],
            &[250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0],
            4_000_000,
        )
    };
    let points: Vec<(usize, f64)> = fleet_sizes
        .iter()
        .flat_map(|&n| qps_list.iter().map(move |&q| (n, q)))
        .collect();
    let cfg_for = |nodes: usize, qps: f64| ClusterConfig {
        nodes,
        rate_per_cycle: rate_from_qps(qps, arch.logical_cycle_ns),
        horizon_cycles: horizon,
        ..ClusterConfig::default()
    };
    let run_grid = |runner: &SweepRunner| -> Vec<ClusterStats> {
        runner.run(&points, |_, &(nodes, qps)| {
            simulate(&model, &cfg_for(nodes, qps))
        })
    };

    println!(
        "== cluster scalability grid: {} points ({} fleets x {} loads), \
         horizon {horizon} cycles ==",
        points.len(),
        fleet_sizes.len(),
        qps_list.len()
    );
    let runner = SweepRunner::new();
    let t0 = Instant::now();
    let serial = run_grid(&SweepRunner::with_threads(1));
    let serial_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = run_grid(&runner);
    let parallel_secs = t0.elapsed().as_secs_f64();

    // Parity: the sweep runner must not perturb a deterministic grid.
    let parity_ok = serial.iter().zip(&parallel).all(|(a, b)| {
        a.offered == b.offered
            && a.latency.p99() == b.latency.p99()
            && a.node_utilization == b.node_utilization
    });
    assert!(parity_ok, "parallel sweep changed deterministic cluster stats");

    let mut t = Table::new(
        "cluster grid — latency (cycles) and delivered throughput vs nodes x qps",
        &[
            "nodes", "qps", "offered", "p50", "p99", "p999", "req/s", "util", "rejected",
        ],
    );
    for ((nodes, qps), s) in points.iter().zip(&parallel) {
        t.row(&[
            nodes.to_string(),
            format!("{qps}"),
            s.offered.to_string(),
            s.latency.p50().to_string(),
            s.latency.p99().to_string(),
            s.latency.p999().to_string(),
            fnum(s.throughput_rps(arch.logical_cycle_ns), 0),
            format!("{:.0}%", 100.0 * s.mean_utilization()),
            s.rejected.to_string(),
        ]);
    }
    t.print();
    println!(
        "grid wall: serial {} | {} threads {} ({:.2}x)",
        fmt_duration(serial_secs),
        runner.threads(),
        fmt_duration(parallel_secs),
        serial_secs / parallel_secs.max(1e-12)
    );

    // Capacity planning demo: fleet for 3x one node's capacity at a p99
    // SLO of two pipeline beats above the fill.
    let cap_qps = 3.0 / (model.interval as f64 * arch.logical_cycle_ns * 1e-9);
    let target = model.fill + 2 * model.interval;
    let t0 = Instant::now();
    let cap = plan_capacity(
        &model,
        &cfg_for(1, cap_qps),
        target,
        64,
        None,
        &runner,
    );
    let cap_secs = t0.elapsed().as_secs_f64();
    let cap_json = match &cap {
        Ok(r) => {
            println!(
                "capacity: {} nodes meet p99 <= {target} cycles at {} qps \
                 ({} points probed, {})",
                r.nodes,
                fnum(cap_qps, 0),
                r.evaluated.len(),
                fmt_duration(cap_secs)
            );
            Json::obj(vec![
                ("qps", cap_qps.into()),
                ("p99_target_cycles", target.into()),
                ("nodes", r.nodes.into()),
                ("points_probed", r.evaluated.len().into()),
                ("confirmed_p99", r.stats.latency.p99().into()),
                (
                    "confirmed_fleet_power_w",
                    r.stats
                        .energy
                        .as_ref()
                        .map(|e| Json::Num(e.avg_power_w()))
                        .unwrap_or(Json::Null),
                ),
                ("wall_secs", cap_secs.into()),
            ])
        }
        Err(e) => {
            println!("capacity search failed: {e}");
            Json::obj(vec![("error", e.as_str().into())])
        }
    };

    // ---- machine-readable trajectory ----------------------------------
    let json_path = std::env::var("SMART_PIM_CLUSTER_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    let epoch_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let grid: Vec<Json> = points
        .iter()
        .zip(&parallel)
        .map(|(&(nodes, qps), s)| {
            let mut row: Vec<(String, Json)> =
                vec![("nodes".into(), nodes.into()), ("qps".into(), qps.into())];
            if let Json::Obj(kvs) = s.to_json(arch.logical_cycle_ns) {
                row.extend(kvs);
            }
            Json::Obj(row)
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", "smart-pim/bench-cluster/v1".into()),
        ("unix_time", epoch_secs.into()),
        ("workload", net.name.as_str().into()),
        ("plan", "fig7".into()),
        ("interval_cycles", model.interval.into()),
        ("fill_cycles", model.fill.into()),
        ("horizon_cycles", horizon.into()),
        ("quick", quick.into()),
        ("threads", runner.threads().into()),
        ("grid", Json::Arr(grid)),
        (
            "perf",
            Json::obj(vec![
                ("points", points.len().into()),
                ("serial_secs", serial_secs.into()),
                ("parallel_secs", parallel_secs.into()),
                (
                    "speedup",
                    (serial_secs / parallel_secs.max(1e-12)).into(),
                ),
                ("parity_ok", parity_ok.into()),
            ]),
        ),
        ("capacity", cap_json),
    ]);
    match std::fs::write(&json_path, doc.render_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
