//! Bench: cluster-serving scalability — end-to-end latency percentiles and
//! delivered throughput across a fleet-size x offered-QPS grid of VGG-E
//! Fig. 7 replicas, plus a capacity-planning run, timed serially and
//! through the parallel sweep runner. Emits `BENCH_cluster.json`
//! (override the path with `SMART_PIM_CLUSTER_BENCH_JSON`; set
//! `SMART_PIM_BENCH_QUICK=1` for the CI-sized grid) so the cluster perf
//! trajectory is trackable across PRs.
//!
//! A second section is the PR 6 scaling study: fleets up to 10k nodes x
//! 1M streamed arrivals through the flattened event loop (indexed
//! routing + deadline suppression), with the linear-scan reference timed
//! side by side at a capped arrival count and re-checked for bit-exact
//! parity at that count. Emits `BENCH_cluster_scale.json` (override with
//! `SMART_PIM_CLUSTER_SCALE_JSON`); the run aborts if any parity pair
//! diverges, so a committed file always certifies equivalence.
//!
//! A third section is the PR 8 multi-tenant study: both residency
//! policies (reprogram-on-miss vs dedicated-partition) serving VGG-E +
//! ResNet-18 under an anti-phase diurnal mix, with per-swap ReRAM
//! weight-programming energy and indexed-vs-scan router parity; its rows
//! land in the same JSON under `tenant_rows`.

use std::time::Instant;

use smart_pim::cluster::{
    plan_capacity, rate_from_qps, simulate, simulate_tenants, ArrivalStream, ClusterConfig,
    ClusterStats, MixMode, NodeModel, Residency, RouteImpl, RoutePolicy, TenantClusterStats,
    TenantConfig, TenantWorkload,
};
use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
use smart_pim::power::WriteCost;
use smart_pim::sweep::SweepRunner;
use smart_pim::util::bench::fmt_duration;
use smart_pim::util::table::{fnum, Table};
use smart_pim::util::Json;

fn main() {
    // Self-profiling rides along: the scaling rows carry a per-row
    // wall-clock section breakdown (`cluster.simulate`, `tenant.simulate`,
    // `sweep.point`), and the JSON doc ends with the run-wide aggregate.
    smart_pim::obs::profile::enable();
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let model = NodeModel::from_workload(&net, &arch, &plan).expect("VGG-E fig7 maps");
    let quick = std::env::var("SMART_PIM_BENCH_QUICK").is_ok();

    let (fleet_sizes, qps_list, horizon): (&[usize], &[f64], u64) = if quick {
        (&[1, 2], &[500.0, 1500.0], 1_000_000)
    } else {
        (
            &[1, 2, 4, 8],
            &[250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0],
            4_000_000,
        )
    };
    let points: Vec<(usize, f64)> = fleet_sizes
        .iter()
        .flat_map(|&n| qps_list.iter().map(move |&q| (n, q)))
        .collect();
    let cfg_for = |nodes: usize, qps: f64| ClusterConfig {
        nodes,
        rate_per_cycle: rate_from_qps(qps, arch.logical_cycle_ns),
        horizon_cycles: horizon,
        ..ClusterConfig::default()
    };
    let run_grid = |runner: &SweepRunner| -> Vec<ClusterStats> {
        runner.run(&points, |_, &(nodes, qps)| {
            simulate(&model, &cfg_for(nodes, qps))
        })
    };

    println!(
        "== cluster scalability grid: {} points ({} fleets x {} loads), \
         horizon {horizon} cycles ==",
        points.len(),
        fleet_sizes.len(),
        qps_list.len()
    );
    let runner = SweepRunner::new();
    let t0 = Instant::now();
    let serial = run_grid(&SweepRunner::with_threads(1));
    let serial_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = run_grid(&runner);
    let parallel_secs = t0.elapsed().as_secs_f64();

    // Parity: the sweep runner must not perturb a deterministic grid.
    let parity_ok = serial.iter().zip(&parallel).all(|(a, b)| {
        a.offered == b.offered
            && a.latency.p99() == b.latency.p99()
            && a.node_utilization == b.node_utilization
    });
    assert!(parity_ok, "parallel sweep changed deterministic cluster stats");

    let mut t = Table::new(
        "cluster grid — latency (cycles) and delivered throughput vs nodes x qps",
        &[
            "nodes", "qps", "offered", "p50", "p99", "p999", "req/s", "util", "rejected",
        ],
    );
    for ((nodes, qps), s) in points.iter().zip(&parallel) {
        t.row(&[
            nodes.to_string(),
            format!("{qps}"),
            s.offered.to_string(),
            s.latency.p50().to_string(),
            s.latency.p99().to_string(),
            s.latency.p999().to_string(),
            fnum(s.throughput_rps(arch.logical_cycle_ns), 0),
            format!("{:.0}%", 100.0 * s.mean_utilization()),
            s.rejected.to_string(),
        ]);
    }
    t.print();
    println!(
        "grid wall: serial {} | {} threads {} ({:.2}x)",
        fmt_duration(serial_secs),
        runner.threads(),
        fmt_duration(parallel_secs),
        serial_secs / parallel_secs.max(1e-12)
    );

    // Capacity planning demo: fleet for 3x one node's capacity at a p99
    // SLO of two pipeline beats above the fill.
    let cap_qps = 3.0 / (model.interval as f64 * arch.logical_cycle_ns * 1e-9);
    let target = model.fill + 2 * model.interval;
    let t0 = Instant::now();
    let cap = plan_capacity(
        &model,
        &cfg_for(1, cap_qps),
        target,
        64,
        None,
        &runner,
    );
    let cap_secs = t0.elapsed().as_secs_f64();
    let cap_json = match &cap {
        Ok(r) => {
            println!(
                "capacity: {} nodes meet p99 <= {target} cycles at {} qps \
                 ({} points probed, {})",
                r.nodes,
                fnum(cap_qps, 0),
                r.evaluated.len(),
                fmt_duration(cap_secs)
            );
            Json::obj(vec![
                ("qps", cap_qps.into()),
                ("p99_target_cycles", target.into()),
                ("nodes", r.nodes.into()),
                ("points_probed", r.evaluated.len().into()),
                ("confirmed_p99", r.stats.latency.p99().into()),
                (
                    "confirmed_fleet_power_w",
                    r.stats
                        .energy
                        .as_ref()
                        .map(|e| Json::Num(e.avg_power_w()))
                        .unwrap_or(Json::Null),
                ),
                ("wall_secs", cap_secs.into()),
            ])
        }
        Err(e) => {
            println!("capacity search failed: {e}");
            Json::obj(vec![("error", e.as_str().into())])
        }
    };

    // ---- machine-readable trajectory ----------------------------------
    let json_path = std::env::var("SMART_PIM_CLUSTER_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    let epoch_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let grid: Vec<Json> = points
        .iter()
        .zip(&parallel)
        .map(|(&(nodes, qps), s)| {
            let mut row: Vec<(String, Json)> =
                vec![("nodes".into(), nodes.into()), ("qps".into(), qps.into())];
            if let Json::Obj(kvs) = s.to_json(arch.logical_cycle_ns) {
                row.extend(kvs);
            }
            Json::Obj(row)
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", "smart-pim/bench-cluster/v1".into()),
        ("unix_time", epoch_secs.into()),
        ("workload", net.name.as_str().into()),
        ("plan", "fig7".into()),
        ("interval_cycles", model.interval.into()),
        ("fill_cycles", model.fill.into()),
        ("horizon_cycles", horizon.into()),
        ("quick", quick.into()),
        ("threads", runner.threads().into()),
        ("grid", Json::Arr(grid)),
        (
            "perf",
            Json::obj(vec![
                ("points", points.len().into()),
                ("serial_secs", serial_secs.into()),
                ("parallel_secs", parallel_secs.into()),
                (
                    "speedup",
                    (serial_secs / parallel_secs.max(1e-12)).into(),
                ),
                ("parity_ok", parity_ok.into()),
            ]),
        ),
        ("capacity", cap_json),
    ]);
    match std::fs::write(&json_path, doc.render_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }

    let (tenant_rows, tenant_parity_ok) = tenant_study(&arch, quick);
    scaling_study(&model, net.name.as_str(), quick, tenant_rows, tenant_parity_ok);
}

/// Two tenant runs are interchangeable only if every observable agrees
/// exactly, per tenant and per node.
fn tenant_identical(a: &TenantClusterStats, b: &TenantClusterStats) -> bool {
    a.offered == b.offered
        && a.completed == b.completed
        && a.rejected == b.rejected
        && a.horizon_cycles == b.horizon_cycles
        && a.drained_at == b.drained_at
        && a.events_processed == b.events_processed
        && a.peak_calendar_depth == b.peak_calendar_depth
        && a.node_utilization == b.node_utilization
        && a.per_node_swaps == b.per_node_swaps
        && a.per_node_injected == b.per_node_injected
        && a.tenants.len() == b.tenants.len()
        && a.tenants.iter().zip(&b.tenants).all(|(x, y)| {
            x.offered == y.offered
                && x.completed == y.completed
                && x.rejected == y.rejected
                && x.swaps == y.swaps
                && x.swap_energy_j == y.swap_energy_j
                && x.total_latency_cycles == y.total_latency_cycles
                && x.latency.p50() == y.latency.p50()
                && x.latency.p99() == y.latency.p99()
        })
}

/// PR 8 multi-tenant section: both residency policies on one fleet
/// serving VGG-E (Fig. 7 plan) + ResNet-18 (unreplicated) under an
/// anti-phase diurnal mix — the swap-storm benchmark, with per-swap
/// weight-programming energy on the reprogram side — and the linear-scan
/// router re-run at the same seed for bit-exact parity. Returns JSON rows
/// folded into `BENCH_cluster_scale.json`.
fn tenant_study(arch: &ArchConfig, quick: bool) -> (Vec<Json>, bool) {
    let build = |name: &str| -> TenantWorkload {
        let net = smart_pim::cnn::workload(name).expect("known workload");
        let plan = match net.name.parse::<VggVariant>() {
            Ok(v) => ReplicationPlan::fig7(v),
            Err(_) => ReplicationPlan::none(&net),
        };
        let model = NodeModel::from_workload(&net, arch, &plan).expect("plan maps");
        let mapping = NetworkMapping::build(&net, arch, &plan).expect("plan maps");
        let write = WriteCost::of_mapping(&net, &mapping, arch);
        TenantWorkload::from_model(&net.name, 1.0, &model, write)
    };
    let tenants = [build("vggE"), build("resnet18")];
    let (nodes, arrivals) = if quick { (8usize, 30_000usize) } else { (32, 200_000) };
    let cfg_for = |residency: Residency, imp: RouteImpl| TenantConfig {
        nodes,
        residency,
        route_impl: imp,
        rate_per_cycle: 0.02,
        mix: MixMode::Diurnal { period: 2_000_000 },
        fixed_requests: Some(arrivals),
        seed: 0xC105_7E4,
        ..TenantConfig::default()
    };
    println!("\n== multi-tenant study: vggE + resnet18, diurnal mix, {nodes} nodes ==");
    let mut t = Table::new(
        "residency policies — completions, swaps, write energy, p99 (cycles)",
        &[
            "residency", "tenant", "completed", "rejected", "swaps", "swap J", "p99",
            "parity",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut all_ok = true;
    for residency in [Residency::Reprogram, Residency::Partition] {
        let t0 = Instant::now();
        let ix = simulate_tenants(&tenants, &cfg_for(residency, RouteImpl::Indexed))
            .expect("tenant sim runs");
        let wall = t0.elapsed().as_secs_f64();
        let sc = simulate_tenants(&tenants, &cfg_for(residency, RouteImpl::LinearScan))
            .expect("tenant sim runs");
        let parity_ok = tenant_identical(&ix, &sc);
        all_ok &= parity_ok;
        for ts in &ix.tenants {
            t.row(&[
                residency.name().to_string(),
                ts.name.clone(),
                ts.completed.to_string(),
                ts.rejected.to_string(),
                ts.swaps.to_string(),
                fnum(ts.swap_energy_j, 2),
                ts.latency.p99().to_string(),
                if parity_ok { "ok" } else { "FAIL" }.to_string(),
            ]);
        }
        let per_tenant: Vec<Json> = ix
            .tenants
            .iter()
            .map(|ts| {
                Json::obj(vec![
                    ("tenant", ts.name.as_str().into()),
                    ("completed", ts.completed.into()),
                    ("rejected", ts.rejected.into()),
                    ("swaps", ts.swaps.into()),
                    ("swap_energy_j", ts.swap_energy_j.into()),
                    ("latency_p99_cycles", ts.latency.p99().into()),
                ])
            })
            .collect();
        rows.push(Json::obj(vec![
            ("nodes", nodes.into()),
            ("residency", residency.name().into()),
            ("route", ix.route.name().into()),
            ("mix", "diurnal".into()),
            ("mix_period", 2_000_000u64.into()),
            ("arrivals", arrivals.into()),
            ("events", ix.events_processed.into()),
            ("peak_calendar_depth", ix.peak_calendar_depth.into()),
            ("wall_secs", wall.into()),
            (
                "events_per_sec",
                (ix.events_processed as f64 / wall.max(1e-12)).into(),
            ),
            ("per_tenant", Json::Arr(per_tenant)),
            ("parity_ok", parity_ok.into()),
        ]));
    }
    t.print();
    assert!(all_ok, "tenant routing impls diverged");
    (rows, all_ok)
}

/// Two runs are interchangeable only if every observable agrees exactly —
/// counts, the effective horizon, drain cycle, perf gauges, the latency
/// distribution and every per-node vector.
fn identical(a: &ClusterStats, b: &ClusterStats) -> bool {
    a.offered == b.offered
        && a.completed == b.completed
        && a.rejected == b.rejected
        && a.horizon_cycles == b.horizon_cycles
        && a.drained_at == b.drained_at
        && a.events_processed == b.events_processed
        && a.peak_calendar_depth == b.peak_calendar_depth
        && a.latency.mean() == b.latency.mean()
        && a.latency.max() == b.latency.max()
        && a.latency.p50() == b.latency.p50()
        && a.latency.p99() == b.latency.p99()
        && a.queueing.mean() == b.queueing.mean()
        && a.node_utilization == b.node_utilization
        && a.per_node_completed == b.per_node_completed
        && a.per_node_rejected == b.per_node_rejected
        && a.per_node_injected == b.per_node_injected
}

/// PR 6 scaling study: the flattened loop (indexed routing, streamed
/// arrivals, deadline suppression) timed on fleets up to 10k nodes x 1M
/// arrivals, with the O(N)-per-arrival linear-scan reference alongside at
/// a capped arrival count — then the indexed loop re-run at that capped
/// count and compared bit-exactly, so every speedup row doubles as a
/// parity certificate. Writes `BENCH_cluster_scale.json`.
fn scaling_study(
    model: &NodeModel,
    workload: &str,
    quick: bool,
    tenant_rows: Vec<Json>,
    tenant_parity_ok: bool,
) {
    // (fleet, arrivals through the indexed loop, arrivals for the scan
    // reference — capped so the quadratic side stays affordable).
    let points: &[(usize, usize, usize)] = if quick {
        &[(64, 30_000, 30_000), (256, 60_000, 15_000)]
    } else {
        &[
            (100, 1_000_000, 1_000_000),
            (1_000, 1_000_000, 200_000),
            (10_000, 1_000_000, 50_000),
        ]
    };
    println!("\n== scaling study: flat event loop vs linear-scan reference ==");
    let cfg_for = |nodes: usize, requests: usize, route: RoutePolicy, imp: RouteImpl| {
        ClusterConfig {
            nodes,
            // ~90% of aggregate fleet capacity: queues form and deadlines
            // fire, but the run still drains promptly.
            rate_per_cycle: 0.9 * nodes as f64 / model.interval as f64,
            route,
            fixed_requests: Some(requests),
            seed: 0x5CA1_AB1E,
            route_impl: imp,
            ..ClusterConfig::default()
        }
    };
    let timed = |cfg: &ClusterConfig| {
        let t0 = Instant::now();
        let s = simulate(model, cfg);
        (s, t0.elapsed().as_secs_f64())
    };

    let mut t = Table::new(
        "flat loop vs scan — events/sec, peak calendar depth, parity",
        &[
            "nodes", "route", "arrivals", "wall", "Mev/s", "peak", "scan N", "scan wall",
            "scan Mev/s", "speedup", "parity",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut all_parity_ok = true;
    for &(nodes, arrivals, scan_arrivals) in points {
        for route in [RoutePolicy::ShortestQueue, RoutePolicy::LeastWork] {
            let prof_before = smart_pim::obs::profile::snapshot();
            let (ix, ix_secs) = timed(&cfg_for(nodes, arrivals, route, RouteImpl::Indexed));
            let (sc, sc_secs) =
                timed(&cfg_for(nodes, scan_arrivals, route, RouteImpl::LinearScan));
            // Re-run the indexed loop at the scan's (possibly capped)
            // arrival count: same seed, same stream — the stats must be
            // bit-identical, and the wall-clock ratio is the speedup at
            // an equal workload.
            let (ix_cap, ix_cap_secs) =
                timed(&cfg_for(nodes, scan_arrivals, route, RouteImpl::Indexed));
            let parity_ok = identical(&ix_cap, &sc);
            all_parity_ok &= parity_ok;
            let ev_per_sec = ix.events_processed as f64 / ix_secs.max(1e-12);
            let scan_ev_per_sec = sc.events_processed as f64 / sc_secs.max(1e-12);
            let speedup = sc_secs / ix_cap_secs.max(1e-12);
            t.row(&[
                nodes.to_string(),
                route.name().to_string(),
                arrivals.to_string(),
                fmt_duration(ix_secs),
                fnum(ev_per_sec / 1e6, 2),
                ix.peak_calendar_depth.to_string(),
                scan_arrivals.to_string(),
                fmt_duration(sc_secs),
                fnum(scan_ev_per_sec / 1e6, 2),
                format!("{speedup:.1}x"),
                if parity_ok { "ok" } else { "FAIL" }.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("nodes", nodes.into()),
                ("route", route.name().into()),
                ("arrivals", arrivals.into()),
                ("indexed_wall_secs", ix_secs.into()),
                ("indexed_events", ix.events_processed.into()),
                ("indexed_events_per_sec", ev_per_sec.into()),
                ("peak_calendar_depth", ix.peak_calendar_depth.into()),
                ("completed", ix.completed.into()),
                ("rejected", ix.rejected.into()),
                ("latency_p99_cycles", ix.latency.p99().into()),
                ("scan_arrivals", scan_arrivals.into()),
                ("scan_wall_secs", sc_secs.into()),
                ("scan_events_per_sec", scan_ev_per_sec.into()),
                ("indexed_wall_at_scan_count_secs", ix_cap_secs.into()),
                ("speedup_at_scan_count", speedup.into()),
                ("parity_ok", parity_ok.into()),
                // All three runs of this row (indexed, scan, indexed@cap)
                // land in one section delta — wall seconds inside the
                // event loop vs the row's total.
                (
                    "profile",
                    smart_pim::obs::profile::sections_json(&smart_pim::obs::profile::delta(
                        &prof_before,
                        &smart_pim::obs::profile::snapshot(),
                    )),
                ),
            ]));
        }
    }
    t.print();
    assert!(
        all_parity_ok,
        "indexed routing diverged from the linear-scan reference"
    );

    let json_path = std::env::var("SMART_PIM_CLUSTER_SCALE_JSON")
        .unwrap_or_else(|_| "BENCH_cluster_scale.json".to_string());
    let epoch_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let doc = Json::obj(vec![
        ("schema", "smart-pim/bench-cluster-scale/v1".into()),
        ("unix_time", epoch_secs.into()),
        ("producer", "rust-bench".into()),
        ("workload", workload.into()),
        ("plan", "fig7".into()),
        ("interval_cycles", model.interval.into()),
        ("fill_cycles", model.fill.into()),
        ("quick", quick.into()),
        // The streamed-arrival state is a few machine words regardless of
        // how many arrivals a run pulls; a materialized Vec<u64> at the
        // largest point would be `arrivals * 8` bytes per run.
        (
            "arrival_stream_bytes",
            std::mem::size_of::<ArrivalStream<'static>>().into(),
        ),
        ("rows", Json::Arr(rows)),
        ("all_parity_ok", all_parity_ok.into()),
        ("tenant_rows", Json::Arr(tenant_rows)),
        ("tenant_parity_ok", tenant_parity_ok.into()),
        // Run-wide self-profiling aggregate (every section since
        // process start, across all three studies).
        ("profile", smart_pim::obs::profile::report_json()),
    ]);
    match std::fs::write(&json_path, doc.render_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
