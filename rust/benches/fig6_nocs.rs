//! Bench: regenerate Fig. 6 — speedup of SMART and ideal NoCs over the
//! wormhole baseline for every VGG in every pipelining scenario.

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::metrics::{paper, Grid};
use smart_pim::sweep::SweepRunner;
use smart_pim::util::bench::Bencher;
use smart_pim::util::stats::geomean;

fn main() {
    let arch = ArchConfig::paper_node();
    let runner = SweepRunner::new();
    println!(
        "== regenerating Fig. 6 (all scenarios) — {} benchmark points on {} threads ==",
        VggVariant::ALL.len() * Scenario::ALL.len() * NocKind::ALL.len(),
        runner.threads()
    );
    let grid = Grid::run_with(&runner, &arch, &VggVariant::ALL, &Scenario::ALL, &NocKind::ALL);
    let mut smart_all = Vec::new();
    let mut ideal_all = Vec::new();
    for scenario in Scenario::ALL {
        let (table, geo) = grid.fig6_table(scenario, &VggVariant::ALL);
        table.print();
        smart_all.push(geo[0]);
        ideal_all.push(geo[1]);
        println!();
    }
    println!(
        "overall geomean — smart/wormhole {:.4}, ideal/wormhole {:.4} (paper ideal: {:.4})",
        geomean(&smart_all),
        geomean(&ideal_all),
        paper::FIG6_IDEAL_GEOMEAN
    );

    println!("\n== timing: NoC co-simulation per kind ==");
    let mut b = Bencher::macro_bench();
    for noc in NocKind::ALL {
        b.bench(&format!("co-sim vggD scenario4 {}", noc.name()), || {
            smart_pim::sim::evaluate(
                VggVariant::D,
                Scenario::ReplicationBatch,
                noc,
                &arch,
            )
        });
    }
}
