//! Bench: regenerate Fig. 6 — speedup of SMART and ideal NoCs over the
//! wormhole baseline for every VGG in every pipelining scenario — then
//! rerun the headline scenario on the torus and Parallel-Prism fabrics and
//! fold the per-topology geomeans into `BENCH_noc.json` (read-modify-write,
//! so the fig-10/11 bench's grid in the same file survives).

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario, TopologyKind};
use smart_pim::metrics::{paper, Grid};
use smart_pim::sweep::SweepRunner;
use smart_pim::util::bench::Bencher;
use smart_pim::util::stats::geomean;
use smart_pim::util::table::{fnum, Table};
use smart_pim::util::Json;

fn main() {
    let arch = ArchConfig::paper_node();
    let runner = SweepRunner::new();
    println!(
        "== regenerating Fig. 6 (all scenarios) — {} benchmark points on {} threads ==",
        VggVariant::ALL.len() * Scenario::ALL.len() * NocKind::ALL.len(),
        runner.threads()
    );
    let grid = Grid::run_with(&runner, &arch, &VggVariant::ALL, &Scenario::ALL, &NocKind::ALL);
    let mut smart_all = Vec::new();
    let mut ideal_all = Vec::new();
    for scenario in Scenario::ALL {
        let (table, geo) = grid.fig6_table(scenario, &VggVariant::ALL);
        table.print();
        smart_all.push(geo[0]);
        ideal_all.push(geo[1]);
        println!();
    }
    println!(
        "overall geomean — smart/wormhole {:.4}, ideal/wormhole {:.4} (paper ideal: {:.4})",
        geomean(&smart_all),
        geomean(&ideal_all),
        paper::FIG6_IDEAL_GEOMEAN
    );

    // ---- Fig. 6 per topology (headline scenario only) ------------------
    // The mesh grid above is the paper's pinned figure; the torus and
    // Parallel-Prism rows are informational (ISSUE 10) and land in
    // BENCH_noc.json next to the fig-10/11 synthetic rows.
    println!("\n== Fig. 6 per topology — scenario 4, all VGGs ==");
    let mut topo_rows: Vec<Json> = Vec::new();
    let mut t = Table::new(
        "fig6 geomeans per topology (scenario 4)",
        &["topology", "smart/wormhole", "ideal/wormhole"],
    );
    for tk in TopologyKind::ALL {
        let mut a = arch.clone();
        a.topology = tk;
        let g = Grid::run_with(
            &runner,
            &a,
            &VggVariant::ALL,
            &[Scenario::ReplicationBatch],
            &NocKind::ALL,
        );
        let (_, geo) = g.fig6_table(Scenario::ReplicationBatch, &VggVariant::ALL);
        t.row(&[tk.name().into(), fnum(geo[0], 4), fnum(geo[1], 4)]);
        topo_rows.push(Json::obj(vec![
            ("topology", tk.name().into()),
            ("scenario", "replication_batch".into()),
            ("smart_geomean", geo[0].into()),
            ("ideal_geomean", geo[1].into()),
        ]));
    }
    t.print();

    // Read-modify-write: keep whatever the fig-10/11 bench already wrote.
    let json_path = std::env::var("SMART_PIM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_noc.json".to_string());
    let mut json = std::fs::read_to_string(&json_path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .unwrap_or_else(|| Json::obj(vec![("schema", "smart-pim/bench-noc/v1".into())]));
    if let Json::Obj(kvs) = &mut json {
        kvs.retain(|(k, _)| k != "fig6_topology");
        kvs.push(("fig6_topology".to_string(), Json::Arr(topo_rows)));
    }
    match std::fs::write(&json_path, json.render_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }

    println!("\n== timing: NoC co-simulation per kind ==");
    let mut b = Bencher::macro_bench();
    for noc in NocKind::ALL {
        b.bench(&format!("co-sim vggD scenario4 {}", noc.name()), || {
            smart_pim::sim::evaluate(
                VggVariant::D,
                Scenario::ReplicationBatch,
                noc,
                &arch,
            )
        });
    }
}
