//! Micro-benchmarks of the simulator hot paths — the profile targets of the
//! performance pass (EXPERIMENTS.md §Perf): NoC cycles/sec, engine
//! cycles/sec, and the PJRT crossbar GEMM when artifacts exist.

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::ArchConfig;
use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
use smart_pim::noc::{Mesh, Network};
use smart_pim::pipeline::build_plans;
use smart_pim::sim::engine::{Engine, NocAdjust};
use smart_pim::util::bench::{fmt_duration, Bencher};
use smart_pim::util::Rng;

fn main() {
    let mut b = Bencher::default();

    // --- NoC simulator inner loop -------------------------------------
    // Steady uniform-random load on an 8x8 mesh; report flit-hops/s.
    let cycles = 3_000u64;
    let r = b.bench("noc 8x8 smart 0.2 load, 3k cycles", || {
        let mesh = Mesh::new(8, 8);
        let mut net = Network::new(mesh, 14, 1, 4);
        let mut rng = Rng::new(1);
        for c in 0..cycles {
            if c % 2 == 0 {
                for src in 0..mesh.nodes() {
                    if rng.chance(0.05) {
                        let dst = rng.below_usize(mesh.nodes());
                        if dst != src {
                            net.enqueue(src, dst, 4);
                        }
                    }
                }
            }
            net.step();
        }
        net.flits_ejected
    });
    let per_cycle = r.median() / cycles as f64;
    println!(
        "  -> {} per NoC cycle ({:.2} Mcycles/s)",
        fmt_duration(per_cycle),
        1e-6 / per_cycle
    );

    // --- 16x20 CNN-scale mesh -----------------------------------------
    b.bench("noc 16x20 wormhole idle+load, 2k cycles", || {
        let mesh = Mesh::new(16, 20);
        let mut net = Network::new(mesh, 1, 4, 4);
        let mut rng = Rng::new(2);
        for _ in 0..2_000u64 {
            for src in (0..mesh.nodes()).step_by(7) {
                if rng.chance(0.02) {
                    let dst = rng.below_usize(mesh.nodes());
                    if dst != src {
                        net.enqueue(src, dst, 8);
                    }
                }
            }
            net.step();
        }
        net.flits_ejected
    });

    // --- pipeline engine -----------------------------------------------
    let arch = ArchConfig::paper_node();
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
    let plans = build_plans(&net, &m, &arch);
    let adj = NocAdjust::identity(plans.len());
    let r = b.bench("engine vggE repl+batch, 10 images", || {
        Engine::new(&plans, &adj, true, 10).run().cycles
    });
    // steady interval 3136 x ~10 images ≈ 36k cycles per run.
    let run_cycles = Engine::new(&plans, &adj, true, 10).run().cycles;
    let eng_per_cycle = r.median() / run_cycles as f64;
    println!(
        "  -> {} per engine cycle ({:.2} Mcycles/s, {} stages)",
        fmt_duration(eng_per_cycle),
        1e-6 / eng_per_cycle,
        plans.len()
    );

    let plan1 = ReplicationPlan::none(&net);
    let m1 = NetworkMapping::build(&net, &arch, &plan1).unwrap();
    let plans1 = build_plans(&net, &m1, &arch);
    let adj1 = NocAdjust::identity(plans1.len());
    b.bench("engine vggE baseline, 1 image (~52k cycles)", || {
        Engine::new(&plans1, &adj1, false, 1).run().cycles
    });

    // --- PJRT crossbar GEMM (needs artifacts) ---------------------------
    if std::path::Path::new("artifacts/crossbar_gemm_128.hlo.txt").exists() {
        use smart_pim::runtime::{literal_i32, Runtime};
        // Artifacts on disk do not imply a PJRT build: the default build
        // ships API-identical stubs whose constructor errors. Skip, don't
        // panic.
        let rt = match Runtime::new("artifacts") {
            Ok(rt) => rt,
            Err(e) => {
                println!("(skipping PJRT bench: {e})");
                return;
            }
        };
        let exe = rt.load("crossbar_gemm_128").unwrap();
        let x: Vec<i32> = (0..128 * 128).map(|i| (i % 65536) as i32).collect();
        let w: Vec<i32> = (0..128 * 128).map(|i| (i % 65536) as i32 - 32768).collect();
        let xl = literal_i32(&x, &[128, 128]).unwrap();
        let wl = literal_i32(&w, &[128, 128]).unwrap();
        let r = b.bench("pjrt crossbar_gemm 128x128x128 (bit-serial)", || {
            exe.run_i32(&[
                xl.clone().reshape(&[128, 128]).unwrap(),
                wl.clone().reshape(&[128, 128]).unwrap(),
            ])
            .unwrap()
            .len()
        });
        // 16 bit-planes x 128^3 MACs x 2 ops.
        let ops = 16.0 * 128f64.powi(3) * 2.0;
        println!(
            "  -> {:.2} GOPS bit-serial equivalent",
            ops / r.median() / 1e9
        );
    } else {
        println!("(skipping PJRT bench: run `make artifacts`)");
    }
}
