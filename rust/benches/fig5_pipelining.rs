//! Bench: regenerate Fig. 5 — speedup of pipelining scenarios (2)-(4) over
//! the baseline (1) for every VGG, per NoC — and time the underlying
//! simulations with the built-in harness (`cargo bench`).

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::metrics::{paper, Grid};
use smart_pim::sim::evaluate;
use smart_pim::util::bench::Bencher;

fn main() {
    let arch = ArchConfig::paper_node();

    println!("== regenerating Fig. 5 (all NoCs) ==");
    for noc in NocKind::ALL {
        let grid = Grid::run(&arch, &VggVariant::ALL, &Scenario::ALL, &[noc]);
        let (table, geo) = grid.fig5_table(noc, &VggVariant::ALL);
        table.print();
        println!(
            "paper geomeans {:.4} / {:.4} / {:.4} | ours {:.4} / {:.4} / {:.4}\n",
            paper::FIG5_GEOMEANS[0],
            paper::FIG5_GEOMEANS[1],
            paper::FIG5_GEOMEANS[2],
            geo[0],
            geo[1],
            geo[2]
        );
    }

    println!("== timing: single benchmark points ==");
    let mut b = Bencher::macro_bench();
    b.bench("evaluate vggA baseline ideal", || {
        evaluate(VggVariant::A, Scenario::Baseline, NocKind::Ideal, &arch)
    });
    b.bench("evaluate vggE repl+batch ideal", || {
        evaluate(
            VggVariant::E,
            Scenario::ReplicationBatch,
            NocKind::Ideal,
            &arch,
        )
    });
    b.bench("evaluate vggE repl+batch smart (co-sim)", || {
        evaluate(
            VggVariant::E,
            Scenario::ReplicationBatch,
            NocKind::Smart,
            &arch,
        )
    });
}
