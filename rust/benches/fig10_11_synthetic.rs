//! Bench: regenerate Figs. 10 and 11 — injection rate vs latency and vs
//! reception rate for the six synthetic traffic patterns on the 8x8 mesh
//! (Sec. VII), wormhole vs SMART.

use smart_pim::config::{ArchConfig, NocKind};
use smart_pim::noc::{run_synthetic, Mesh, Pattern, SyntheticConfig};
use smart_pim::util::bench::Bencher;
use smart_pim::util::table::{fnum, Table};

const RATES: [f64; 10] = [0.02, 0.05, 0.08, 0.12, 0.16, 0.2, 0.3, 0.5, 0.65, 0.8];

fn main() {
    let arch = ArchConfig::paper_node();
    let mesh = Mesh::new(8, 8);

    println!("== regenerating Fig. 10 (latency) and Fig. 11 (reception) ==");
    let mut saturation: Vec<(String, f64, f64)> = Vec::new();
    for pattern in Pattern::ALL {
        let mut t = Table::new(
            format!("{} — latency / reception per injection rate", pattern.name()),
            &[
                "rate",
                "wormhole lat",
                "smart lat",
                "wormhole recv",
                "smart recv",
            ],
        );
        let mut sat_w = f64::NAN;
        let mut sat_s = f64::NAN;
        for &rate in &RATES {
            let cfg = SyntheticConfig {
                pattern,
                injection_rate: rate,
                warmup: 1_500,
                measure: 6_000,
                drain: 12_000,
                ..Default::default()
            };
            let w = run_synthetic(NocKind::Wormhole, mesh, &cfg, arch.hpc_max);
            let s = run_synthetic(NocKind::Smart, mesh, &cfg, arch.hpc_max);
            if w.saturated() && sat_w.is_nan() {
                sat_w = rate;
            }
            if s.saturated() && sat_s.is_nan() {
                sat_s = rate;
            }
            t.row(&[
                format!("{rate}"),
                format!("{}{}", fnum(w.avg_latency, 1), sat(&w)),
                format!("{}{}", fnum(s.avg_latency, 1), sat(&s)),
                fnum(w.reception_rate, 4),
                fnum(s.reception_rate, 4),
            ]);
        }
        t.print();
        saturation.push((pattern.name().to_string(), sat_w, sat_s));
        println!();
    }

    let mut t = Table::new(
        "saturation points (first saturated rate)",
        &["pattern", "wormhole", "smart", "paper wormhole", "paper smart"],
    );
    let paper_pts = [
        ("uniform_random", "0.05", "0.25"),
        ("transpose", "0.05", "0.25"),
        ("tornado", "0.05", "0.25"),
        ("shuffle", "0.05", "0.25"),
        ("neighbor", "0.2", "0.8"),
        ("bit_complement", "0.05", "0.25"),
    ];
    for ((name, w, s), (_, pw, ps)) in saturation.iter().zip(paper_pts) {
        t.row(&[
            name.clone(),
            fmt_sat(*w),
            fmt_sat(*s),
            pw.into(),
            ps.into(),
        ]);
    }
    t.print();

    println!("\n== timing: one sweep point ==");
    let mut b = Bencher::macro_bench();
    for kind in [NocKind::Wormhole, NocKind::Smart] {
        let cfg = SyntheticConfig {
            injection_rate: 0.1,
            ..Default::default()
        };
        b.bench(&format!("uniform 0.1 {} (12k cycles)", kind.name()), || {
            run_synthetic(kind, mesh, &cfg, arch.hpc_max).completed
        });
    }
}

fn sat(s: &smart_pim::noc::NocStats) -> &'static str {
    if s.saturated() {
        " SAT"
    } else {
        ""
    }
}

fn fmt_sat(x: f64) -> String {
    if x.is_nan() {
        ">0.8".into()
    } else {
        format!("{x}")
    }
}
