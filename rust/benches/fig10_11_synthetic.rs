//! Bench: regenerate Figs. 10 and 11 — injection rate vs latency and vs
//! reception rate for the six synthetic traffic patterns on the 8x8 mesh
//! (Sec. VII), wormhole vs SMART — through the unified parallel sweep
//! engine, then time the event-driven engine against the seed
//! cycle-stepped loop, rerun a uniform-random slice on every fabric
//! (mesh / torus / Parallel-Prism), and emit machine-readable results to
//! `BENCH_noc.json` (override the path with `SMART_PIM_BENCH_JSON`) so the
//! perf trajectory is trackable across PRs.

use std::time::Instant;

use smart_pim::config::{ArchConfig, TopologyKind};
use smart_pim::noc::{AnyTopology, Mesh, Pattern, StepMode, SyntheticConfig};
use smart_pim::sweep::{SweepRunner, SyntheticOutcome, SyntheticSweep};
use smart_pim::util::bench::fmt_duration;
use smart_pim::util::table::{fnum, Table};
use smart_pim::util::Json;

const RATES: [f64; 10] = [0.02, 0.05, 0.08, 0.12, 0.16, 0.2, 0.3, 0.5, 0.65, 0.8];
/// Timing subset: the fig10 sweep at low-to-mid injection rates. Parity
/// between the engines is asserted (wrong stats fail the bench); the
/// measured speedups are informational and recorded in BENCH_noc.json
/// (the target is >= 2x over the seed loop — see ISSUE/acceptance).
const PERF_RATES: [f64; 4] = [0.02, 0.05, 0.08, 0.10];

fn base_cfg() -> SyntheticConfig {
    SyntheticConfig {
        warmup: 1_500,
        measure: 6_000,
        drain: 12_000,
        ..Default::default()
    }
}

fn main() {
    let arch = ArchConfig::paper_node();
    let mesh = Mesh::new(8, 8);
    let runner = SweepRunner::new();

    println!(
        "== regenerating Fig. 10 (latency) and Fig. 11 (reception) — \
         parallel sweep on {} threads ==",
        runner.threads()
    );
    let mut sweep = SyntheticSweep::new(mesh, arch.hpc_max);
    sweep.rates = RATES.to_vec();
    sweep.base = base_cfg();
    sweep.per_point_seeds = false; // keep the seed CLI's numbers comparable
    let t0 = Instant::now();
    let outcomes = sweep.run(&runner);
    let grid_secs = t0.elapsed().as_secs_f64();

    let mut saturation: Vec<(String, f64, f64)> = Vec::new();
    for pattern in sweep.patterns.clone() {
        let mut t = Table::new(
            format!("{} — latency / reception per injection rate", pattern.name()),
            &[
                "rate",
                "wormhole lat",
                "smart lat",
                "wormhole recv",
                "smart recv",
            ],
        );
        let mut sat_w = f64::NAN;
        let mut sat_s = f64::NAN;
        for pair in sweep.rows_for(&outcomes, pattern).chunks(2) {
            let (w, s) = (pair[0], pair[1]);
            if w.stats.saturated() && sat_w.is_nan() {
                sat_w = w.rate;
            }
            if s.stats.saturated() && sat_s.is_nan() {
                sat_s = s.rate;
            }
            t.row(&[
                format!("{}", w.rate),
                format!("{}{}", fnum(w.stats.avg_latency, 1), sat(w)),
                format!("{}{}", fnum(s.stats.avg_latency, 1), sat(s)),
                fnum(w.stats.reception_rate, 4),
                fnum(s.stats.reception_rate, 4),
            ]);
        }
        t.print();
        saturation.push((pattern.name().to_string(), sat_w, sat_s));
        println!();
    }

    let mut t = Table::new(
        "saturation points (first saturated rate)",
        &["pattern", "wormhole", "smart", "paper wormhole", "paper smart"],
    );
    let paper_pts = [
        ("uniform_random", "0.05", "0.25"),
        ("transpose", "0.05", "0.25"),
        ("tornado", "0.05", "0.25"),
        ("shuffle", "0.05", "0.25"),
        ("neighbor", "0.2", "0.8"),
        ("bit_complement", "0.05", "0.25"),
    ];
    for ((name, w, s), (_, pw, ps)) in saturation.iter().zip(paper_pts) {
        t.row(&[
            name.clone(),
            fmt_sat(*w),
            fmt_sat(*s),
            pw.into(),
            ps.into(),
        ]);
    }
    t.print();
    println!(
        "full grid ({} points): {}",
        outcomes.len(),
        fmt_duration(grid_secs)
    );

    // ---- perf gate: event-driven vs the seed cycle-stepped loop --------
    println!("\n== engine timing: fig10 sweep, all patterns, rates {PERF_RATES:?} ==");
    let mut perf = SyntheticSweep::new(mesh, arch.hpc_max);
    perf.rates = PERF_RATES.to_vec();
    perf.base = base_cfg();
    perf.per_point_seeds = false;
    let serial = SweepRunner::with_threads(1);

    // The seed loop: serial iteration, cycle-stepped engine.
    let t0 = Instant::now();
    let seed_out = perf.run_with_mode(&serial, StepMode::CycleStepped);
    let seed_secs = t0.elapsed().as_secs_f64();

    // Engine-only comparison: serial iteration, event-driven engine.
    let t0 = Instant::now();
    let event_out = perf.run_with_mode(&serial, StepMode::EventDriven);
    let event_serial_secs = t0.elapsed().as_secs_f64();

    // The shipping configuration: parallel sweep + event-driven engine.
    let t0 = Instant::now();
    let event_par_out = perf.run_with_mode(&runner, StepMode::EventDriven);
    let event_parallel_secs = t0.elapsed().as_secs_f64();

    // Golden parity on the way: both engines and both runners must report
    // bit-identical stats (the dedicated test is golden_noc_parity.rs).
    // A timing comparison between engines that disagree on the physics is
    // meaningless, so parity failure fails the bench loudly.
    let parity_ok = seed_out
        .iter()
        .zip(&event_out)
        .zip(&event_par_out)
        .all(|((a, b), c)| a.stats == b.stats && a.stats == c.stats);
    assert!(
        parity_ok,
        "event-driven and cycle-stepped engines reported different NocStats"
    );

    let speedup_engine = seed_secs / event_serial_secs.max(1e-12);
    let speedup_total = seed_secs / event_parallel_secs.max(1e-12);
    println!("seed loop (cycle-stepped, serial): {}", fmt_duration(seed_secs));
    println!(
        "event-driven, serial:              {}  ({:.2}x)",
        fmt_duration(event_serial_secs),
        speedup_engine
    );
    println!(
        "event-driven, {:>2} threads:         {}  ({:.2}x)",
        runner.threads(),
        fmt_duration(event_parallel_secs),
        speedup_total
    );
    println!("parity (identical NocStats): {parity_ok}");

    // ---- topology study: same traffic, different fabrics ---------------
    // One uniform-random slice per fabric (mesh / torus / prism), plus the
    // fabric's all-pairs mean hop distance — the structural quantity that
    // explains the latency gap between the rows.
    println!("\n== topology study: uniform_random per fabric ==");
    let mut topo_rows: Vec<Json> = Vec::new();
    let mut tt = Table::new(
        "per-topology uniform_random (8x8)",
        &[
            "topology",
            "avg hops",
            "rate",
            "wormhole lat",
            "smart lat",
            "smart speedup",
        ],
    );
    let mut avg_hops_of = [0.0f64; 3];
    for (ti, &tk) in TopologyKind::ALL.iter().enumerate() {
        let topo = AnyTopology::new(tk, 8, 8);
        let n = topo.nodes();
        let mut hop_sum = 0u64;
        for s in 0..n {
            for d in 0..n {
                hop_sum += topo.hops(s, d) as u64;
            }
        }
        let avg_hops = hop_sum as f64 / (n * (n - 1)) as f64;
        avg_hops_of[ti] = avg_hops;
        let mut ts = SyntheticSweep::new(topo, arch.hpc_max);
        ts.patterns = vec![Pattern::UniformRandom];
        ts.rates = vec![0.02, 0.05, 0.1];
        ts.base = base_cfg();
        ts.per_point_seeds = false;
        let out = ts.run(&runner);
        for pair in out.chunks(2) {
            let (w, s) = (&pair[0], &pair[1]);
            tt.row(&[
                tk.name().into(),
                fnum(avg_hops, 4),
                format!("{}", w.rate),
                fnum(w.stats.avg_latency, 1),
                fnum(s.stats.avg_latency, 1),
                fnum(w.stats.avg_latency / s.stats.avg_latency, 4),
            ]);
            topo_rows.push(Json::obj(vec![
                ("topology", tk.name().into()),
                ("avg_hops", avg_hops.into()),
                ("rate", w.rate.into()),
                ("wormhole_latency", w.stats.avg_latency.into()),
                ("smart_latency", s.stats.avg_latency.into()),
                (
                    "smart_speedup",
                    (w.stats.avg_latency / s.stats.avg_latency).into(),
                ),
            ]));
        }
    }
    tt.print();
    // Acceptance invariant (ISSUE 10): wrap links must shorten routes.
    assert!(
        avg_hops_of[1] < avg_hops_of[0],
        "torus avg hops {} must beat mesh {}",
        avg_hops_of[1],
        avg_hops_of[0]
    );

    // ---- machine-readable trajectory ----------------------------------
    let json_path = std::env::var("SMART_PIM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_noc.json".to_string());
    let json = bench_json(
        &outcomes,
        seed_secs,
        event_serial_secs,
        event_parallel_secs,
        runner.threads(),
        parity_ok,
        seed_out.len(),
        topo_rows,
    );
    match std::fs::write(&json_path, json.render_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn bench_json(
    outcomes: &[SyntheticOutcome],
    seed_secs: f64,
    event_serial_secs: f64,
    event_parallel_secs: f64,
    threads: usize,
    parity_ok: bool,
    perf_points: usize,
    topo_rows: Vec<Json>,
) -> Json {
    let epoch_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let grid: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("topology", "mesh".into()),
                ("pattern", o.pattern.name().into()),
                ("rate", o.rate.into()),
                ("backend", o.kind.name().into()),
                ("mean_latency", o.stats.avg_latency.into()),
                ("net_latency", o.stats.avg_net_latency.into()),
                ("reception_rate", o.stats.reception_rate.into()),
                ("completed", o.stats.completed.into()),
                ("dropped", o.stats.dropped.into()),
                ("saturated", o.stats.saturated().into()),
                ("wall_secs", o.wall_secs.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", "smart-pim/bench-noc/v1".into()),
        ("unix_time", epoch_secs.into()),
        ("mesh", "8x8".into()),
        ("threads", threads.into()),
        ("grid", Json::Arr(grid)),
        ("topologies", Json::Arr(topo_rows)),
        (
            "perf",
            Json::obj(vec![
                ("points", perf_points.into()),
                ("rates", Json::Arr(PERF_RATES.iter().map(|&r| r.into()).collect())),
                ("seed_loop_secs", seed_secs.into()),
                ("event_serial_secs", event_serial_secs.into()),
                ("event_parallel_secs", event_parallel_secs.into()),
                (
                    "speedup_engine",
                    (seed_secs / event_serial_secs.max(1e-12)).into(),
                ),
                (
                    "speedup_total",
                    (seed_secs / event_parallel_secs.max(1e-12)).into(),
                ),
                ("parity_ok", parity_ok.into()),
            ]),
        ),
    ])
}

fn sat(o: &SyntheticOutcome) -> &'static str {
    if o.stats.saturated() {
        " SAT"
    } else {
        ""
    }
}

fn fmt_sat(x: f64) -> String {
    if x.is_nan() {
        ">0.8".into()
    } else {
        format!("{x}")
    }
}
