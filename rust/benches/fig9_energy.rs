//! Bench: regenerate Fig. 9 — energy efficiency (TOPS/W) of each VGG, with
//! the per-image energy breakdown.

use smart_pim::cnn::VggVariant;
use smart_pim::config::{ArchConfig, NocKind, Scenario};
use smart_pim::metrics::{paper, Grid};
use smart_pim::util::bench::Bencher;
use smart_pim::util::table::{fnum, Table};

fn main() {
    let arch = ArchConfig::paper_node();
    println!("== regenerating Fig. 9 ==");
    let grid = Grid::run(
        &arch,
        &VggVariant::ALL,
        &[Scenario::ReplicationBatch],
        &[NocKind::Smart],
    );
    let mut t = Table::new(
        "Fig. 9 — energy efficiency (smart, scenario 4)",
        &["vgg", "TOPS/W ours", "TOPS/W paper", "E/img (mJ)", "core", "tile", "noc"],
    );
    for (i, v) in VggVariant::ALL.iter().enumerate() {
        let r = grid.get(*v, Scenario::ReplicationBatch, NocKind::Smart);
        t.row(&[
            v.name().into(),
            fnum(r.tops_per_watt, 4),
            fnum(paper::FIG9_TOPS_PER_WATT[i], 4),
            fnum(r.energy.total_mj(), 2),
            fnum(r.energy.core_mj, 2),
            fnum(r.energy.tile_mj, 2),
            fnum(r.energy.noc_mj, 3),
        ]);
    }
    t.print();
    println!("(paper's best case: VGG-E at 3.5914 TOPS/W)");

    println!("\n== timing: energy model alone ==");
    let mut b = Bencher::default();
    use smart_pim::cnn::vgg;
    use smart_pim::mapping::{NetworkMapping, ReplicationPlan};
    use smart_pim::power::EnergyModel;
    let net = vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
    let em = EnergyModel::new(&arch);
    let hops = vec![3.0; net.len()];
    b.bench("image_energy vggE", || em.image_energy(&net, &m, &hops));
}
