//! Metrics & reporting: speedup grids, geomeans, paper-style tables for
//! Figs. 5, 6, 8, 9, the searched-vs-Fig.7 planner comparison, and the
//! paper-headline scoreboard (`smart-pim reproduce`).

pub mod headline;

pub use headline::{scoreboard, HeadlineMetric, Scoreboard};

use crate::cnn::VggVariant;
use crate::config::{ArchConfig, NocKind, Scenario};
use crate::mapping::{MappingMode, MappingSelection, ReplicationPlan};
use crate::planner::{evaluate_candidates, CostModel, PlanCandidate, Planner, PlannerConfig};
use crate::sim::{evaluate, PerfReport};
use crate::sweep::SweepRunner;
use crate::util::stats::geomean;
use crate::util::table::{fnum, Table};

/// Full 5 x 4 x 3 benchmark grid (Sec. VI-B's 60 benchmarks).
pub struct Grid {
    /// One report per grid point, in run order.
    pub reports: Vec<PerfReport>,
}

impl Grid {
    /// Run every benchmark on a machine-sized [`SweepRunner`].
    /// `variants`/`scenarios`/`nocs` allow subsetting.
    pub fn run(
        arch: &ArchConfig,
        variants: &[VggVariant],
        scenarios: &[Scenario],
        nocs: &[NocKind],
    ) -> Self {
        Self::run_with(&SweepRunner::new(), arch, variants, scenarios, nocs)
    }

    /// Run every benchmark point of the grid through the sweep engine.
    /// Each (VGG, scenario, NoC) point is independent, so the 60-benchmark
    /// grid fans out across cores; results keep grid order.
    pub fn run_with(
        runner: &SweepRunner,
        arch: &ArchConfig,
        variants: &[VggVariant],
        scenarios: &[Scenario],
        nocs: &[NocKind],
    ) -> Self {
        let mut points = Vec::with_capacity(variants.len() * scenarios.len() * nocs.len());
        for &v in variants {
            for &s in scenarios {
                for &n in nocs {
                    points.push((v, s, n));
                }
            }
        }
        let reports = runner.run(&points, |_, &(v, s, n)| evaluate(v, s, n, arch));
        Self { reports }
    }

    /// The report of one (VGG, scenario, NoC) point; panics if absent.
    pub fn get(&self, v: VggVariant, s: Scenario, n: NocKind) -> &PerfReport {
        self.reports
            .iter()
            .find(|r| r.variant == v && r.scenario == s && r.noc == n)
            .expect("benchmark point missing from grid")
    }

    /// Fig. 5: per-VGG speedup of each scenario over scenario (1), within
    /// one NoC. Returns (table, per-scenario geomeans for (2),(3),(4)).
    pub fn fig5_table(&self, noc: NocKind, variants: &[VggVariant]) -> (Table, [f64; 3]) {
        let mut t = Table::new(
            format!("Fig. 5 — speedup vs scenario (1), NoC = {}", noc.name()),
            &["vgg", "(2)/(1)", "(3)/(1)", "(4)/(1)"],
        );
        let mut cols: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for &v in variants {
            let base = self.get(v, Scenario::Baseline, noc).fps;
            let s2 = self.get(v, Scenario::BatchOnly, noc).fps / base;
            let s3 = self.get(v, Scenario::ReplicationOnly, noc).fps / base;
            let s4 = self.get(v, Scenario::ReplicationBatch, noc).fps / base;
            cols[0].push(s2);
            cols[1].push(s3);
            cols[2].push(s4);
            t.row(&[
                v.name().into(),
                fnum(s2, 4),
                fnum(s3, 4),
                fnum(s4, 4),
            ]);
        }
        let geo = [geomean(&cols[0]), geomean(&cols[1]), geomean(&cols[2])];
        t.row(&[
            "geomean".into(),
            fnum(geo[0], 4),
            fnum(geo[1], 4),
            fnum(geo[2], 4),
        ]);
        (t, geo)
    }

    /// Fig. 6: per-VGG speedup of SMART and ideal over wormhole, within one
    /// scenario. Returns (table, [smart geomean, ideal geomean]).
    pub fn fig6_table(&self, scenario: Scenario, variants: &[VggVariant]) -> (Table, [f64; 2]) {
        let mut t = Table::new(
            format!(
                "Fig. 6 — speedup vs wormhole, scenario {}",
                scenario.label()
            ),
            &["vgg", "smart/wormhole", "ideal/wormhole"],
        );
        let mut cols: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
        for &v in variants {
            let base = self.get(v, scenario, NocKind::Wormhole).fps;
            let s = self.get(v, scenario, NocKind::Smart).fps / base;
            let i = self.get(v, scenario, NocKind::Ideal).fps / base;
            cols[0].push(s);
            cols[1].push(i);
            t.row(&[v.name().into(), fnum(s, 4), fnum(i, 4)]);
        }
        let geo = [geomean(&cols[0]), geomean(&cols[1])];
        t.row(&["geomean".into(), fnum(geo[0], 4), fnum(geo[1], 4)]);
        (t, geo)
    }

    /// Fig. 8: VGG-E TOPS (and FPS) for each NoC x scenario.
    pub fn fig8_table(&self) -> Table {
        let mut t = Table::new(
            "Fig. 8 — VGG E throughput: TOPS (FPS)",
            &["noc", "(1)", "(2)", "(3)", "(4)"],
        );
        for noc in NocKind::ALL {
            let mut cells = vec![noc.name().to_string()];
            for s in Scenario::ALL {
                let r = self.get(VggVariant::E, s, noc);
                cells.push(format!("{} ({} FPS)", fnum(r.tops, 4), fnum(r.fps, 0)));
            }
            t.row(&cells);
        }
        t
    }

    /// Fig. 9: energy efficiency per VGG (TOPS/W), best configuration.
    pub fn fig9_table(&self, variants: &[VggVariant]) -> Table {
        let mut t = Table::new("Fig. 9 — energy efficiency", &["vgg", "TOPS/W"]);
        for &v in variants {
            let r = self.get(v, Scenario::ReplicationBatch, NocKind::Smart);
            t.row(&[v.name().into(), fnum(r.tops_per_watt, 4)]);
        }
        t
    }
}

/// Searched-planner comparison: for each workload, the no-replication
/// baseline, the paper's hand-tuned Fig. 7 plan (VGGs only — branching
/// workloads have no hand plan and show `-`), and the searched plan under
/// the same tile budget and mapping mode — modeled and engine-measured
/// steady-state intervals side by side, plus the mapping selection the
/// search settled on. The table behind `smart-pim plan --compare` and
/// `report-all`. Workloads are independent, so the whole comparison
/// (search + engine replays) fans out across the sweep runner, one point
/// per workload.
pub fn planner_table(
    arch: &ArchConfig,
    nets: &[crate::cnn::Network],
    tile_budget: usize,
    batch_depth: u64,
    mapping: MappingMode,
    runner: &SweepRunner,
) -> Result<Table, String> {
    struct RowData {
        name: String,
        none_interval: u64,
        fig7: Option<crate::planner::PlanAssessment>,
        fig7_measured: Option<f64>,
        best: PlanCandidate,
    }
    let rows: Vec<Result<RowData, String>> = runner.run(nets, |_, net| {
        let cm = CostModel::new(net, arch);
        let none = cm.assess(&ReplicationPlan::none(net))?;
        // Only the VGGs carry a hand-tuned Fig. 7 plan to compare against
        // (always priced under the seed im2col mapping, as published).
        let fig7_plan = net.name.parse::<VggVariant>().ok().map(ReplicationPlan::fig7);
        let fig7 = match &fig7_plan {
            Some(p) => Some(cm.assess(p)?),
            None => None,
        };
        let searched = Planner::new(
            net,
            arch,
            PlannerConfig {
                tile_budget,
                batch_depth,
                mapping,
                ..PlannerConfig::default()
            },
        )
        .search()?;
        // Engine confirmation for every contender (serial here: the
        // workloads themselves are already fanned out by the runner).
        let mut cands: Vec<PlanCandidate> = Vec::new();
        if let (Some(p), Some(a)) = (fig7_plan, fig7.clone()) {
            cands.push(PlanCandidate {
                plan: p,
                mapping: MappingSelection::im2col(net.len()),
                assessment: a,
                measured_interval: None,
            });
        }
        cands.push(searched.best);
        evaluate_candidates(
            net,
            arch,
            &SweepRunner::with_threads(1),
            &mut cands,
            batch_depth.max(8),
        );
        let best = cands.pop().expect("searched candidate in, candidate out");
        let fig7_measured = cands.first().and_then(|c| c.measured_interval);
        Ok(RowData {
            name: net.name.clone(),
            none_interval: none.interval,
            fig7,
            fig7_measured,
            best,
        })
    });

    let mut t = Table::new(
        format!(
            "searched vs Fig. 7 vs no replication — interval in logical \
             cycles (budget {tile_budget} tiles, batch depth {batch_depth}, \
             mapping {mapping})"
        ),
        &[
            "network",
            "none",
            "fig7 model (tiles)",
            "fig7 engine",
            "searched model (tiles)",
            "searched engine",
            "mapping",
            "speedup vs fig7|none",
        ],
    );
    let fmt_measured = |m: Option<f64>| m.map(|x| fnum(x, 0)).unwrap_or_else(|| "-".into());
    for row in rows {
        let r = row?;
        // Branching workloads have no hand plan: their speedup column is
        // searched vs no replication.
        let baseline = r
            .fig7
            .as_ref()
            .map(|f| f.interval)
            .unwrap_or(r.none_interval);
        t.row(&[
            r.name,
            format!("{}", r.none_interval),
            r.fig7
                .as_ref()
                .map(|f| format!("{} ({})", f.interval, f.tiles))
                .unwrap_or_else(|| "-".into()),
            fmt_measured(r.fig7_measured),
            format!(
                "{} ({})",
                r.best.assessment.interval, r.best.assessment.tiles
            ),
            fmt_measured(r.best.measured_interval),
            r.best.mapping.summary(),
            fnum(baseline as f64 / r.best.assessment.interval as f64, 2),
        ]);
    }
    Ok(t)
}

/// Cluster-serving rows for `report-all` and `smart-pim cluster`-adjacent
/// reporting: a small fleet-size x offered-QPS grid of VGG-E Fig. 7
/// replicas under seeded Poisson arrivals (per-node steady-state capacity
/// is ~1042 req/s — the paper's Fig. 8 FPS anchor), with SLO metrics per
/// point. Points are independent simulations, so the grid fans out on the
/// sweep runner.
pub fn cluster_table(arch: &ArchConfig, runner: &SweepRunner) -> Result<Table, String> {
    use crate::cluster::{rate_from_qps, simulate, ClusterConfig, NodeModel};

    let net = crate::cnn::vgg::build(VggVariant::E);
    let plan = ReplicationPlan::fig7(VggVariant::E);
    let model = NodeModel::from_workload(&net, arch, &plan)?;
    // Loads from comfortable to near-saturation (per-node cap ~1042 rps).
    let points: [(usize, f64); 4] = [(1, 500.0), (2, 1500.0), (4, 3000.0), (4, 4000.0)];
    let stats = runner.run(&points, |_, &(nodes, qps)| {
        simulate(
            &model,
            &ClusterConfig {
                nodes,
                rate_per_cycle: rate_from_qps(qps, arch.logical_cycle_ns),
                horizon_cycles: 3_000_000,
                ..ClusterConfig::default()
            },
        )
    });
    let mut t = Table::new(
        "cluster serving — VGG-E Fig. 7 replicas, poisson arrivals, \
         rr routing (latency in logical cycles)",
        &[
            "nodes", "qps", "offered", "p50", "p99", "p99 (ms)", "throughput (req/s)",
            "util", "rejected",
        ],
    );
    for ((nodes, qps), s) in points.iter().zip(&stats) {
        t.row(&[
            nodes.to_string(),
            format!("{qps}"),
            s.offered.to_string(),
            s.latency.p50().to_string(),
            s.latency.p99().to_string(),
            fnum(s.latency.p99() as f64 * arch.logical_cycle_ns / 1e6, 3),
            fnum(s.throughput_rps(arch.logical_cycle_ns), 1),
            format!("{:.1} %", 100.0 * s.mean_utilization()),
            format!("{:.1} %", 100.0 * s.rejection_rate()),
        ]);
    }
    Ok(t)
}

/// Multi-tenant serving rows for `report-all`: a residency-policy x
/// fleet-size grid of a two-model fleet (VGG-A on its Fig. 7 plan +
/// ResNet-18 unreplicated) under an anti-phase diurnal tenant mix — the
/// swap-storm scenario. Reprogram-on-miss rows carry the model-swap count
/// and ReRAM weight-programming energy; dedicated-partition rows are
/// swap-free by construction but reject when a partition saturates.
pub fn tenant_table(arch: &ArchConfig, runner: &SweepRunner) -> Result<Table, String> {
    use crate::cluster::{
        rate_from_qps, simulate_tenants, MixMode, NodeModel, Residency, TenantConfig,
        TenantWorkload,
    };
    use crate::cnn::Network;
    use crate::mapping::NetworkMapping;
    use crate::power::WriteCost;

    let tenant = |net: &Network,
                  plan: &ReplicationPlan,
                  weight: f64|
     -> Result<TenantWorkload, String> {
        let model = NodeModel::from_workload(net, arch, plan)?;
        let mapping = NetworkMapping::build(net, arch, plan)?;
        let write = WriteCost::of_mapping(net, &mapping, arch);
        Ok(TenantWorkload::from_model(&net.name, weight, &model, write))
    };
    let vgg_a = crate::cnn::vgg::build(VggVariant::A);
    let resnet = crate::cnn::workload("resnet18")?;
    let tenants = vec![
        tenant(&vgg_a, &ReplicationPlan::fig7(VggVariant::A), 1.0)?,
        tenant(&resnet, &ReplicationPlan::none(&resnet), 1.0)?,
    ];

    let points: [(Residency, usize); 4] = [
        (Residency::Reprogram, 8),
        (Residency::Reprogram, 16),
        (Residency::Partition, 8),
        (Residency::Partition, 16),
    ];
    let stats = runner.run(&points, |_, &(residency, nodes)| {
        simulate_tenants(
            &tenants,
            &TenantConfig {
                nodes,
                residency,
                rate_per_cycle: rate_from_qps(1_500.0, arch.logical_cycle_ns),
                mix: MixMode::Diurnal { period: 1_000_000 },
                horizon_cycles: 3_000_000,
                ..TenantConfig::default()
            },
        )
    });
    let mut t = Table::new(
        "multi-tenant serving — VGG-A fig7 + ResNet-18, diurnal mix, jsq \
         routing (latency in logical cycles)",
        &[
            "residency", "nodes", "tenant", "offered", "p50", "p99", "rejected",
            "swaps", "swap energy (J)",
        ],
    );
    for ((residency, nodes), r) in points.iter().zip(stats) {
        let s = r?;
        for ts in &s.tenants {
            t.row(&[
                residency.name().to_string(),
                nodes.to_string(),
                ts.name.clone(),
                ts.offered.to_string(),
                ts.latency.p50().to_string(),
                ts.latency.p99().to_string(),
                ts.rejected.to_string(),
                ts.swaps.to_string(),
                fnum(ts.swap_energy_j, 2),
            ]);
        }
    }
    Ok(t)
}

/// Build the workload list for the comparison tables: all five VGGs plus
/// the ResNets.
pub fn all_workloads() -> Vec<crate::cnn::Network> {
    crate::cnn::workload_names()
        .into_iter()
        .map(|n| crate::cnn::workload(n).expect("shipped workload builds"))
        .collect()
}

/// Paper-reported reference values, used by tests and EXPERIMENTS.md to
/// report paper-vs-measured side by side.
pub mod paper {
    /// Fig. 5 geomeans: (2)/(1), (3)/(1), (4)/(1).
    pub const FIG5_GEOMEANS: [f64; 3] = [1.0309, 10.1788, 13.6903];
    /// Fig. 6 geomean of ideal vs wormhole.
    pub const FIG6_IDEAL_GEOMEAN: f64 = 1.0809;
    /// The abstract's "1.08x" SMART-over-wormhole claim. The paper prints
    /// the 1.0809 geomean as ideal/wormhole (Fig. 6) and treats SMART as
    /// tracking ideal (single-cycle multi-hop paths), so the abstract
    /// attributes the same figure to SMART; kept as its own constant so
    /// the scoreboard's attribution is explicit.
    pub const FIG6_SMART_GEOMEAN: f64 = FIG6_IDEAL_GEOMEAN;
    /// Fig. 8 VGG-E best case: SMART scenario (4).
    pub const FIG8_BEST_TOPS: f64 = 40.4027;
    /// Fig. 8 VGG-E best-case FPS.
    pub const FIG8_BEST_FPS: f64 = 1029.0;
    /// Fig. 8 wormhole scenario (4).
    pub const FIG8_WORMHOLE_TOPS: f64 = 36.7904;
    /// Fig. 9 energy efficiency (A-E).
    pub const FIG9_TOPS_PER_WATT: [f64; 5] = [2.8841, 2.5538, 2.5846, 3.1271, 3.5914];
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::vgg;

    #[test]
    fn small_grid_tables_render() {
        let arch = ArchConfig::paper_node();
        let variants = [VggVariant::A];
        let grid = Grid::run(
            &arch,
            &variants,
            &[Scenario::Baseline, Scenario::ReplicationBatch],
            &[NocKind::Ideal],
        );
        assert_eq!(grid.reports.len(), 2);
        let r = grid.get(VggVariant::A, Scenario::Baseline, NocKind::Ideal);
        assert!(r.fps > 0.0);
    }

    #[test]
    fn parallel_grid_matches_serial() {
        use crate::sweep::SweepRunner;
        let arch = ArchConfig::paper_node();
        let variants = [VggVariant::A];
        let scenarios = [Scenario::Baseline, Scenario::ReplicationBatch];
        let nocs = [NocKind::Ideal];
        let serial =
            Grid::run_with(&SweepRunner::with_threads(1), &arch, &variants, &scenarios, &nocs);
        let parallel =
            Grid::run_with(&SweepRunner::with_threads(4), &arch, &variants, &scenarios, &nocs);
        assert_eq!(serial.reports.len(), parallel.reports.len());
        for (a, b) in serial.reports.iter().zip(&parallel.reports) {
            assert_eq!(a.variant, b.variant);
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.noc, b.noc);
            assert_eq!(a.fps, b.fps, "{:?} {:?}", a.variant, a.scenario);
        }
    }

    #[test]
    fn planner_table_renders() {
        // Rendering only — the searched-dominates-Fig.7 property is gated
        // by rust/tests/golden_planner.rs, not this test.
        let arch = ArchConfig::paper_node();
        let t = planner_table(
            &arch,
            &[vgg::build(VggVariant::A)],
            320,
            8,
            MappingMode::Im2col,
            &SweepRunner::with_threads(2),
        )
        .unwrap();
        assert_eq!(t.n_rows(), 1);
        let out = t.render();
        assert!(out.contains("vggA"), "{out}");
        assert!(out.contains("searched"), "{out}");
        assert!(out.contains("im2col"), "{out}");
    }

    #[test]
    fn planner_table_handles_branching_workloads() {
        // A ResNet row has no Fig. 7 hand plan: the fig7 columns render "-"
        // and the speedup falls back to searched-vs-none.
        let arch = ArchConfig::paper_node();
        let t = planner_table(
            &arch,
            &[crate::cnn::workload("resnet18").unwrap()],
            320,
            8,
            MappingMode::Auto,
            &SweepRunner::with_threads(2),
        )
        .unwrap();
        let out = t.render();
        assert!(out.contains("resnet18"), "{out}");
        assert!(out.contains('-'), "{out}");
    }

    #[test]
    fn cluster_table_renders_slo_columns() {
        let arch = ArchConfig::paper_node();
        let t = cluster_table(&arch, &SweepRunner::with_threads(2)).unwrap();
        assert_eq!(t.n_rows(), 4);
        let out = t.render();
        assert!(out.contains("p99"), "{out}");
        assert!(out.contains("throughput"), "{out}");
    }

    #[test]
    fn tenant_table_renders_both_residency_policies() {
        let arch = ArchConfig::paper_node();
        let t = tenant_table(&arch, &SweepRunner::with_threads(2)).unwrap();
        // 4 grid points x 2 tenants.
        assert_eq!(t.n_rows(), 8);
        let out = t.render();
        assert!(out.contains("reprogram"), "{out}");
        assert!(out.contains("partition"), "{out}");
        assert!(out.contains("vggA"), "{out}");
        assert!(out.contains("resnet18"), "{out}");
    }

    #[test]
    fn all_workloads_has_vggs_and_resnets() {
        let w = all_workloads();
        assert_eq!(w.len(), 7);
        assert_eq!(w[0].name, "vggA");
        assert_eq!(w[6].name, "resnet34");
    }

    #[test]
    fn paper_constants_sane() {
        assert!(paper::FIG5_GEOMEANS[2] > paper::FIG5_GEOMEANS[1]);
        assert!(paper::FIG8_BEST_TOPS < 41.0);
        assert_eq!(paper::FIG9_TOPS_PER_WATT.len(), 5);
    }
}
