//! The paper-headline scoreboard behind `smart-pim reproduce`: the five
//! abstract-level claims — best-case TOPS, FPS and TOPS/W, the ~14x
//! pipelining speedup, and the ~1.08x SMART-over-wormhole speedup — plus
//! the VW-SDK mapping-search consistency gate, each recomputed through the
//! full model stack and checked against a pinned tolerance band, then
//! written to `BENCH_headline.json`.
//!
//! Band provenance (DESIGN.md §5): the FPS/TOPS bands bracket the ideal
//! calibration anchor (1042 FPS at the 3136-cycle VGG-E beat) from below,
//! since the SMART co-simulation can only throttle it; the TOPS/W band
//! brackets the arithmetic energy model (3.50 TOPS/W for VGG-E Fig. 7,
//! engine-independent); the speedup bands are the paper-band integration
//! ranges `tests/integration_pipeline.rs` has pinned since the grid first
//! ran. A band failure therefore means a *regression*, not a noisy run —
//! every quantity here is deterministic.

use crate::cnn::VggVariant;
use crate::config::{ArchConfig, NocKind, Scenario};
use crate::sweep::SweepRunner;
use crate::util::stats::geomean;
use crate::util::table::{fnum, Table};
use crate::util::Json;

use super::{paper, Grid};

/// One headline claim: the model's value vs the paper's, with the pinned
/// acceptance band for the model.
#[derive(Debug, Clone)]
pub struct HeadlineMetric {
    /// Stable machine key (JSON field-friendly).
    pub key: &'static str,
    /// Human-readable row label.
    pub label: &'static str,
    /// The value this model produces.
    pub model: f64,
    /// The value the paper reports.
    pub paper: f64,
    /// Inclusive lower edge of the model's acceptance band.
    pub lo: f64,
    /// Inclusive upper edge of the model's acceptance band.
    pub hi: f64,
}

impl HeadlineMetric {
    /// Does the model value sit inside its pinned band?
    pub fn pass(&self) -> bool {
        self.model.is_finite() && self.lo <= self.model && self.model <= self.hi
    }
}

/// The full scoreboard: the five paper-headline metrics in abstract order
/// plus the VW-SDK search gate.
#[derive(Debug, Clone)]
pub struct Scoreboard {
    /// The metrics, in report order.
    pub metrics: Vec<HeadlineMetric>,
}

/// Acceptance bands, pinned. Each constant documents its derivation.
pub mod bands {
    /// Best-case TOPS (VGG-E, scenario 4, SMART). The ideal-NoC anchor is
    /// 40.92 TOPS (19.63 GMACs x 1042 FPS); SMART may throttle a few
    /// percent but must stay above the paper's wormhole result (36.79).
    pub const TOPS: (f64, f64) = (37.5, 41.5);
    /// Best-case FPS: the 1042-FPS calibration anchor minus the same
    /// few-percent SMART allowance.
    pub const FPS: (f64, f64) = (950.0, 1065.0);
    /// Best-case TOPS/W (VGG-E, scenario 4): the arithmetic energy model
    /// yields 3.50, engine-independent; the band brackets it against the
    /// paper's 3.5914.
    pub const TOPS_PER_WATT: (f64, f64) = (3.2, 3.8);
    /// Geomean speedup of scenario (4) over (1) across the five VGGs on
    /// SMART — the abstract's "up to 14x better performance" claim; same
    /// band `integration_pipeline.rs::fig5_geomeans_in_paper_band` pins.
    pub const SCENARIO_SPEEDUP: (f64, f64) = (11.0, 20.0);
    /// Geomean speedup of SMART over wormhole in scenario (4) — the
    /// abstract's 1.08x claim. The model keeps the gap in the single-digit
    /// percent range (wormhole sits just past the conv1/conv2 hotspot's
    /// stability edge); the floor allows the sub-percent sampling jitter
    /// the NoC-ordering tests tolerate on unsaturated variants, the cap is
    /// the ideal/wormhole plausibility bound.
    pub const SMART_SPEEDUP: (f64, f64) = (0.99, 1.35);
    /// Geomean throughput ratio of the VW-SDK joint search over the
    /// im2col-only search at the paper's 320-tile budget (throughput is
    /// 1/interval at steady state, so this is the modeled searched-interval
    /// ratio im2col/vwsdk). The column-conservation law
    /// (`mapping::backend` module doc) makes the two searches tie exactly
    /// at the paper node's 128-column geometry, so the floor is a hard
    /// "VW-SDK never loses"; the cap bounds plausibility.
    pub const VWSDK_SEARCH: (f64, f64) = (1.0, 1.5);
}

/// Compute the scoreboard: one 20-point benchmark grid (5 VGGs x
/// scenarios {(1), (4)} x NoCs {wormhole, smart}) fanned out on `runner`,
/// then the five headline reductions plus the VW-SDK search gate (a
/// model-only pair of planner searches per VGG, no engine runs).
pub fn scoreboard(arch: &ArchConfig, runner: &SweepRunner) -> Scoreboard {
    let grid = Grid::run_with(
        runner,
        arch,
        &VggVariant::ALL,
        &[Scenario::Baseline, Scenario::ReplicationBatch],
        &[NocKind::Wormhole, NocKind::Smart],
    );
    let best = grid.get(VggVariant::E, Scenario::ReplicationBatch, NocKind::Smart);
    let scenario_ratios: Vec<f64> = VggVariant::ALL
        .iter()
        .map(|&v| {
            grid.get(v, Scenario::ReplicationBatch, NocKind::Smart).fps
                / grid.get(v, Scenario::Baseline, NocKind::Smart).fps
        })
        .collect();
    let smart_ratios: Vec<f64> = VggVariant::ALL
        .iter()
        .map(|&v| {
            grid.get(v, Scenario::ReplicationBatch, NocKind::Smart).fps
                / grid.get(v, Scenario::ReplicationBatch, NocKind::Wormhole).fps
        })
        .collect();
    // Modeled searched-interval ratio im2col/vwsdk per VGG: throughput is
    // 1/interval, so >= 1 means the VW-SDK joint search never loses.
    let vwsdk_ratios: Vec<f64> = VggVariant::ALL
        .iter()
        .map(|&v| {
            let net = crate::cnn::vgg::build(v);
            let seed = crate::planner::plan_for(&net, arch, arch.total_tiles())
                .expect("im2col search");
            let vw = crate::planner::plan_for_mapped(
                &net,
                arch,
                arch.total_tiles(),
                crate::mapping::MappingMode::VwSdk,
            )
            .expect("vwsdk search");
            seed.best.assessment.interval as f64 / vw.best.assessment.interval as f64
        })
        .collect();
    let metric = |key, label, model, paper, (lo, hi): (f64, f64)| HeadlineMetric {
        key,
        label,
        model,
        paper,
        lo,
        hi,
    };
    Scoreboard {
        metrics: vec![
            metric(
                "best_tops",
                "best-case TOPS (VGG-E, scenario 4, SMART)",
                best.tops,
                paper::FIG8_BEST_TOPS,
                bands::TOPS,
            ),
            metric(
                "best_fps",
                "best-case FPS (VGG-E, scenario 4, SMART)",
                best.fps,
                paper::FIG8_BEST_FPS,
                bands::FPS,
            ),
            metric(
                "best_tops_per_watt",
                "best-case TOPS/W (VGG-E, scenario 4)",
                best.tops_per_watt,
                paper::FIG9_TOPS_PER_WATT[4],
                bands::TOPS_PER_WATT,
            ),
            metric(
                "scenario_speedup",
                "pipelining speedup, geomean (4)/(1)",
                geomean(&scenario_ratios),
                paper::FIG5_GEOMEANS[2],
                bands::SCENARIO_SPEEDUP,
            ),
            metric(
                "smart_speedup",
                "SMART/wormhole speedup, geomean @ (4)",
                geomean(&smart_ratios),
                paper::FIG6_SMART_GEOMEAN,
                bands::SMART_SPEEDUP,
            ),
            metric(
                "vwsdk_search_ratio",
                "VW-SDK/im2col searched throughput, geomean",
                geomean(&vwsdk_ratios),
                // Consistency gate, not a paper figure: the floor is the
                // "never loses" bound the conservation law guarantees.
                1.0,
                bands::VWSDK_SEARCH,
            ),
        ],
    }
}

impl Scoreboard {
    /// True when every metric sits inside its band.
    pub fn all_pass(&self) -> bool {
        self.metrics.iter().all(|m| m.pass())
    }

    /// The failing metrics' keys (empty on a clean board).
    pub fn failures(&self) -> Vec<&'static str> {
        self.metrics
            .iter()
            .filter(|m| !m.pass())
            .map(|m| m.key)
            .collect()
    }

    /// The paper-vs-model table `smart-pim reproduce` prints.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "paper-headline scoreboard — model vs paper, pinned bands",
            &["metric", "model", "paper", "band", "status"],
        );
        for m in &self.metrics {
            t.row(&[
                m.label.into(),
                fnum(m.model, 4),
                fnum(m.paper, 4),
                format!("[{}, {}]", fnum(m.lo, 2), fnum(m.hi, 2)),
                if m.pass() { "PASS" } else { "FAIL" }.into(),
            ]);
        }
        t
    }

    /// The `BENCH_headline.json` document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "metrics",
                Json::Arr(
                    self.metrics
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("key", m.key.into()),
                                ("label", m.label.into()),
                                ("model", m.model.into()),
                                ("paper", m.paper.into()),
                                ("band_lo", m.lo.into()),
                                ("band_hi", m.hi.into()),
                                ("pass", m.pass().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("all_pass", self.all_pass().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(model: f64, lo: f64, hi: f64) -> HeadlineMetric {
        HeadlineMetric {
            key: "k",
            label: "l",
            model,
            paper: 1.0,
            lo,
            hi,
        }
    }

    #[test]
    fn band_edges_are_inclusive() {
        assert!(fake(1.0, 1.0, 2.0).pass());
        assert!(fake(2.0, 1.0, 2.0).pass());
        assert!(!fake(0.999, 1.0, 2.0).pass());
        assert!(!fake(2.001, 1.0, 2.0).pass());
        assert!(!fake(f64::NAN, 0.0, 2.0).pass(), "NaN must fail, not pass");
    }

    #[test]
    fn scoreboard_reports_failures_and_json() {
        let b = Scoreboard {
            metrics: vec![fake(1.5, 1.0, 2.0), fake(5.0, 1.0, 2.0)],
        };
        assert!(!b.all_pass());
        assert_eq!(b.failures(), vec!["k"]);
        let j = b.to_json().render();
        assert!(j.contains("\"all_pass\":false"), "{j}");
        assert!(j.contains("\"band_lo\":1"), "{j}");
        let t = b.table().render();
        assert!(t.contains("FAIL") && t.contains("PASS"), "{t}");
    }

    // The full-grid scoreboard run is pinned by tests/golden_energy.rs
    // (one 20-point grid under `cargo test`, the same scale as the
    // existing paper-band integration tests); the CI `reproduce` smoke
    // step runs it a second time to gate the CLI surface and the
    // BENCH_headline.json artifact path specifically.
}
