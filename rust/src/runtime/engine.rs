//! PJRT execution engine: load AOT-compiled HLO-text artifacts and run them
//! on the CPU PJRT client — the request-path compute of the serving
//! coordinator. Python never runs here (DESIGN.md §2).
//!
//! The PJRT path needs the `xla` crate (xla-rs + a local `xla_extension`
//! install), which the offline build environment does not ship. It is
//! therefore gated behind the `pjrt` cargo feature (DESIGN.md §2): without
//! it this module compiles to API-identical stubs whose constructors
//! return a clear error, so the coordinator, CLI and tests build and run
//! everywhere and degrade gracefully where PJRT is absent.

use std::path::{Path, PathBuf};

use crate::bail;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;
use crate::util::error::Result;

use super::weights::WeightsFile;

// ---------------------------------------------------------------------------
// Real PJRT-backed implementation (`--features pjrt`).
// ---------------------------------------------------------------------------

/// Literal tensor handed to an executable.
#[cfg(feature = "pjrt")]
pub type Literal = xla::Literal;

/// A compiled executable plus its metadata.
#[cfg(feature = "pjrt")]
pub struct Executable {
    /// Executable name (artifact stem).
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.into(),
        })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory the artifacts are loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load and compile `<artifacts_dir>/<name>.hlo.txt`.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see python/compile/aot.py).
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF-8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable {
            name: name.to_string(),
            exe,
        })
    }

    /// Load the weights container for a model.
    pub fn load_weights(&self, file: &str) -> Result<WeightsFile> {
        WeightsFile::load(&self.artifacts_dir.join(file))
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with literal inputs; returns the flattened f32 output (the
    /// AOT graphs are lowered with `return_tuple=True`, so the single
    /// result is unwrapped from a 1-tuple).
    pub fn run_f32(&self, inputs: &[Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()
            .context("device-to-host transfer")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple")?;
        out.to_vec::<f32>().context("reading f32 output")
    }

    /// Execute and return the flattened i32 output.
    pub fn run_i32(&self, inputs: &[Literal]) -> Result<Vec<i32>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()
            .context("device-to-host transfer")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple")?;
        out.to_vec::<i32>().context("reading i32 output")
    }
}

/// Build an f32 literal of the given shape from a flat slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .context("reshaping f32 literal")
}

/// Build an i32 literal of the given shape from a flat slice.
#[cfg(feature = "pjrt")]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .context("reshaping i32 literal")
}

// ---------------------------------------------------------------------------
// Stub implementation (default build): same API, clear runtime errors.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str =
    "smart_pim was built without the `pjrt` feature — PJRT execution is unavailable \
     (enable the feature and provide the `xla` crate; see DESIGN.md §2)";

/// Literal tensor handed to an executable (stub: shape bookkeeping only).
#[cfg(not(feature = "pjrt"))]
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
}

#[cfg(not(feature = "pjrt"))]
impl Literal {
    /// Mirror of `xla::Literal::reshape` so callers type-check unchanged.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let old: i64 = self.dims.iter().product();
        let new: i64 = dims.iter().product();
        if old != new {
            bail!("cannot reshape {:?} to {:?}", self.dims, dims);
        }
        Ok(Literal {
            dims: dims.to_vec(),
        })
    }
}

/// A compiled executable plus its metadata (stub).
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    /// Executable name (artifact stem).
    pub name: String,
}

/// The PJRT runtime (stub: construction always fails with a clear error).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    artifacts_dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub constructor: always fails with the no-PJRT error.
    pub fn new(_artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        bail!("{NO_PJRT}");
    }

    /// Stub platform name.
    pub fn platform(&self) -> String {
        "unavailable (no pjrt feature)".to_string()
    }

    /// Directory the artifacts would be loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Stub loader: always fails with the no-PJRT error.
    pub fn load(&self, _name: &str) -> Result<Executable> {
        bail!("{NO_PJRT}");
    }

    /// Load the weights container for a model (pure Rust: works without
    /// PJRT, but unreachable here since construction fails).
    pub fn load_weights(&self, file: &str) -> Result<WeightsFile> {
        WeightsFile::load(&self.artifacts_dir.join(file))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Stub executor: always fails with the no-PJRT error.
    pub fn run_f32(&self, _inputs: &[Literal]) -> Result<Vec<f32>> {
        bail!("{NO_PJRT}");
    }

    /// Stub executor: always fails with the no-PJRT error.
    pub fn run_i32(&self, _inputs: &[Literal]) -> Result<Vec<i32>> {
        bail!("{NO_PJRT}");
    }
}

/// Build an f32 literal of the given shape from a flat slice.
#[cfg(not(feature = "pjrt"))]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    Ok(Literal {
        dims: dims.to_vec(),
    })
}

/// Build an i32 literal of the given shape from a flat slice.
#[cfg(not(feature = "pjrt"))]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    Ok(Literal {
        dims: dims.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The heavier artifact round-trip tests live in
    // rust/tests/integration_runtime.rs; here we only cover the pure
    // helpers so `cargo test --lib` stays artifact-independent.

    #[test]
    fn literal_shape_mismatch_rejected() {
        let r = literal_f32(&[1.0, 2.0, 3.0], &[2, 2]);
        assert!(r.is_err());
        let r = literal_i32(&[1, 2, 3, 4], &[2, 2]);
        assert!(r.is_ok());
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = match Runtime::new("/nonexistent-dir") {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this build/environment
        };
        let err = match rt.load("nope") {
            Ok(_) => panic!("load of missing artifact succeeded"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::new("artifacts").err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_literal_reshape_checks_element_count() {
        let l = literal_i32(&[1, 2, 3, 4], &[4]).unwrap();
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
