//! PJRT execution engine: load AOT-compiled HLO-text artifacts and run them
//! on the CPU PJRT client — the request-path compute of the serving
//! coordinator. Python never runs here (DESIGN.md §2).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::weights::WeightsFile;

/// A compiled executable plus its metadata.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.into(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load and compile `<artifacts_dir>/<name>.hlo.txt`.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see python/compile/aot.py).
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF-8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable {
            name: name.to_string(),
            exe,
        })
    }

    /// Load the weights container for a model.
    pub fn load_weights(&self, file: &str) -> Result<WeightsFile> {
        WeightsFile::load(&self.artifacts_dir.join(file))
    }
}

impl Executable {
    /// Execute with literal inputs; returns the flattened f32 output (the
    /// AOT graphs are lowered with `return_tuple=True`, so the single
    /// result is unwrapped from a 1-tuple).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute and return the flattened i32 output.
    pub fn run_i32(&self, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} != {} elements", dims, data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The heavier artifact round-trip tests live in
    // rust/tests/integration_runtime.rs; here we only cover the pure
    // helpers so `cargo test --lib` stays artifact-independent.

    #[test]
    fn literal_shape_mismatch_rejected() {
        let r = literal_f32(&[1.0, 2.0, 3.0], &[2, 2]);
        assert!(r.is_err());
        let r = literal_i32(&[1, 2, 3, 4], &[2, 2]);
        assert!(r.is_ok());
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = match Runtime::new("/nonexistent-dir") {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT in this environment; covered elsewhere
        };
        let err = match rt.load("nope") {
            Ok(_) => panic!("load of missing artifact succeeded"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
