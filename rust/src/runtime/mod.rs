//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes them on the CPU
//! PJRT client. This is the only compute path at serving time — Python is
//! build-time only.

pub mod engine;
pub mod vgg_tiny;
pub mod weights;

pub use engine::{literal_f32, literal_i32, Executable, Runtime};
pub use vgg_tiny::VggTiny;
pub use weights::{Tensor, WeightsFile};
