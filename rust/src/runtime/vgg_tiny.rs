//! Typed wrapper for the tiny-VGG inference artifacts: the quantized CNN
//! whose every GEMM runs through the bit-serial crossbar Pallas kernel
//! (python/compile/model.py), AOT-lowered at batch sizes 1 and 4.

use crate::util::error::{Context, Result};
use crate::{bail, format_err};

use super::engine::{literal_f32, literal_i32, Executable, Runtime};
use super::weights::WeightsFile;

/// Input image geometry fixed by the artifact.
pub const IMAGE_HW: usize = 32;
/// Input channels.
pub const IMAGE_CH: usize = 3;
/// Flattened input length.
pub const IMAGE_LEN: usize = IMAGE_HW * IMAGE_HW * IMAGE_CH;
/// Output classes (CIFAR-10).
pub const CLASSES: usize = 10;

/// The tiny-VGG model: compiled executables for batch 1 and 4 plus the
/// weight literals (shared across calls).
pub struct VggTiny {
    exe_b1: Executable,
    exe_b4: Executable,
    weights: WeightsFile,
}

impl VggTiny {
    /// Supported batch sizes, largest first (the batcher prefers the
    /// largest executable it can fill).
    pub const BATCH_SIZES: [usize; 2] = [4, 1];

    /// Load every tiny-VGG executable from the runtime's artifacts.
    pub fn load(rt: &Runtime) -> Result<Self> {
        let exe_b1 = rt.load("vgg_tiny_b1")?;
        let exe_b4 = rt.load("vgg_tiny_b4")?;
        let weights = rt.load_weights("weights_vgg_tiny.bin")?;
        if weights.tensors.len() != 5 {
            bail!("expected 5 weight tensors, got {}", weights.tensors.len());
        }
        Ok(Self {
            exe_b1,
            exe_b4,
            weights,
        })
    }

    /// Run inference on a batch of images (flattened `B x 32 x 32 x 3`,
    /// values in [0,1]). `images.len()` must be `B * IMAGE_LEN` with B in
    /// {1, 4}. Returns `B x CLASSES` logits.
    pub fn infer(&self, images: &[f32]) -> Result<Vec<f32>> {
        let b = images.len() / IMAGE_LEN;
        if b * IMAGE_LEN != images.len() {
            bail!("input length {} not a whole batch", images.len());
        }
        let exe = match b {
            1 => &self.exe_b1,
            4 => &self.exe_b4,
            _ => bail!("unsupported batch size {b} (artifacts exist for 1 and 4)"),
        };
        let mut inputs = Vec::with_capacity(1 + self.weights.tensors.len());
        inputs.push(literal_f32(
            images,
            &[b as i64, IMAGE_HW as i64, IMAGE_HW as i64, IMAGE_CH as i64],
        )?);
        for t in &self.weights.tensors {
            let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
            inputs.push(literal_i32(&t.data, &dims)?);
        }
        let out = exe.run_f32(&inputs).context("tiny-VGG inference")?;
        if out.len() != b * CLASSES {
            bail!("expected {} logits, got {}", b * CLASSES, out.len());
        }
        Ok(out)
    }

    /// Argmax per image.
    pub fn classify(&self, images: &[f32]) -> Result<Vec<usize>> {
        let logits = self.infer(images)?;
        Ok(logits
            .chunks_exact(CLASSES)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect())
    }
}

/// Read the `test_image_b{B}.txt` / `expected_logits_b{B}.txt` golden pair
/// written by aot.py (one whitespace-separated row per image).
pub fn load_golden(rt: &Runtime, batch: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    let img_path = rt
        .artifacts_dir()
        .join(format!("test_image_b{batch}.txt"));
    let logit_path = rt
        .artifacts_dir()
        .join(format!("expected_logits_b{batch}.txt"));
    let parse = |path: &std::path::Path| -> Result<Vec<f32>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        text.split_whitespace()
            .map(|t| t.parse::<f32>().map_err(|e| format_err!("{t:?}: {e}")))
            .collect()
    };
    Ok((parse(&img_path)?, parse(&logit_path)?))
}

#[cfg(test)]
mod tests {
    // Artifact-dependent round trips live in
    // rust/tests/integration_runtime.rs. Pure-shape checks only here.
    use super::*;

    #[test]
    fn constants_consistent() {
        assert_eq!(IMAGE_LEN, 3072);
        assert_eq!(VggTiny::BATCH_SIZES, [4, 1]);
    }
}
