//! Loader for `artifacts/weights_*.bin` — the tensor container written by
//! `python/compile/aot.py` (`write_weights_bin`).
//!
//! Format (little-endian): magic `u32` = 0x534D5057 ("SMPW"), tensor count
//! `u32`, then per tensor: name length `u32`, name bytes, ndim `u32`, dims
//! `u32 x ndim`, row-major `i32` data.

use std::io::Read;
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

/// Container magic: `"SMPW"`.
pub const WEIGHTS_MAGIC: u32 = 0x534D_5057;

/// One int32 tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Tensor name (graph parameter it feeds).
    pub name: String,
    /// Shape, row-major.
    pub dims: Vec<usize>,
    /// Quantized values.
    pub data: Vec<i32>,
}

impl Tensor {
    /// Element count (product of dims).
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// All tensors of a weights file, in file order.
#[derive(Debug, Clone)]
pub struct WeightsFile {
    /// All tensors, in file order.
    pub tensors: Vec<Tensor>,
}

impl WeightsFile {
    /// Read and parse a container file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading weights file {}", path.display()))?;
        Self::parse(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse a container from bytes.
    pub fn parse(mut bytes: &[u8]) -> Result<Self> {
        let magic = read_u32(&mut bytes)?;
        if magic != WEIGHTS_MAGIC {
            bail!("bad magic {magic:#x} (expected {WEIGHTS_MAGIC:#x})");
        }
        let count = read_u32(&mut bytes)? as usize;
        if count > 10_000 {
            bail!("implausible tensor count {count}");
        }
        let mut tensors = Vec::with_capacity(count);
        for i in 0..count {
            let name_len = read_u32(&mut bytes)? as usize;
            if name_len > 4096 {
                bail!("tensor {i}: name length {name_len} too large");
            }
            let mut name_bytes = vec![0u8; name_len];
            bytes.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("tensor name not UTF-8")?;
            let ndim = read_u32(&mut bytes)? as usize;
            if ndim > 8 {
                bail!("tensor {name}: ndim {ndim} too large");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut bytes)? as usize);
            }
            let n: usize = dims.iter().product();
            if n > 512 * 1024 * 1024 / 4 {
                bail!("tensor {name}: {n} elements too large");
            }
            let mut data = vec![0i32; n];
            let mut raw = vec![0u8; n * 4];
            bytes.read_exact(&mut raw).context("tensor data truncated")?;
            for (j, chunk) in raw.chunks_exact(4).enumerate() {
                data[j] = i32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.push(Tensor { name, dims, data });
        }
        Ok(Self { tensors })
    }

    /// Tensor by name, if present.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }
}

fn read_u32(bytes: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    bytes.read_exact(&mut b).context("unexpected EOF")?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&WEIGHTS_MAGIC.to_le_bytes());
        v.extend_from_slice(&2u32.to_le_bytes());
        // tensor "w0": 2x3
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(b"w0");
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(&3u32.to_le_bytes());
        for x in [1i32, -2, 3, -4, 5, -6] {
            v.extend_from_slice(&x.to_le_bytes());
        }
        // tensor "w1": scalar-ish 1-dim
        v.extend_from_slice(&2u32.to_le_bytes());
        v.extend_from_slice(b"w1");
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&1u32.to_le_bytes());
        v.extend_from_slice(&42i32.to_le_bytes());
        v
    }

    #[test]
    fn parses_round_trip() {
        let w = WeightsFile::parse(&sample_bytes()).unwrap();
        assert_eq!(w.tensors.len(), 2);
        let t = w.get("w0").unwrap();
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.data, vec![1, -2, 3, -4, 5, -6]);
        assert_eq!(w.get("w1").unwrap().data, vec![42]);
        assert!(w.get("nope").is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = sample_bytes();
        b[0] ^= 0xFF;
        assert!(WeightsFile::parse(&b).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let b = sample_bytes();
        assert!(WeightsFile::parse(&b[..b.len() - 2]).is_err());
    }

    #[test]
    fn real_artifact_loads_if_present() {
        let path = Path::new("artifacts/weights_vgg_tiny.bin");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let w = WeightsFile::load(path).unwrap();
        assert_eq!(w.tensors.len(), 5);
        assert_eq!(w.tensors[0].dims, vec![27, 16]);
        assert_eq!(w.tensors[4].dims, vec![64, 10]);
    }
}
