//! The VGG model zoo (Simonyan & Zisserman 2014) at ImageNet resolution —
//! the paper's workloads (Sec. VI-B): configurations A through E.
//!
//! Pooling is fused into the preceding conv stage, matching the paper's
//! pipelining model; the final pool feeds the 25088-dim FC stack.

use super::layer::Layer;
use super::network::Network;

/// VGG variant identifiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum VggVariant {
    /// VGG-A (VGG-11): 8 conv + 3 FC layers.
    A,
    /// VGG-B (VGG-13): 10 conv layers.
    B,
    /// VGG-C: 13 convs, three of them 1x1.
    C,
    /// VGG-D (VGG-16): 13 3x3 convs.
    D,
    /// VGG-E (VGG-19): 16 convs, the paper's headline workload.
    E,
}

impl VggVariant {
    /// Every variant, in configuration order.
    pub const ALL: [VggVariant; 5] = [
        VggVariant::A,
        VggVariant::B,
        VggVariant::C,
        VggVariant::D,
        VggVariant::E,
    ];

    /// Workload name (`vggA` .. `vggE`).
    pub fn name(&self) -> &'static str {
        match self {
            VggVariant::A => "vggA",
            VggVariant::B => "vggB",
            VggVariant::C => "vggC",
            VggVariant::D => "vggD",
            VggVariant::E => "vggE",
        }
    }

    /// Conv-stage plan: (out_ch, ksize) per conv, grouped into the five
    /// blocks; the last conv of each block carries the 2x2 max-pool.
    fn blocks(&self) -> Vec<Vec<(usize, usize)>> {
        // (out_ch, ksize); VGG-C uses 1x1 convs as the third conv of blocks
        // 3-5 (the original paper's "C" configuration).
        match self {
            VggVariant::A => vec![
                vec![(64, 3)],
                vec![(128, 3)],
                vec![(256, 3), (256, 3)],
                vec![(512, 3), (512, 3)],
                vec![(512, 3), (512, 3)],
            ],
            VggVariant::B => vec![
                vec![(64, 3), (64, 3)],
                vec![(128, 3), (128, 3)],
                vec![(256, 3), (256, 3)],
                vec![(512, 3), (512, 3)],
                vec![(512, 3), (512, 3)],
            ],
            VggVariant::C => vec![
                vec![(64, 3), (64, 3)],
                vec![(128, 3), (128, 3)],
                vec![(256, 3), (256, 3), (256, 1)],
                vec![(512, 3), (512, 3), (512, 1)],
                vec![(512, 3), (512, 3), (512, 1)],
            ],
            VggVariant::D => vec![
                vec![(64, 3), (64, 3)],
                vec![(128, 3), (128, 3)],
                vec![(256, 3), (256, 3), (256, 3)],
                vec![(512, 3), (512, 3), (512, 3)],
                vec![(512, 3), (512, 3), (512, 3)],
            ],
            VggVariant::E => vec![
                vec![(64, 3), (64, 3)],
                vec![(128, 3), (128, 3)],
                vec![(256, 3), (256, 3), (256, 3), (256, 3)],
                vec![(512, 3), (512, 3), (512, 3), (512, 3)],
                vec![(512, 3), (512, 3), (512, 3), (512, 3)],
            ],
        }
    }
}

impl std::str::FromStr for VggVariant {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        // Accept separator spellings too: vgg_e / vgg-e == vggE.
        let norm: String = s
            .chars()
            .filter(|&c| c != '_' && c != '-')
            .map(|c| c.to_ascii_uppercase())
            .collect();
        match norm.as_str() {
            "A" | "VGGA" | "VGG11" => Ok(VggVariant::A),
            "B" | "VGGB" | "VGG13" => Ok(VggVariant::B),
            "C" | "VGGC" => Ok(VggVariant::C),
            "D" | "VGGD" | "VGG16" => Ok(VggVariant::D),
            "E" | "VGGE" | "VGG19" => Ok(VggVariant::E),
            other => Err(format!("unknown VGG variant {other:?} (A..E)")),
        }
    }
}

/// Build a VGG variant at ImageNet resolution (224x224x3, 1000 classes).
pub fn build(variant: VggVariant) -> Network {
    build_at(variant, 224, 1000)
}

/// Build at an arbitrary input resolution (must be divisible by 32).
pub fn build_at(variant: VggVariant, input_hw: usize, classes: usize) -> Network {
    assert!(input_hw % 32 == 0, "VGG needs input divisible by 32");
    let mut layers = Vec::new();
    let mut hw = input_hw;
    let mut ch = 3;
    let mut idx = 0;
    for block in variant.blocks() {
        let n = block.len();
        for (j, &(out_ch, ksize)) in block.iter().enumerate() {
            idx += 1;
            let pool = j + 1 == n; // pool after the last conv of the block
            layers.push(Layer::conv(
                format!("conv{idx}"),
                (hw, hw),
                ch,
                out_ch,
                ksize,
                pool,
            ));
            ch = out_ch;
        }
        hw /= 2;
    }
    let flat = hw * hw * ch; // 7*7*512 = 25088 at 224
    layers.push(Layer::fc("fc1", flat, 4096));
    layers.push(Layer::fc("fc2", 4096, 4096));
    layers.push(Layer::fc("fc3", 4096, classes));
    Network::new(variant.name(), layers).expect("VGG construction must validate")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_counts_match_fig7() {
        // Fig. 7: A has 8 conv layers, B 10, C 13, D 13, E 16; all have 3 FC.
        let want = [
            (VggVariant::A, 8),
            (VggVariant::B, 10),
            (VggVariant::C, 13),
            (VggVariant::D, 13),
            (VggVariant::E, 16),
        ];
        for (v, n) in want {
            let net = build(v);
            assert_eq!(net.n_conv(), n, "{}", v.name());
            assert_eq!(net.n_fc(), 3, "{}", v.name());
        }
    }

    #[test]
    fn downsample_chain_is_five_pools() {
        // Sec. VI-C: 224 -> 112 -> 56 -> 28 -> 14 -> 7.
        let net = build(VggVariant::E);
        let pools: Vec<usize> = net
            .layers()
            .iter()
            .filter(|l| l.has_pool())
            .map(|l| l.out_hw().0)
            .collect();
        assert_eq!(pools, vec![112, 56, 28, 14, 7]);
    }

    #[test]
    fn fc_input_is_25088() {
        for v in VggVariant::ALL {
            let net = build(v);
            let fc1 = net.layers().iter().find(|l| !l.is_conv()).unwrap();
            assert_eq!(fc1.in_ch, 25088, "{}", v.name());
        }
    }

    #[test]
    fn vgg_e_total_macs_about_19_6_g() {
        // Known figure: VGG-19 ≈ 19.5-19.7 GMACs at 224x224.
        let net = build(VggVariant::E);
        let g = net.macs() as f64 / 1e9;
        assert!((19.0..20.5).contains(&g), "VGG-E GMACs = {g}");
    }

    #[test]
    fn vgg_a_weights_about_132_m() {
        // VGG-11 has ≈ 132.9 M parameters (no biases in our model).
        let net = build(VggVariant::A);
        let m = net.weights() as f64 / 1e6;
        assert!((130.0..135.0).contains(&m), "VGG-A params = {m} M");
    }

    #[test]
    fn parse_variants() {
        assert_eq!("vgg19".parse::<VggVariant>().unwrap(), VggVariant::E);
        assert_eq!("a".parse::<VggVariant>().unwrap(), VggVariant::A);
        assert!("vgg7".parse::<VggVariant>().is_err());
    }

    #[test]
    fn reduced_resolution_build() {
        let net = build_at(VggVariant::A, 32, 10);
        let fc1 = net.layers().iter().find(|l| !l.is_conv()).unwrap();
        assert_eq!(fc1.in_ch, 512); // 1*1*512
    }
}
