//! CNN layer descriptors and shape/operation arithmetic.
//!
//! Following the paper's notation (Sec. IV): the IFM of a layer is
//! `c x h x w`, the kernel is `n x c x l x l`, and the OFM is `n x h' x w'`.

/// One layer of a CNN, with its input feature-map geometry resolved.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// IFM height.
    pub in_h: usize,
    /// IFM width.
    pub in_w: usize,
    /// IFM channels (`c`).
    pub in_ch: usize,
}

/// Layer type. Pooling is attached to the preceding conv layer (`pool_after`)
/// because the paper treats "conv + pool" as one pipelined stage with its own
/// intra-layer pipeline variant (Sec. IV-A).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    Conv {
        /// Kernel count `n` (output channels).
        out_ch: usize,
        /// Kernel spatial size `l` (VGG: 3, or 1 for the C-variant 1x1s).
        ksize: usize,
        /// Stride (VGG: always 1).
        stride: usize,
        /// SAME padding (VGG: ksize/2).
        pad: usize,
        /// 2x2/2 max-pool fused after this conv.
        pool_after: bool,
    },
    /// Fully connected: `out` neurons over the flattened input.
    Fc { out: usize },
}

impl Layer {
    pub fn conv(
        name: impl Into<String>,
        in_hw: (usize, usize),
        in_ch: usize,
        out_ch: usize,
        ksize: usize,
        pool_after: bool,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Conv {
                out_ch,
                ksize,
                stride: 1,
                pad: ksize / 2,
                pool_after,
            },
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch,
        }
    }

    pub fn fc(name: impl Into<String>, in_dim: usize, out: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Fc { out },
            in_h: 1,
            in_w: 1,
            in_ch: in_dim,
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. })
    }

    pub fn has_pool(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv {
                pool_after: true,
                ..
            }
        )
    }

    pub fn ksize(&self) -> usize {
        match self.kind {
            LayerKind::Conv { ksize, .. } => ksize,
            LayerKind::Fc { .. } => 1,
        }
    }

    /// Pre-pool convolution output spatial dims (`h'`, `w'`).
    pub fn conv_out_hw(&self) -> (usize, usize) {
        match self.kind {
            LayerKind::Conv {
                ksize, stride, pad, ..
            } => {
                let oh = (self.in_h + 2 * pad - ksize) / stride + 1;
                let ow = (self.in_w + 2 * pad - ksize) / stride + 1;
                (oh, ow)
            }
            LayerKind::Fc { .. } => (1, 1),
        }
    }

    /// OFM spatial dims after the fused pool (if any).
    pub fn out_hw(&self) -> (usize, usize) {
        let (h, w) = self.conv_out_hw();
        if self.has_pool() {
            (h / 2, w / 2)
        } else {
            (h, w)
        }
    }

    /// OFM channels.
    pub fn out_ch(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => out_ch,
            LayerKind::Fc { out } => out,
        }
    }

    /// Flattened OFM size (next layer's FC input dim).
    pub fn out_dim(&self) -> usize {
        let (h, w) = self.out_hw();
        h * w * self.out_ch()
    }

    /// Output "pixels" the layer streams (all channels of one position count
    /// as one pixel — the unit of the paper's intra-layer pipeline).
    pub fn out_pixels(&self) -> u64 {
        let (h, w) = self.conv_out_hw();
        (h * w) as u64
    }

    /// GEMM view: the kernel matrix is `gemm_k()` rows x `gemm_n()` columns.
    pub fn gemm_k(&self) -> usize {
        match self.kind {
            LayerKind::Conv { ksize, .. } => self.in_ch * ksize * ksize,
            LayerKind::Fc { .. } => self.in_ch,
        }
    }

    pub fn gemm_n(&self) -> usize {
        self.out_ch()
    }

    /// Multiply-accumulate operations for one inference of this layer.
    pub fn macs(&self) -> u64 {
        let (oh, ow) = self.conv_out_hw();
        (oh * ow) as u64 * self.gemm_k() as u64 * self.gemm_n() as u64
    }

    /// Operations (1 MAC = 2 ops, the paper's TOPS accounting).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight count (no biases in the crossbar model).
    pub fn weights(&self) -> u64 {
        self.gemm_k() as u64 * self.gemm_n() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shapes_vgg_first_layer() {
        let l = Layer::conv("conv1", (224, 224), 3, 64, 3, false);
        assert_eq!(l.conv_out_hw(), (224, 224));
        assert_eq!(l.out_hw(), (224, 224));
        assert_eq!(l.gemm_k(), 27);
        assert_eq!(l.gemm_n(), 64);
        assert_eq!(l.macs(), 224 * 224 * 27 * 64);
        assert_eq!(l.out_pixels(), 224 * 224);
    }

    #[test]
    fn pool_halves_output() {
        let l = Layer::conv("c", (224, 224), 3, 64, 3, true);
        assert_eq!(l.conv_out_hw(), (224, 224));
        assert_eq!(l.out_hw(), (112, 112));
        assert_eq!(l.out_dim(), 112 * 112 * 64);
    }

    #[test]
    fn one_by_one_conv() {
        // VGG-C's 1x1 convolutions.
        let l = Layer::conv("c", (56, 56), 256, 256, 1, false);
        assert_eq!(l.conv_out_hw(), (56, 56));
        assert_eq!(l.gemm_k(), 256);
    }

    #[test]
    fn fc_shapes() {
        let l = Layer::fc("fc1", 25088, 4096);
        assert_eq!(l.out_pixels(), 1);
        assert_eq!(l.macs(), 25088 * 4096);
        assert_eq!(l.out_dim(), 4096);
        assert!(!l.is_conv());
    }
}
