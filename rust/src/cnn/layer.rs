//! CNN layer descriptors and shape/operation arithmetic.
//!
//! Following the paper's notation (Sec. IV): the IFM of a layer is
//! `c x h x w`, the kernel is `n x c x l x l`, and the OFM is `n x h' x w'`.

/// One layer of a CNN, with its input feature-map geometry resolved.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Layer name (unique within a network).
    pub name: String,
    /// Layer type and type-specific parameters.
    pub kind: LayerKind,
    /// IFM height.
    pub in_h: usize,
    /// IFM width.
    pub in_w: usize,
    /// IFM channels (`c`).
    pub in_ch: usize,
}

/// Layer type. Pooling is attached to the preceding conv layer (`pool_after`)
/// because the paper treats "conv + pool" as one pipelined stage with its own
/// intra-layer pipeline variant (Sec. IV-A).
///
/// Besides the crossbar-mapped kinds (`Conv`, `Fc`) there are three
/// *dataflow* kinds that carry no weights: `Add` and `Concat` are the merge
/// nodes of a layer DAG (residual connections and channel concatenation),
/// and `GlobalAvgPool` is the spatial reduction in front of a ResNet-style
/// classifier head. They execute in the tile's shift-and-add / output
/// register path, not in crossbars.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    /// Convolution mapped onto crossbars (the paper's main workload unit).
    Conv {
        /// Kernel count `n` (output channels).
        out_ch: usize,
        /// Kernel spatial size `l` (VGG: 3, or 1 for the C-variant 1x1s).
        ksize: usize,
        /// Stride (VGG: always 1; ResNet downsamples with 2).
        stride: usize,
        /// SAME padding (VGG: ksize/2).
        pad: usize,
        /// 2x2/2 max-pool fused after this conv.
        pool_after: bool,
    },
    /// Fully connected: `out` neurons over the flattened input.
    Fc { out: usize },
    /// Element-wise sum of two or more equal-shape inputs (residual merge).
    Add,
    /// Channel-wise concatenation of two or more same-resolution inputs.
    Concat,
    /// Global average pool: reduces `h x w x c` to `1 x 1 x c`.
    GlobalAvgPool,
}

impl Layer {
    /// A stride-1 SAME-padded convolution (the VGG default).
    pub fn conv(
        name: impl Into<String>,
        in_hw: (usize, usize),
        in_ch: usize,
        out_ch: usize,
        ksize: usize,
        pool_after: bool,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Conv {
                out_ch,
                ksize,
                stride: 1,
                pad: ksize / 2,
                pool_after,
            },
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch,
        }
    }

    /// A convolution with explicit stride and padding (ResNet's 7x7/2 stem
    /// and 1x1/2 downsample paths; [`Layer::conv`] keeps the VGG defaults).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_s(
        name: impl Into<String>,
        in_hw: (usize, usize),
        in_ch: usize,
        out_ch: usize,
        ksize: usize,
        stride: usize,
        pad: usize,
        pool_after: bool,
    ) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Conv {
                out_ch,
                ksize,
                stride,
                pad,
                pool_after,
            },
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch,
        }
    }

    /// A fully-connected layer over a flattened `in_dim` input.
    pub fn fc(name: impl Into<String>, in_dim: usize, out: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Fc { out },
            in_h: 1,
            in_w: 1,
            in_ch: in_dim,
        }
    }

    /// A residual merge: element-wise sum of equal-shape `h x w x ch` inputs.
    pub fn add(name: impl Into<String>, in_hw: (usize, usize), in_ch: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Add,
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch,
        }
    }

    /// A channel concatenation; `total_ch` is the summed channel count of
    /// all inputs.
    pub fn concat(name: impl Into<String>, in_hw: (usize, usize), total_ch: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::Concat,
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch: total_ch,
        }
    }

    /// A global average pool over an `h x w x ch` feature map.
    pub fn global_avg_pool(name: impl Into<String>, in_hw: (usize, usize), in_ch: usize) -> Self {
        Self {
            name: name.into(),
            kind: LayerKind::GlobalAvgPool,
            in_h: in_hw.0,
            in_w: in_hw.1,
            in_ch,
        }
    }

    /// Is this a crossbar-mapped convolution?
    pub fn is_conv(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. })
    }

    /// Is this a fully-connected layer?
    pub fn is_fc(&self) -> bool {
        matches!(self.kind, LayerKind::Fc { .. })
    }

    /// Is this a DAG merge node (`Add` or `Concat`)?
    pub fn is_merge(&self) -> bool {
        matches!(self.kind, LayerKind::Add | LayerKind::Concat)
    }

    /// Does this layer hold weights in crossbars (conv or FC)? Dataflow
    /// kinds (merge nodes, global pooling) occupy no subarrays.
    pub fn is_crossbar(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::Fc { .. })
    }

    /// Does this conv fuse a 2x2/2 max-pool after it?
    pub fn has_pool(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv {
                pool_after: true,
                ..
            }
        )
    }

    /// Kernel spatial size (1 for every non-conv kind).
    pub fn ksize(&self) -> usize {
        match self.kind {
            LayerKind::Conv { ksize, .. } => ksize,
            _ => 1,
        }
    }

    /// Pre-pool convolution output spatial dims (`h'`, `w'`).
    pub fn conv_out_hw(&self) -> (usize, usize) {
        match self.kind {
            LayerKind::Conv {
                ksize, stride, pad, ..
            } => {
                let oh = (self.in_h + 2 * pad - ksize) / stride + 1;
                let ow = (self.in_w + 2 * pad - ksize) / stride + 1;
                (oh, ow)
            }
            LayerKind::Fc { .. } | LayerKind::GlobalAvgPool => (1, 1),
            LayerKind::Add | LayerKind::Concat => (self.in_h, self.in_w),
        }
    }

    /// OFM spatial dims after the fused pool (if any).
    pub fn out_hw(&self) -> (usize, usize) {
        let (h, w) = self.conv_out_hw();
        if self.has_pool() {
            (h / 2, w / 2)
        } else {
            (h, w)
        }
    }

    /// OFM channels.
    pub fn out_ch(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => out_ch,
            LayerKind::Fc { out } => out,
            // Merges and pooling pass channels through (Concat's `in_ch` is
            // already the summed channel count of its inputs).
            LayerKind::Add | LayerKind::Concat | LayerKind::GlobalAvgPool => self.in_ch,
        }
    }

    /// Flattened OFM size (next layer's FC input dim).
    pub fn out_dim(&self) -> usize {
        let (h, w) = self.out_hw();
        h * w * self.out_ch()
    }

    /// Output "pixels" the layer streams (all channels of one position count
    /// as one pixel — the unit of the paper's intra-layer pipeline).
    pub fn out_pixels(&self) -> u64 {
        let (h, w) = self.conv_out_hw();
        (h * w) as u64
    }

    /// GEMM view: the kernel matrix is `gemm_k()` rows x `gemm_n()` columns.
    /// Dataflow kinds hold no weight matrix (both dims are 0).
    pub fn gemm_k(&self) -> usize {
        match self.kind {
            LayerKind::Conv { ksize, .. } => self.in_ch * ksize * ksize,
            LayerKind::Fc { .. } => self.in_ch,
            LayerKind::Add | LayerKind::Concat | LayerKind::GlobalAvgPool => 0,
        }
    }

    /// GEMM output columns (0 for weight-less dataflow kinds).
    pub fn gemm_n(&self) -> usize {
        if self.is_crossbar() {
            self.out_ch()
        } else {
            0
        }
    }

    /// Multiply-accumulate operations for one inference of this layer.
    pub fn macs(&self) -> u64 {
        let (oh, ow) = self.conv_out_hw();
        (oh * ow) as u64 * self.gemm_k() as u64 * self.gemm_n() as u64
    }

    /// Operations (1 MAC = 2 ops, the paper's TOPS accounting).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight count (no biases in the crossbar model).
    pub fn weights(&self) -> u64 {
        self.gemm_k() as u64 * self.gemm_n() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shapes_vgg_first_layer() {
        let l = Layer::conv("conv1", (224, 224), 3, 64, 3, false);
        assert_eq!(l.conv_out_hw(), (224, 224));
        assert_eq!(l.out_hw(), (224, 224));
        assert_eq!(l.gemm_k(), 27);
        assert_eq!(l.gemm_n(), 64);
        assert_eq!(l.macs(), 224 * 224 * 27 * 64);
        assert_eq!(l.out_pixels(), 224 * 224);
    }

    #[test]
    fn pool_halves_output() {
        let l = Layer::conv("c", (224, 224), 3, 64, 3, true);
        assert_eq!(l.conv_out_hw(), (224, 224));
        assert_eq!(l.out_hw(), (112, 112));
        assert_eq!(l.out_dim(), 112 * 112 * 64);
    }

    #[test]
    fn one_by_one_conv() {
        // VGG-C's 1x1 convolutions.
        let l = Layer::conv("c", (56, 56), 256, 256, 1, false);
        assert_eq!(l.conv_out_hw(), (56, 56));
        assert_eq!(l.gemm_k(), 256);
    }

    #[test]
    fn fc_shapes() {
        let l = Layer::fc("fc1", 25088, 4096);
        assert_eq!(l.out_pixels(), 1);
        assert_eq!(l.macs(), 25088 * 4096);
        assert_eq!(l.out_dim(), 4096);
        assert!(!l.is_conv());
        assert!(l.is_fc() && l.is_crossbar());
    }

    #[test]
    fn strided_conv_shapes_resnet_stem() {
        // ResNet conv1: 224x224x3, 7x7/2 pad 3 -> 112x112x64; fused pool
        // halves again to 56.
        let l = Layer::conv_s("conv1", (224, 224), 3, 64, 7, 2, 3, true);
        assert_eq!(l.conv_out_hw(), (112, 112));
        assert_eq!(l.out_hw(), (56, 56));
        assert_eq!(l.gemm_k(), 3 * 49);
        assert_eq!(l.macs(), 112 * 112 * 147 * 64);
    }

    #[test]
    fn add_passes_shape_through_with_no_weights() {
        let l = Layer::add("res1", (56, 56), 64);
        assert_eq!(l.out_hw(), (56, 56));
        assert_eq!(l.out_ch(), 64);
        assert_eq!(l.out_pixels(), 56 * 56);
        assert_eq!(l.macs(), 0);
        assert_eq!(l.weights(), 0);
        assert!(l.is_merge() && !l.is_crossbar() && !l.is_conv());
    }

    #[test]
    fn concat_sums_channels() {
        let l = Layer::concat("cat", (28, 28), 64 + 128);
        assert_eq!(l.out_ch(), 192);
        assert_eq!(l.out_dim(), 28 * 28 * 192);
        assert_eq!(l.weights(), 0);
    }

    #[test]
    fn global_avg_pool_reduces_to_channels() {
        let l = Layer::global_avg_pool("gap", (7, 7), 512);
        assert_eq!(l.out_hw(), (1, 1));
        assert_eq!(l.out_dim(), 512);
        assert_eq!(l.out_pixels(), 1);
        assert_eq!(l.macs(), 0);
        assert!(!l.is_merge() && !l.is_crossbar());
    }
}
