//! Network container: an ordered list of layers with validated shape chain.

use super::layer::{Layer, LayerKind};

/// A validated feed-forward CNN.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    layers: Vec<Layer>,
}

impl Network {
    /// Build and validate: each layer's input must match its predecessor's
    /// output (spatial dims and channels for conv; flattened dim for FC).
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Result<Self, String> {
        let name = name.into();
        if layers.is_empty() {
            return Err(format!("network {name}: no layers"));
        }
        for i in 1..layers.len() {
            let prev = &layers[i - 1];
            let cur = &layers[i];
            match cur.kind {
                LayerKind::Conv { .. } => {
                    let (h, w) = prev.out_hw();
                    if (cur.in_h, cur.in_w) != (h, w) || cur.in_ch != prev.out_ch() {
                        return Err(format!(
                            "network {name}: {} out {}x{}x{} != {} in {}x{}x{}",
                            prev.name,
                            h,
                            w,
                            prev.out_ch(),
                            cur.name,
                            cur.in_h,
                            cur.in_w,
                            cur.in_ch
                        ));
                    }
                }
                LayerKind::Fc { .. } => {
                    if cur.in_ch != prev.out_dim() {
                        return Err(format!(
                            "network {name}: {} flat out {} != {} in {}",
                            prev.name,
                            prev.out_dim(),
                            cur.name,
                            cur.in_ch
                        ));
                    }
                }
            }
        }
        Ok(Self { name, layers })
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_conv())
    }

    pub fn n_conv(&self) -> usize {
        self.conv_layers().count()
    }

    pub fn n_fc(&self) -> usize {
        self.layers.iter().filter(|l| !l.is_conv()).count()
    }

    /// Total MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total operations (2 x MACs, the paper's TOPS accounting).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Total weights.
    pub fn weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layer::Layer;

    #[test]
    fn valid_chain_builds() {
        let net = Network::new(
            "mini",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, true),
                Layer::conv("c2", (4, 4), 4, 8, 3, false),
                Layer::fc("fc", 4 * 4 * 8, 10),
            ],
        )
        .unwrap();
        assert_eq!(net.len(), 3);
        assert_eq!(net.n_conv(), 2);
        assert_eq!(net.n_fc(), 1);
    }

    #[test]
    fn mismatched_channels_rejected() {
        let err = Network::new(
            "bad",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::conv("c2", (8, 8), 5, 8, 3, false), // 5 != 4
            ],
        )
        .unwrap_err();
        assert!(err.contains("c1"), "{err}");
    }

    #[test]
    fn mismatched_fc_dim_rejected() {
        let err = Network::new(
            "bad",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::fc("fc", 999, 10),
            ],
        )
        .unwrap_err();
        assert!(err.contains("flat out"), "{err}");
    }

    #[test]
    fn empty_network_rejected() {
        assert!(Network::new("empty", vec![]).is_err());
    }
}
