//! Network container: a validated layer **DAG**.
//!
//! A [`Network`] is a list of layers in topological order plus an explicit
//! edge set. The linear chain the paper evaluates (VGG A-E) is the trivial
//! DAG — [`Network::new`] builds it from a plain layer list, exactly as the
//! seed code did — while [`Network::from_graph`] accepts arbitrary
//! branching topologies (ResNet residual blocks, Inception-style concats)
//! with merge nodes ([`LayerKind::Add`] / [`LayerKind::Concat`]) and shape
//! checking along **every** edge.
//!
//! Validation rules:
//! - the layer order given must be topological, and the edge set acyclic;
//! - layer 0 is the only source (host-fed), the last layer the only sink;
//! - `Conv` / `Fc` / `GlobalAvgPool` take exactly one input edge; `Add`
//!   needs >= 2 equal-shape inputs; `Concat` >= 2 same-resolution inputs
//!   whose channels sum to its `in_ch`.

use super::layer::{Layer, LayerKind};

/// A validated feed-forward CNN over an explicit layer DAG.
#[derive(Debug, Clone)]
pub struct Network {
    /// Workload name (`vggE`, `resnet18`, ...).
    pub name: String,
    layers: Vec<Layer>,
    /// Predecessor indices per layer (edge sources), each sorted ascending.
    preds: Vec<Vec<usize>>,
    /// Successor indices per layer (edge targets), each sorted ascending.
    succs: Vec<Vec<usize>>,
}

impl Network {
    /// Build and validate a **linear** network: layer `i` feeds layer
    /// `i+1`. This is the seed API, kept verbatim — every VGG constant and
    /// golden test goes through here, and a linear network is simply the
    /// trivial DAG (`preds[i] == [i-1]`).
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Result<Self, String> {
        let edges: Vec<(usize, usize)> = (1..layers.len()).map(|i| (i - 1, i)).collect();
        Self::from_graph(name, layers, edges)
    }

    /// Build and validate a layer DAG from an explicit edge list
    /// (`(producer, consumer)` index pairs into `layers`).
    ///
    /// # Example
    ///
    /// A minimal residual cell — `c1` feeds both `c2` and the merge:
    ///
    /// ```
    /// use smart_pim::cnn::{Layer, Network};
    ///
    /// let net = Network::from_graph(
    ///     "tiny-res",
    ///     vec![
    ///         Layer::conv("c1", (8, 8), 3, 4, 3, false),
    ///         Layer::conv("c2", (8, 8), 4, 4, 3, false),
    ///         Layer::add("sum", (8, 8), 4),
    ///         Layer::fc("fc", 8 * 8 * 4, 10),
    ///     ],
    ///     vec![(0, 1), (1, 2), (0, 2), (2, 3)],
    /// )
    /// .unwrap();
    /// assert!(!net.is_linear());
    /// assert_eq!(net.preds(2), &[0, 1]); // the merge waits on both paths
    /// ```
    pub fn from_graph(
        name: impl Into<String>,
        layers: Vec<Layer>,
        edges: Vec<(usize, usize)>,
    ) -> Result<Self, String> {
        let name = name.into();
        let n = layers.len();
        if n == 0 {
            return Err(format!("network {name}: no layers"));
        }
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(a, b) in &edges {
            if a >= n || b >= n {
                return Err(format!(
                    "network {name}: edge ({a}, {b}) out of range for {n} layers"
                ));
            }
            if succs[a].contains(&b) {
                return Err(format!("network {name}: duplicate edge ({a}, {b})"));
            }
            succs[a].push(b);
            preds[b].push(a);
        }
        // Order check: the given layer order must be topological. A forward
        // violation is either a cycle (the edge set admits no topological
        // order at all) or a mis-ordered acyclic graph; Kahn's algorithm
        // distinguishes the two for a precise error.
        if edges.iter().any(|&(a, b)| a >= b) {
            let mut indeg: Vec<usize> = preds.iter().map(Vec::len).collect();
            let mut ready: Vec<usize> =
                (0..n).filter(|&i| indeg[i] == 0).collect();
            let mut emitted = 0usize;
            while let Some(v) = ready.pop() {
                emitted += 1;
                for &s in &succs[v] {
                    indeg[s] -= 1;
                    if indeg[s] == 0 {
                        ready.push(s);
                    }
                }
            }
            if emitted < n {
                return Err(format!("network {name}: edge set contains a cycle"));
            }
            return Err(format!(
                "network {name}: layers must be listed in topological order \
                 (some edge points backwards)"
            ));
        }
        for p in preds.iter_mut() {
            p.sort_unstable();
        }
        for s in succs.iter_mut() {
            s.sort_unstable();
        }
        // The source must be a real compute layer: merges need >= 2 inputs
        // and a host-fed pool has nothing to reduce.
        if !layers[0].is_crossbar() {
            return Err(format!(
                "network {name}: layer 0 ({}) must be a conv or FC layer",
                layers[0].name
            ));
        }
        // Connectivity: one source (layer 0), one sink (the last layer).
        for (i, p) in preds.iter().enumerate() {
            if i == 0 && !p.is_empty() {
                return Err(format!(
                    "network {name}: layer 0 ({}) must be the host-fed source",
                    layers[0].name
                ));
            }
            if i > 0 && p.is_empty() {
                return Err(format!(
                    "network {name}: layer {} ({}) has no input edge",
                    i, layers[i].name
                ));
            }
        }
        for (i, s) in succs.iter().enumerate() {
            if i + 1 == n && !s.is_empty() {
                return Err(format!(
                    "network {name}: last layer ({}) must be the sink",
                    layers[n - 1].name
                ));
            }
            if i + 1 < n && s.is_empty() {
                return Err(format!(
                    "network {name}: layer {} ({}) has a dangling output",
                    i, layers[i].name
                ));
            }
        }
        // Shape check along every edge.
        for (i, cur) in layers.iter().enumerate().skip(1) {
            let ins = &preds[i];
            match cur.kind {
                LayerKind::Conv { .. } | LayerKind::GlobalAvgPool => {
                    if ins.len() != 1 {
                        return Err(format!(
                            "network {name}: {} takes one input, got {}",
                            cur.name,
                            ins.len()
                        ));
                    }
                    let prev = &layers[ins[0]];
                    let (h, w) = prev.out_hw();
                    if (cur.in_h, cur.in_w) != (h, w) || cur.in_ch != prev.out_ch() {
                        return Err(format!(
                            "network {name}: {} out {}x{}x{} != {} in {}x{}x{}",
                            prev.name,
                            h,
                            w,
                            prev.out_ch(),
                            cur.name,
                            cur.in_h,
                            cur.in_w,
                            cur.in_ch
                        ));
                    }
                }
                LayerKind::Fc { .. } => {
                    if ins.len() != 1 {
                        return Err(format!(
                            "network {name}: {} takes one input, got {}",
                            cur.name,
                            ins.len()
                        ));
                    }
                    let prev = &layers[ins[0]];
                    if cur.in_ch != prev.out_dim() {
                        return Err(format!(
                            "network {name}: {} flat out {} != {} in {}",
                            prev.name,
                            prev.out_dim(),
                            cur.name,
                            cur.in_ch
                        ));
                    }
                }
                LayerKind::Add => {
                    if ins.len() < 2 {
                        return Err(format!(
                            "network {name}: merge {} needs >= 2 inputs, got {}",
                            cur.name,
                            ins.len()
                        ));
                    }
                    for &p in ins {
                        let prev = &layers[p];
                        let (h, w) = prev.out_hw();
                        if (h, w) != (cur.in_h, cur.in_w) || prev.out_ch() != cur.in_ch {
                            return Err(format!(
                                "network {name}: merge {} expects {}x{}x{}, input {} \
                                 produces {}x{}x{}",
                                cur.name,
                                cur.in_h,
                                cur.in_w,
                                cur.in_ch,
                                prev.name,
                                h,
                                w,
                                prev.out_ch()
                            ));
                        }
                    }
                }
                LayerKind::Concat => {
                    if ins.len() < 2 {
                        return Err(format!(
                            "network {name}: merge {} needs >= 2 inputs, got {}",
                            cur.name,
                            ins.len()
                        ));
                    }
                    let mut ch_sum = 0usize;
                    for &p in ins {
                        let prev = &layers[p];
                        let (h, w) = prev.out_hw();
                        if (h, w) != (cur.in_h, cur.in_w) {
                            return Err(format!(
                                "network {name}: merge {} expects {}x{}, input {} \
                                 produces {}x{}",
                                cur.name, cur.in_h, cur.in_w, prev.name, h, w
                            ));
                        }
                        ch_sum += prev.out_ch();
                    }
                    if ch_sum != cur.in_ch {
                        return Err(format!(
                            "network {name}: merge {} declares {} channels, inputs \
                             sum to {ch_sum}",
                            cur.name, cur.in_ch
                        ));
                    }
                }
            }
        }
        Ok(Self {
            name,
            layers,
            preds,
            succs,
        })
    }

    /// The layers in topological order.
    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Predecessor layer indices of layer `i` (empty for the source).
    pub fn preds(&self, i: usize) -> &[usize] {
        &self.preds[i]
    }

    /// Successor layer indices of layer `i` (empty for the sink).
    pub fn succs(&self, i: usize) -> &[usize] {
        &self.succs[i]
    }

    /// True when the DAG is the trivial chain (`preds[i] == [i-1]`), i.e.
    /// exactly what the seed's `Vec<Layer>` representation expressed.
    pub fn is_linear(&self) -> bool {
        self.preds
            .iter()
            .enumerate()
            .all(|(i, p)| if i == 0 { p.is_empty() } else { p == &[i - 1] })
    }

    /// Total edge count.
    pub fn n_edges(&self) -> usize {
        self.preds.iter().map(Vec::len).sum()
    }

    /// Layer count.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// True when the network has no layers (never, post-validation).
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// The crossbar-mapped convolution layers.
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_conv())
    }

    /// Number of convolution layers.
    pub fn n_conv(&self) -> usize {
        self.conv_layers().count()
    }

    /// Number of fully-connected layers (merge/pool nodes are neither).
    pub fn n_fc(&self) -> usize {
        self.layers.iter().filter(|l| l.is_fc()).count()
    }

    /// Number of dataflow merge nodes (`Add` / `Concat`).
    pub fn n_merge(&self) -> usize {
        self.layers.iter().filter(|l| l.is_merge()).count()
    }

    /// Total MACs for one inference.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total operations (2 x MACs, the paper's TOPS accounting).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Total weights.
    pub fn weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layer::Layer;

    #[test]
    fn valid_chain_builds() {
        let net = Network::new(
            "mini",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, true),
                Layer::conv("c2", (4, 4), 4, 8, 3, false),
                Layer::fc("fc", 4 * 4 * 8, 10),
            ],
        )
        .unwrap();
        assert_eq!(net.len(), 3);
        assert_eq!(net.n_conv(), 2);
        assert_eq!(net.n_fc(), 1);
        assert!(net.is_linear());
        assert_eq!(net.n_edges(), 2);
        assert_eq!(net.preds(2), &[1]);
        assert_eq!(net.succs(0), &[1]);
    }

    #[test]
    fn mismatched_channels_rejected() {
        let err = Network::new(
            "bad",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::conv("c2", (8, 8), 5, 8, 3, false), // 5 != 4
            ],
        )
        .unwrap_err();
        assert!(err.contains("c1"), "{err}");
    }

    #[test]
    fn mismatched_fc_dim_rejected() {
        let err = Network::new(
            "bad",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::fc("fc", 999, 10),
            ],
        )
        .unwrap_err();
        assert!(err.contains("flat out"), "{err}");
    }

    #[test]
    fn empty_network_rejected() {
        assert!(Network::new("empty", vec![]).is_err());
    }

    /// A minimal residual cell: c1 feeds both c2 and the merge; the merge
    /// sums c2's output with c1's (equal shapes).
    fn residual_layers() -> Vec<Layer> {
        vec![
            Layer::conv("c1", (8, 8), 3, 4, 3, false),
            Layer::conv("c2", (8, 8), 4, 4, 3, false),
            Layer::add("sum", (8, 8), 4),
            Layer::fc("fc", 8 * 8 * 4, 10),
        ]
    }

    #[test]
    fn residual_dag_builds() {
        let net = Network::from_graph(
            "res",
            residual_layers(),
            vec![(0, 1), (1, 2), (0, 2), (2, 3)],
        )
        .unwrap();
        assert!(!net.is_linear());
        assert_eq!(net.n_merge(), 1);
        assert_eq!(net.preds(2), &[0, 1]);
        assert_eq!(net.succs(0), &[1, 2]);
        assert_eq!(net.n_edges(), 4);
    }

    #[test]
    fn merge_shape_mismatch_rejected() {
        // The merge declares 8 channels but both inputs produce 4.
        let mut layers = residual_layers();
        layers[2] = Layer::add("sum", (8, 8), 8);
        layers[3] = Layer::fc("fc", 8 * 8 * 8, 10);
        let err = Network::from_graph("res", layers, vec![(0, 1), (1, 2), (0, 2), (2, 3)])
            .unwrap_err();
        assert!(err.contains("merge"), "{err}");
    }

    #[test]
    fn merge_with_one_input_rejected() {
        let layers = residual_layers();
        let err = Network::from_graph("res", layers, vec![(0, 1), (1, 2), (2, 3)]).unwrap_err();
        assert!(err.contains(">= 2"), "{err}");
    }

    #[test]
    fn cycle_rejected() {
        let err = Network::from_graph(
            "loopy",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::conv("c2", (8, 8), 4, 4, 3, false),
                Layer::conv("c3", (8, 8), 4, 4, 3, false),
            ],
            vec![(0, 1), (1, 2), (2, 1)],
        )
        .unwrap_err();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn non_topological_order_rejected() {
        // Acyclic, but the consumer is listed before its producer.
        let err = Network::from_graph(
            "misordered",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::conv("c3", (8, 8), 4, 4, 3, false),
                Layer::conv("c2", (8, 8), 4, 4, 3, false),
            ],
            vec![(0, 2), (2, 1)],
        )
        .unwrap_err();
        assert!(err.contains("topological"), "{err}");
    }

    #[test]
    fn dangling_and_unreachable_rejected() {
        // c2 has no consumer (dangling output).
        let err = Network::from_graph(
            "dangling",
            residual_layers(),
            vec![(0, 1), (0, 2), (1, 2), (1, 3)],
        )
        .unwrap_err();
        assert!(err.contains("dangling"), "{err}");
        // fc has no input edge.
        let err = Network::from_graph(
            "orphan",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::fc("fc", 8 * 8 * 4, 10),
            ],
            vec![],
        )
        .unwrap_err();
        assert!(err.contains("no input"), "{err}");
    }

    #[test]
    fn duplicate_edge_rejected() {
        let err = Network::from_graph(
            "dup",
            vec![
                Layer::conv("c1", (8, 8), 3, 4, 3, false),
                Layer::conv("c2", (8, 8), 4, 4, 3, false),
            ],
            vec![(0, 1), (0, 1)],
        )
        .unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn concat_channel_sum_checked() {
        let layers = vec![
            Layer::conv("c1", (8, 8), 3, 4, 3, false),
            Layer::conv("c2", (8, 8), 4, 6, 3, false),
            Layer::concat("cat", (8, 8), 10), // 4 + 6
            Layer::fc("fc", 8 * 8 * 10, 10),
        ];
        let net =
            Network::from_graph("cat", layers.clone(), vec![(0, 1), (0, 2), (1, 2), (2, 3)])
                .unwrap();
        assert_eq!(net.layers()[2].out_ch(), 10);
        // Wrong declared sum.
        let mut bad = layers;
        bad[2] = Layer::concat("cat", (8, 8), 11);
        bad[3] = Layer::fc("fc", 8 * 8 * 11, 10);
        let err = Network::from_graph("cat", bad, vec![(0, 1), (0, 2), (1, 2), (2, 3)])
            .unwrap_err();
        assert!(err.contains("sum"), "{err}");
    }

    #[test]
    fn linear_via_from_graph_equals_new() {
        let layers = vec![
            Layer::conv("c1", (8, 8), 3, 4, 3, true),
            Layer::conv("c2", (4, 4), 4, 8, 3, false),
            Layer::fc("fc", 4 * 4 * 8, 10),
        ];
        let a = Network::new("lin", layers.clone()).unwrap();
        let b = Network::from_graph("lin", layers, vec![(0, 1), (1, 2)]).unwrap();
        assert!(a.is_linear() && b.is_linear());
        assert_eq!(a.macs(), b.macs());
        for i in 0..a.len() {
            assert_eq!(a.preds(i), b.preds(i));
            assert_eq!(a.succs(i), b.succs(i));
        }
    }
}
