//! The ResNet model zoo (He et al. 2015) at ImageNet resolution — the
//! first *branching* workloads of the repository, exercising the layer-DAG
//! machinery end to end (residual `Add` merges, strided downsampling,
//! global average pooling).
//!
//! Modeling choices, consistent with the VGG zoo:
//! - the 3x3/2 max-pool after the stem conv is fused into it
//!   (`pool_after`, the paper's conv+pool pipelined-stage model), which
//!   yields the same 112 -> 56 spatial reduction;
//! - batch-norm folds into the conv weights at inference (no extra layer);
//! - the residual `Add` and the global average pool are dataflow nodes: no
//!   crossbar weights, executed in the tile's S&A/OR path;
//! - projection shortcuts (1x1/2 convs) are real crossbar layers on the
//!   skip path, so `n_conv()` counts 20 for ResNet-18 (17 trunk + 3
//!   projections), while the canonical "18" counts trunk convs + FC.

use super::layer::Layer;
use super::network::Network;

/// ResNet variant identifiers (basic-block family).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ResNetVariant {
    /// ResNet-18: [2, 2, 2, 2] basic blocks.
    R18,
    /// ResNet-34: [3, 4, 6, 3] basic blocks.
    R34,
}

impl ResNetVariant {
    /// Every variant, in depth order.
    pub const ALL: [ResNetVariant; 2] = [ResNetVariant::R18, ResNetVariant::R34];

    /// Workload name (`resnet18` / `resnet34`).
    pub fn name(&self) -> &'static str {
        match self {
            ResNetVariant::R18 => "resnet18",
            ResNetVariant::R34 => "resnet34",
        }
    }

    /// Basic blocks per stage.
    fn blocks(&self) -> [usize; 4] {
        match self {
            ResNetVariant::R18 => [2, 2, 2, 2],
            ResNetVariant::R34 => [3, 4, 6, 3],
        }
    }
}

impl std::str::FromStr for ResNetVariant {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        // Accept separator spellings too: resnet_18 / resnet-18 == resnet18.
        let norm: String = s
            .chars()
            .filter(|&c| c != '_' && c != '-')
            .map(|c| c.to_ascii_lowercase())
            .collect();
        match norm.as_str() {
            "18" | "r18" | "resnet18" => Ok(ResNetVariant::R18),
            "34" | "r34" | "resnet34" => Ok(ResNetVariant::R34),
            other => Err(format!("unknown ResNet variant {other:?} (18 or 34)")),
        }
    }
}

/// Build a ResNet variant at ImageNet resolution (224x224x3, 1000 classes).
pub fn build(variant: ResNetVariant) -> Network {
    build_at(variant, 224, 1000)
}

/// Build at an arbitrary input resolution (must be divisible by 32).
pub fn build_at(variant: ResNetVariant, input_hw: usize, classes: usize) -> Network {
    assert!(input_hw % 32 == 0, "ResNet needs input divisible by 32");
    let mut layers: Vec<Layer> = Vec::new();
    let mut edges: Vec<(usize, usize)> = Vec::new();

    // Stem: 7x7/2 conv (pad 3) with the 2x max-pool fused -> hw/4.
    layers.push(Layer::conv_s(
        "conv1",
        (input_hw, input_hw),
        3,
        64,
        7,
        2,
        3,
        true,
    ));
    let mut trunk = 0usize; // index of the layer feeding the next block
    let mut hw = input_hw / 4;
    let mut ch = 64usize;

    for (stage, &n_blocks) in variant.blocks().iter().enumerate() {
        let out_ch = 64 << stage; // 64, 128, 256, 512
        for block in 0..n_blocks {
            let downsample = stage > 0 && block == 0;
            let stride = if downsample { 2 } else { 1 };
            let out_hw = hw / stride;
            let tag = format!("s{}b{}", stage + 1, block + 1);

            let conv_a = layers.len();
            layers.push(Layer::conv_s(
                format!("{tag}.conv_a"),
                (hw, hw),
                ch,
                out_ch,
                3,
                stride,
                1,
                false,
            ));
            edges.push((trunk, conv_a));

            let conv_b = layers.len();
            layers.push(Layer::conv_s(
                format!("{tag}.conv_b"),
                (out_hw, out_hw),
                out_ch,
                out_ch,
                3,
                1,
                1,
                false,
            ));
            edges.push((conv_a, conv_b));

            // Skip path: identity when shapes match, 1x1/2 projection when
            // the block downsamples.
            let skip = if downsample {
                let down = layers.len();
                layers.push(Layer::conv_s(
                    format!("{tag}.down"),
                    (hw, hw),
                    ch,
                    out_ch,
                    1,
                    2,
                    0,
                    false,
                ));
                edges.push((trunk, down));
                down
            } else {
                trunk
            };

            let add = layers.len();
            layers.push(Layer::add(format!("{tag}.add"), (out_hw, out_hw), out_ch));
            edges.push((conv_b, add));
            edges.push((skip, add));

            trunk = add;
            hw = out_hw;
            ch = out_ch;
        }
    }

    // Head: global average pool then the classifier FC.
    let gap = layers.len();
    layers.push(Layer::global_avg_pool("gap", (hw, hw), ch));
    edges.push((trunk, gap));
    let fc = layers.len();
    layers.push(Layer::fc("fc", ch, classes));
    edges.push((gap, fc));

    Network::from_graph(variant.name(), layers, edges)
        .expect("ResNet construction must validate")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_topology() {
        let net = build(ResNetVariant::R18);
        // 17 trunk convs + 3 projection shortcuts.
        assert_eq!(net.n_conv(), 20);
        assert_eq!(net.n_fc(), 1);
        assert_eq!(net.n_merge(), 8);
        assert_eq!(net.len(), 30);
        assert!(!net.is_linear());
        // Every block contributes 4 edges except the 3 downsample blocks (5),
        // plus stem->first block handled inside, plus gap and fc edges.
        assert_eq!(net.n_edges(), 8 * 4 + 3 + 2);
    }

    #[test]
    fn resnet34_topology() {
        let net = build(ResNetVariant::R34);
        assert_eq!(net.n_conv(), 33 + 3);
        assert_eq!(net.n_merge(), 16);
        assert_eq!(net.n_fc(), 1);
    }

    #[test]
    fn downsample_chain() {
        // 224 -> (stem) 56 -> 28 -> 14 -> 7.
        let net = build(ResNetVariant::R18);
        let adds: Vec<usize> = net
            .layers()
            .iter()
            .filter(|l| l.is_merge())
            .map(|l| l.in_h)
            .collect();
        assert_eq!(adds, vec![56, 56, 28, 28, 14, 14, 7, 7]);
    }

    #[test]
    fn fc_reads_channels_after_gap() {
        let net = build(ResNetVariant::R18);
        let fc = net.layers().last().unwrap();
        assert_eq!(fc.in_ch, 512);
        assert_eq!(fc.out_ch(), 1000);
    }

    #[test]
    fn resnet18_macs_and_params_near_published() {
        // ~1.82 GMACs and ~11.7 M parameters (conv+fc, no BN/bias).
        let net = build(ResNetVariant::R18);
        let g = net.macs() as f64 / 1e9;
        assert!((1.6..2.1).contains(&g), "R18 GMACs = {g}");
        let m = net.weights() as f64 / 1e6;
        assert!((11.0..12.0).contains(&m), "R18 params = {m} M");
    }

    #[test]
    fn resnet34_macs_near_published() {
        // ~3.67 GMACs, ~21.8 M params.
        let net = build(ResNetVariant::R34);
        let g = net.macs() as f64 / 1e9;
        assert!((3.3..4.0).contains(&g), "R34 GMACs = {g}");
        let m = net.weights() as f64 / 1e6;
        assert!((21.0..22.5).contains(&m), "R34 params = {m} M");
    }

    #[test]
    fn parse_variants() {
        assert_eq!(
            "resnet18".parse::<ResNetVariant>().unwrap(),
            ResNetVariant::R18
        );
        assert_eq!("34".parse::<ResNetVariant>().unwrap(), ResNetVariant::R34);
        assert!("resnet50".parse::<ResNetVariant>().is_err());
    }

    #[test]
    fn merge_inputs_are_slowest_predecessor_shaped() {
        // Every Add has exactly two preds and they agree on shape.
        let net = build(ResNetVariant::R34);
        for (i, l) in net.layers().iter().enumerate() {
            if l.is_merge() {
                let p = net.preds(i);
                assert_eq!(p.len(), 2, "{}", l.name);
                let a = &net.layers()[p[0]];
                let b = &net.layers()[p[1]];
                assert_eq!(a.out_hw(), b.out_hw(), "{}", l.name);
                assert_eq!(a.out_ch(), b.out_ch(), "{}", l.name);
            }
        }
    }
}
