//! CNN workload descriptors: layers, networks, and the VGG A-E zoo the
//! paper evaluates (Sec. VI-B).

pub mod layer;
pub mod network;
pub mod vgg;

pub use layer::{Layer, LayerKind};
pub use network::Network;
pub use vgg::VggVariant;
