//! CNN workload descriptors: layers, layer-DAG networks, the VGG A-E zoo
//! the paper evaluates (Sec. VI-B), and the ResNet-18/34 branching
//! workloads that exercise the DAG machinery.

pub mod layer;
pub mod network;
pub mod resnet;
pub mod vgg;

pub use layer::{Layer, LayerKind};
pub use network::Network;
pub use resnet::ResNetVariant;
pub use vgg::VggVariant;

/// Build any named workload: the VGG variants by letter or alias
/// (`A`/`vgg11`/`vggA`, ... `E`/`vgg19`) and the ResNets
/// (`resnet18`/`r18`/`18`, `resnet34`). This is the single name resolver
/// behind `--network` CLI options.
pub fn workload(name: &str) -> Result<Network, String> {
    if let Ok(v) = name.parse::<VggVariant>() {
        return Ok(vgg::build(v));
    }
    if let Ok(r) = name.parse::<ResNetVariant>() {
        return Ok(resnet::build(r));
    }
    Err(format!(
        "unknown network {name:?} (VGG: A..E/vgg11/vgg13/vgg16/vgg19; \
         ResNet: resnet18/resnet34)"
    ))
}

/// Every named workload the repository ships, in reporting order.
pub fn workload_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = VggVariant::ALL.iter().map(|v| v.name()).collect();
    names.extend(ResNetVariant::ALL.iter().map(|r| r.name()));
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_resolves_all_names() {
        for name in workload_names() {
            let net = workload(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(net.len() > 10, "{name}");
        }
        assert!(workload("alexnet").is_err());
    }

    #[test]
    fn workload_vgg_matches_builder() {
        let a = workload("vggE").unwrap();
        let b = vgg::build(VggVariant::E);
        assert_eq!(a.macs(), b.macs());
        assert_eq!(a.len(), b.len());
    }
}
