//! Observability: deterministic trace export, a metrics registry, and
//! self-profiling hooks (DESIGN.md §7).
//!
//! Three independent planes, all zero-dependency:
//!
//! - [`trace`] — an object-safe [`TraceSink`] every engine reports
//!   timeline events to (virtual-cycle timestamps, so recordings are
//!   deterministic per seed), exported as Chrome trace-event JSON via
//!   `--trace-out` on the `noc`, `simulate`, and `cluster` subcommands;
//! - [`metrics`] — named counters/gauges plus bounded-memory streaming
//!   histograms (≤1% relative error), rendered as the `metrics` block in
//!   `--json` outputs;
//! - [`profile`] — wall-clock scoped timers around the hot paths,
//!   aggregated into the `smart-pim profile` report and the bench rows.
//!
//! Contract: instrumentation must never change simulated behavior. With
//! a [`NullSink`] every stat is bit-identical to an uninstrumented
//! build, and a recording run reports exactly the stats of a no-op run
//! (`tests/obs_parity.rs`).

pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{LogHistogram, MetricsRegistry};
pub use trace::{
    chrome_trace, NullSink, RecordingSink, SharedSink, TraceEvent, TracePhase, TraceSink,
};
