//! Metrics registry: named counters, gauges, and log-bucketed streaming
//! histograms with bounded memory and a pinned relative-error guarantee.
//!
//! The registry is the structured replacement for the ad-hoc gauges that
//! used to be bolted onto result structs one field at a time
//! (`events_processed`, `peak_calendar_depth`): engines fold their
//! operation counts into a [`MetricsRegistry`] and every `--json` surface
//! renders it as a `metrics` block. Names are dotted paths
//! (`cluster.events.arrival`), kept in sorted order so rendering is
//! deterministic.
//!
//! # Histogram error math
//!
//! [`LogHistogram`] is a DDSketch-style sketch: a positive sample `v`
//! lands in bucket `i = ceil(ln v / ln GAMMA)` where
//! `GAMMA = (1 + ALPHA) / (1 - ALPHA)`, i.e. bucket `i` covers
//! `(GAMMA^(i-1), GAMMA^i]`. The bucket's representative value is the
//! harmonic midpoint `2 * GAMMA^i / (GAMMA + 1)`, so for every sample in
//! the bucket the relative error of its representative is at most
//! `ALPHA` = 1% (the mirror sweeps 200k random u64s and the worst
//! observed error is exactly 0.0100). Quantiles are nearest-rank over
//! bucket counts, so a quantile estimate inherits the same ≤1% bound
//! relative to the exact nearest-rank sample. Memory is bounded by the
//! bucket span of u64: at most `ceil(ln(2^64) / ln GAMMA)` ≈ 2219
//! buckets, independent of sample count — vs. the store-every-sample
//! exact path that holds 1M+ latencies at cluster scale.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Relative-error bound of [`LogHistogram`] (1%).
pub const ALPHA: f64 = 0.01;

/// Bucket growth factor `(1 + ALPHA) / (1 - ALPHA)`.
const GAMMA: f64 = (1.0 + ALPHA) / (1.0 - ALPHA);

/// Streaming histogram over `u64` samples: bounded memory, ≤[`ALPHA`]
/// relative error on representatives and nearest-rank quantiles. Zero is
/// tracked exactly in its own bucket; `count`, `sum` (hence `mean`),
/// `min`, and `max` are always exact.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHistogram {
    /// Sparse log-bucket counts, keyed by `ceil(ln v / ln GAMMA)`.
    buckets: BTreeMap<i64, u64>,
    zeros: u64,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn observe(&mut self, v: u64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v as u128;
        if v == 0 {
            self.zeros += 1;
        } else {
            *self.buckets.entry(Self::bucket_of(v)).or_insert(0) += 1;
        }
    }

    fn bucket_of(v: u64) -> i64 {
        debug_assert!(v > 0);
        ((v as f64).ln() / GAMMA.ln()).ceil() as i64
    }

    fn representative(i: i64) -> f64 {
        2.0 * (i as f64 * GAMMA.ln()).exp() / (GAMMA + 1.0)
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    /// Live bucket count (memory bound witness).
    pub fn bucket_count(&self) -> usize {
        self.buckets.len() + usize::from(self.zeros > 0)
    }

    /// Nearest-rank percentile estimate, within [`ALPHA`] of the exact
    /// nearest-rank sample, clamped into `[min, max]`. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank <= self.zeros {
            return 0;
        }
        let mut seen = self.zeros;
        for (&i, &c) in &self.buckets {
            seen += c;
            if seen >= rank {
                let est = Self::representative(i).round() as u64;
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary object (count/min/mean/p50/p95/p99/max) for `metrics`
    /// blocks.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", self.count.into()),
            ("min", self.min().into()),
            ("mean", self.mean().into()),
            ("p50", self.percentile(50.0).into()),
            ("p95", self.percentile(95.0).into()),
            ("p99", self.percentile(99.0).into()),
            ("max", self.max().into()),
        ])
    }
}

/// Named counters, gauges, and histograms. Deterministic rendering:
/// `BTreeMap` keeps names sorted, and every value is a pure function of
/// the run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LogHistogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to counter `name` (created at 0).
    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set gauge `name` to `v`.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record a histogram sample under `name`.
    pub fn observe(&mut self, name: &str, v: u64) {
        self.histograms
            .entry(name.to_string())
            .or_default()
            .observe(v);
    }

    /// Install a pre-accumulated histogram under `name` (hot loops build
    /// a local [`LogHistogram`] and fold it in once at the end, avoiding
    /// a map lookup per sample). Replaces any existing entry.
    pub fn set_histogram(&mut self, name: &str, h: LogHistogram) {
        self.histograms.insert(name.to_string(), h);
    }

    /// Current value of counter `name` (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of gauge `name`.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// The histogram under `name`, if any sample was recorded.
    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Render as a `metrics` block: `{"counters": {...}, "gauges": {...},
    /// "histograms": {...}}`, empty sections omitted.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        if !self.counters.is_empty() {
            pairs.push((
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), v.into()))
                        .collect(),
                ),
            ));
        }
        if !self.gauges.is_empty() {
            pairs.push((
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, &v)| (k.clone(), v.into()))
                        .collect(),
                ),
            ));
        }
        if !self.histograms.is_empty() {
            pairs.push((
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn counters_and_gauges_round_trip() {
        let mut m = MetricsRegistry::new();
        m.incr("a.b", 2);
        m.incr("a.b", 3);
        m.gauge("g", 1.5);
        assert_eq!(m.counter("a.b"), 5);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge_value("g"), Some(1.5));
        let doc = m.to_json().render();
        assert!(doc.contains("\"a.b\":5"), "{doc}");
        assert!(doc.contains("\"g\":1.5"), "{doc}");
    }

    #[test]
    fn histogram_exact_fields_are_exact() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 10, 100, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1111);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 222.2).abs() < 1e-9);
        // Zeros are exact: p10 of [0,1,10,100,1000] is 0.
        assert_eq!(h.percentile(10.0), 0);
    }

    #[test]
    fn histogram_representative_error_within_alpha() {
        let mut rng = Rng::new(0x0B5E_9001);
        let mut h = LogHistogram::new();
        let mut samples: Vec<u64> = (0..40_000).map(|_| 1 + rng.below(10_000_000)).collect();
        for &v in &samples {
            h.observe(v);
        }
        samples.sort_unstable();
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let rank = ((p / 100.0 * samples.len() as f64).ceil() as usize)
                .clamp(1, samples.len());
            let exact = samples[rank - 1];
            let est = h.percentile(p);
            let rel = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(rel <= ALPHA + 1e-9, "p{p}: exact {exact} est {est} rel {rel}");
        }
    }

    #[test]
    fn histogram_memory_is_bounded() {
        let mut rng = Rng::new(7);
        let mut h = LogHistogram::new();
        for _ in 0..100_000 {
            h.observe(rng.next_u64());
        }
        // ceil(ln(2^64)/ln(GAMMA)) ≈ 2219 buckets max; far below count.
        assert!(h.bucket_count() <= 2220, "buckets {}", h.bucket_count());
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
