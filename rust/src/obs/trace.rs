//! Trace events and sinks: the crate-wide instrumentation tap.
//!
//! Every engine (NoC, pipeline, cluster, tenant) reports what happened —
//! and *when*, in virtual cycles — through the object-safe [`TraceSink`]
//! trait, mirroring the [`crate::noc::NocBackend`] /
//! [`crate::mapping::MappingBackend`] idiom. The default [`NullSink`]
//! discards everything; a [`RecordingSink`] keeps the event stream and
//! exports it as Chrome trace-event JSON (loadable in Perfetto /
//! `chrome://tracing`).
//!
//! Determinism contract: timestamps are **virtual cycles**, never wall
//! clock, so a recorded trace is a pure function of the run's seed and
//! configuration — two runs with the same seed produce byte-identical
//! trace files. The dual parity contract (pinned by
//! `tests/obs_parity.rs`): a run with the [`NullSink`] is bit-identical
//! to an uninstrumented run, and attaching a [`RecordingSink`] changes
//! no reported stat.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::util::json::Json;

/// What kind of mark an event leaves on the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A point event (Chrome phase `i`).
    Instant,
    /// A closed interval starting at `ts` (Chrome phase `X`).
    Span {
        /// Duration in virtual cycles.
        dur: u64,
    },
    /// A sampled counter value (Chrome phase `C`).
    Counter {
        /// The counter's value at `ts`.
        value: u64,
    },
}

/// One timeline event. `subsystem` maps to a Chrome *process*, `track`
/// to a *thread* within it (a node index, stage index, or router id), so
/// Perfetto groups related activity onto shared swimlanes.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Emitting subsystem (e.g. `"noc"`, `"pipeline"`, `"cluster.node"`).
    pub subsystem: &'static str,
    /// Track (swimlane) within the subsystem.
    pub track: u64,
    /// Event name (static so the hot path never allocates).
    pub name: &'static str,
    /// Timestamp in virtual cycles.
    pub ts: u64,
    /// Instant / span / counter.
    pub phase: TracePhase,
    /// Small numeric payload, rendered into the Chrome `args` object.
    pub args: Vec<(&'static str, u64)>,
}

/// Object-safe event consumer. Hot paths must guard event construction
/// on [`TraceSink::enabled`] so the no-op case costs one branch.
pub trait TraceSink {
    /// Whether events should be built and recorded at all.
    fn enabled(&self) -> bool;
    /// Consume one event (no-op sinks discard it).
    fn record(&mut self, ev: TraceEvent);
    /// Attach a human-readable name to a track (emitted as Chrome
    /// `thread_name` metadata). Default: ignore.
    fn name_track(&mut self, _subsystem: &'static str, _track: u64, _name: &str) {}
}

/// The no-op sink: every un-traced entry point routes through this, and
/// the parity suite pins that doing so changes nothing observable.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }

    fn record(&mut self, _ev: TraceEvent) {}
}

/// Shared sink handle for engines that outlive a single call (the NoC
/// backends own their sink across `step`/`drain`; the caller keeps a
/// clone to read the recording back). Single-threaded by construction —
/// each sweep worker builds its own network and sink.
pub type SharedSink = Rc<RefCell<dyn TraceSink>>;

/// In-memory recording sink.
#[derive(Debug, Clone, Default)]
pub struct RecordingSink {
    events: Vec<TraceEvent>,
    names: BTreeMap<(&'static str, u64), String>,
}

impl RecordingSink {
    /// An empty recording.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap a recording in the shared handle the NoC backends take. Keep
    /// the original `Rc` to inspect the recording after the run:
    /// `Rc::new(RefCell::new(sink))` then coerce clones.
    pub fn shared(self) -> Rc<RefCell<RecordingSink>> {
        Rc::new(RefCell::new(self))
    }

    /// All recorded events, in recording order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events of one subsystem, in recording order.
    pub fn events_for(&self, subsystem: &str) -> Vec<&TraceEvent> {
        self.events
            .iter()
            .filter(|e| e.subsystem == subsystem)
            .collect()
    }

    /// Export as a Chrome trace-event document (see [`chrome_trace`]).
    pub fn chrome_trace(&self) -> Json {
        chrome_trace(&self.events, &self.names)
    }
}

impl TraceSink for RecordingSink {
    fn enabled(&self) -> bool {
        true
    }

    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    fn name_track(&mut self, subsystem: &'static str, track: u64, name: &str) {
        self.names
            .entry((subsystem, track))
            .or_insert_with(|| name.to_string());
    }
}

/// Build a Chrome trace-event JSON document (the `{"traceEvents": [...]}`
/// envelope Perfetto and `chrome://tracing` load directly).
///
/// - each distinct `subsystem` becomes a process (`pid` assigned in
///   lexicographic order, so the mapping is deterministic), announced by
///   `process_name` metadata;
/// - each named track becomes `thread_name` metadata;
/// - events are stably sorted by timestamp, which makes per-track
///   timestamps monotone even when an engine records a span before an
///   earlier-starting span on another arrival path;
/// - `ts`/`dur` carry virtual cycles directly in the microsecond fields
///   (1 cycle renders as 1 "us"), keeping traces seed-deterministic.
pub fn chrome_trace(events: &[TraceEvent], names: &BTreeMap<(&'static str, u64), String>) -> Json {
    let mut subsystems: Vec<&'static str> = events.iter().map(|e| e.subsystem).collect();
    subsystems.extend(names.keys().map(|(s, _)| *s));
    subsystems.sort_unstable();
    subsystems.dedup();
    let pid_of = |s: &str| -> u64 {
        1 + subsystems
            .iter()
            .position(|&x| x == s)
            .expect("subsystem registered") as u64
    };

    let mut out: Vec<Json> = Vec::with_capacity(events.len() + subsystems.len() + names.len());
    for s in &subsystems {
        out.push(Json::obj(vec![
            ("ph", "M".into()),
            ("pid", pid_of(s).into()),
            ("name", "process_name".into()),
            ("args", Json::obj(vec![("name", (*s).into())])),
        ]));
    }
    for ((s, track), name) in names {
        out.push(Json::obj(vec![
            ("ph", "M".into()),
            ("pid", pid_of(s).into()),
            ("tid", (*track).into()),
            ("name", "thread_name".into()),
            ("args", Json::obj(vec![("name", name.as_str().into())])),
        ]));
    }

    let mut ordered: Vec<&TraceEvent> = events.iter().collect();
    ordered.sort_by_key(|e| e.ts);
    for e in ordered {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("name", e.name.into()),
            ("pid", pid_of(e.subsystem).into()),
            ("tid", e.track.into()),
            ("ts", e.ts.into()),
        ];
        match e.phase {
            TracePhase::Instant => {
                pairs.push(("ph", "i".into()));
                pairs.push(("s", "t".into()));
            }
            TracePhase::Span { dur } => {
                pairs.push(("ph", "X".into()));
                pairs.push(("dur", dur.into()));
            }
            TracePhase::Counter { .. } => {
                pairs.push(("ph", "C".into()));
            }
        }
        let mut args: Vec<(&str, Json)> = Vec::with_capacity(e.args.len() + 1);
        if let TracePhase::Counter { value } = e.phase {
            args.push(("value", value.into()));
        }
        for (k, v) in &e.args {
            args.push((k, (*v).into()));
        }
        if !args.is_empty() {
            pairs.push(("args", Json::obj(args)));
        }
        out.push(Json::obj(pairs));
    }
    Json::obj(vec![("traceEvents", Json::Arr(out))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(subsystem: &'static str, track: u64, ts: u64, phase: TracePhase) -> TraceEvent {
        TraceEvent {
            subsystem,
            track,
            name: "e",
            ts,
            phase,
            args: vec![("x", 7)],
        }
    }

    #[test]
    fn null_sink_reports_disabled() {
        assert!(!NullSink.enabled());
        NullSink.record(ev("a", 0, 0, TracePhase::Instant));
    }

    #[test]
    fn recording_sink_keeps_order_and_names() {
        let mut s = RecordingSink::new();
        s.name_track("a", 3, "node 3");
        s.record(ev("a", 3, 10, TracePhase::Span { dur: 5 }));
        s.record(ev("b", 0, 2, TracePhase::Instant));
        assert_eq!(s.len(), 2);
        assert_eq!(s.events()[0].ts, 10);
        assert_eq!(s.events_for("b").len(), 1);
    }

    #[test]
    fn chrome_export_sorts_by_ts_and_round_trips() {
        let mut s = RecordingSink::new();
        s.name_track("beta", 1, "track one");
        s.record(ev("beta", 1, 30, TracePhase::Span { dur: 4 }));
        s.record(ev("alpha", 0, 10, TracePhase::Instant));
        s.record(ev("beta", 1, 20, TracePhase::Counter { value: 9 }));
        let doc = s.chrome_trace();
        let parsed = Json::parse(&doc.render()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name + 1 thread_name + 3 events.
        assert_eq!(evs.len(), 6);
        // Metadata first; then events in ts order regardless of recording
        // order.
        let ts: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() != Some("M"))
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ts, vec![10.0, 20.0, 30.0]);
        // pids are assigned lexicographically: alpha=1, beta=2.
        let first = &evs[0];
        assert_eq!(
            first.get("args").unwrap().get("name").unwrap().as_str(),
            Some("alpha")
        );
        assert_eq!(first.get("pid").unwrap().as_f64(), Some(1.0));
        // Counter events carry their value in args.
        let c = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("C"))
            .unwrap();
        assert_eq!(
            c.get("args").unwrap().get("value").unwrap().as_f64(),
            Some(9.0)
        );
    }

    #[test]
    fn export_is_deterministic() {
        let build = || {
            let mut s = RecordingSink::new();
            s.record(ev("z", 0, 5, TracePhase::Instant));
            s.record(ev("a", 1, 5, TracePhase::Span { dur: 1 }));
            s.chrome_trace().render_pretty()
        };
        assert_eq!(build(), build());
    }
}
