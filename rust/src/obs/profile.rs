//! Self-profiling: scoped wall-clock timers around the crate's hot paths
//! (sweep points, cluster event-loop phases, planner search rounds,
//! engine runs), aggregated into a process-global report.
//!
//! The profiler measures **wall time only** — it never touches virtual
//! cycles, so enabling it cannot change any simulated stat (the parity
//! suite runs with it both off and on). It is disabled by default;
//! when disabled a [`scope`] costs one relaxed atomic load. Sections are
//! thread-safe (sweep points run on worker threads) and keyed by static
//! names, so the report is a deterministic *set* of sections even though
//! the timings themselves are machine-dependent.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(false);

fn sections() -> &'static Mutex<BTreeMap<&'static str, Section>> {
    static SECTIONS: OnceLock<Mutex<BTreeMap<&'static str, Section>>> = OnceLock::new();
    SECTIONS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Aggregated timings of one named code section.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Section {
    /// Times the section was entered.
    pub calls: u64,
    /// Total nanoseconds across all calls.
    pub nanos: u128,
}

impl Section {
    /// Mean nanoseconds per call (0 when never called).
    pub fn mean_nanos(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.nanos as f64 / self.calls as f64
        }
    }
}

/// Turn profiling on (timers start recording).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn profiling off (scopes become one atomic load again).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Whether timers are currently recording.
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drop all recorded sections (does not change enablement).
pub fn reset() {
    sections().lock().unwrap().clear();
}

/// Fold a pre-aggregated measurement into section `name`. Hot loops that
/// cannot afford one `Instant::now` pair per iteration accumulate
/// locally and call this once.
pub fn add(name: &'static str, calls: u64, nanos: u128) {
    if calls == 0 && nanos == 0 {
        return;
    }
    let mut map = sections().lock().unwrap();
    let s = map.entry(name).or_default();
    s.calls += calls;
    s.nanos += nanos;
}

/// RAII timer: measures from construction to drop when profiling is
/// enabled, otherwise does nothing.
pub struct Scope {
    name: &'static str,
    start: Option<Instant>,
}

/// Open a timed scope over `name`.
pub fn scope(name: &'static str) -> Scope {
    Scope {
        name,
        start: is_enabled().then(Instant::now),
    }
}

impl Drop for Scope {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            add(self.name, 1, start.elapsed().as_nanos());
        }
    }
}

/// Snapshot of all sections recorded so far (sorted by name).
pub fn snapshot() -> BTreeMap<&'static str, Section> {
    sections().lock().unwrap().clone()
}

/// Per-section difference `after - before`, dropping empty deltas — the
/// bench uses this to attribute profile time to individual rows.
pub fn delta(
    before: &BTreeMap<&'static str, Section>,
    after: &BTreeMap<&'static str, Section>,
) -> BTreeMap<&'static str, Section> {
    let mut out = BTreeMap::new();
    for (&name, a) in after {
        let b = before.get(name).copied().unwrap_or_default();
        let d = Section {
            calls: a.calls - b.calls,
            nanos: a.nanos - b.nanos,
        };
        if d.calls > 0 || d.nanos > 0 {
            out.insert(name, d);
        }
    }
    out
}

/// Render sections as JSON: `{"section": {"calls": n, "total_ms": x,
/// "mean_us": y}, ...}`.
pub fn sections_json(map: &BTreeMap<&'static str, Section>) -> Json {
    Json::Obj(
        map.iter()
            .map(|(&name, s)| {
                (
                    name.to_string(),
                    Json::obj(vec![
                        ("calls", s.calls.into()),
                        ("total_ms", (s.nanos as f64 / 1e6).into()),
                        ("mean_us", (s.mean_nanos() / 1e3).into()),
                    ]),
                )
            })
            .collect(),
    )
}

/// The current aggregate report as JSON.
pub fn report_json() -> Json {
    sections_json(&snapshot())
}

/// Human-readable report table (one line per section, widest first by
/// total time).
pub fn report_table() -> String {
    let snap = snapshot();
    if snap.is_empty() {
        return "profile: no sections recorded (is profiling enabled?)\n".to_string();
    }
    let mut rows: Vec<(&'static str, Section)> = snap.into_iter().collect();
    rows.sort_by(|a, b| b.1.nanos.cmp(&a.1.nanos).then(a.0.cmp(b.0)));
    let mut out = String::from(
        "section                          calls     total ms      mean us\n",
    );
    for (name, s) in rows {
        out.push_str(&format!(
            "{:<30} {:>8} {:>12.3} {:>12.3}\n",
            name,
            s.calls,
            s.nanos as f64 / 1e6,
            s.mean_nanos() / 1e3,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The profiler is process-global state shared across the test
    // harness's threads: tests that toggle enablement serialize on this
    // lock, and every test uses its own section names.
    static TOGGLE: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_scope_records_nothing() {
        let _guard = TOGGLE.lock().unwrap();
        disable();
        drop(scope("test.disabled"));
        assert!(snapshot().get("test.disabled").is_none());
    }

    #[test]
    fn enabled_scope_records_calls() {
        let _guard = TOGGLE.lock().unwrap();
        enable();
        {
            let _s = scope("test.enabled");
        }
        {
            let _s = scope("test.enabled");
        }
        disable();
        let snap = snapshot();
        let s = snap.get("test.enabled").unwrap();
        assert_eq!(s.calls, 2);
    }

    #[test]
    fn add_and_delta_fold_correctly() {
        add("test.fold", 3, 3_000);
        let before = snapshot();
        add("test.fold", 2, 1_000);
        let d = delta(&before, &snapshot());
        let s = d.get("test.fold").unwrap();
        assert_eq!(s.calls, 2);
        assert_eq!(s.nanos, 1_000);
        assert!((s.mean_nanos() - 500.0).abs() < 1e-9);
        let doc = sections_json(&d).render();
        assert!(doc.contains("\"test.fold\""), "{doc}");
        assert!(report_table().contains("test.fold"));
    }
}
