//! Minimal JSON support (the offline vendored crate set has no `serde` —
//! DESIGN.md §1, substitution 4). Emission publishes machine-readable
//! bench results (`BENCH_noc.json`, `BENCH_cluster.json`); [`Json::parse`]
//! reads them back and loads cluster arrival traces
//! ([`crate::cluster::ArrivalProcess`] trace replay).

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always rendered as f64).
    Num(f64),
    /// A string (escaped on render).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Parse a JSON document (must be a single value plus whitespace).
    /// Numbers parse to f64 — same representation emission uses.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// The value under `key`, if this is an `Obj` containing it.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(kvs) if !kvs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in kvs.iter().enumerate() {
                    pad(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < kvs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Recursion guard: deeper nesting than this is a malformed (or hostile)
/// document, not a bench file or an arrival trace.
const MAX_DEPTH: usize = 128;

/// Recursive-descent reader over the document bytes. Strings are required
/// to be valid UTF-8 because the input is `&str`; escapes cover the forms
/// [`write_str`] emits plus `\uXXXX` (with surrogate pairs).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        // JSON grammar, stricter than f64's FromStr (which would accept
        // "5.", "-.5", "+1", hex, "inf", ...): -? int frac? exp?
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let digits = |p: &mut Self| -> bool {
            let s = p.pos;
            while p.peek().is_some_and(|b| b.is_ascii_digit()) {
                p.pos += 1;
            }
            p.pos > s
        };
        // Integer part: '0' alone or [1-9] then digits (RFC 8259 — no
        // leading zeros).
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                digits(self);
            }
            _ => return Err(format!("bad number at byte {start}: missing digits")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !digits(self) {
                return Err(format!("bad number at byte {start}: missing fraction"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !digits(self) {
                return Err(format!("bad number at byte {start}: missing exponent"));
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii slice");
        let v: f64 = s
            .parse()
            .map_err(|e| format!("bad number {s:?} at byte {start}: {e}"))?;
        if !v.is_finite() {
            // e.g. "1e999": valid grammar, but a non-finite Num would
            // re-render as invalid JSON ("null"), so reject on input.
            return Err(format!("number {s:?} at byte {start} overflows f64"));
        }
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Copy the longest escape-free, ASCII-or-continuation run.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' {
                    break;
                }
                if b < 0x20 {
                    return Err(format!("raw control byte in string at {}", self.pos));
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|e| format!("string is not UTF-8: {e}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: a second \uXXXX must follow.
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| format!("bad \\u escape {c:#x}"))?,
                            );
                        }
                        other => {
                            return Err(format!("unknown escape \\{}", other as char));
                        }
                    }
                }
                _ => return Err("unterminated string".into()),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        let s = self
            .bytes
            .get(self.pos..end)
            .and_then(|b| std::str::from_utf8(b).ok())
            .filter(|s| s.bytes().all(|b| b.is_ascii_hexdigit()))
            .ok_or_else(|| "bad \\u escape (need 4 hex digits)".to_string())?;
        let v = u32::from_str_radix(s, 16).expect("4 hex digits fit u32");
        self.pos = end;
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(3u64).render(), "3");
        assert_eq!(Json::from(3.25).render(), "3.25");
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(
            Json::from("a\"b\\c\nd").render(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::from("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structure_renders() {
        let j = Json::obj(vec![
            ("name", "noc".into()),
            ("rates", Json::Arr(vec![0.02.into(), 0.05.into()])),
            ("ok", true.into()),
        ]);
        assert_eq!(
            j.render(),
            "{\"name\":\"noc\",\"rates\":[0.02,0.05],\"ok\":true}"
        );
    }

    #[test]
    fn pretty_round_trips_content() {
        let j = Json::obj(vec![
            ("a", Json::Arr(vec![1u64.into(), 2u64.into()])),
            ("b", Json::obj(vec![("c", Json::Null)])),
            ("empty", Json::Arr(vec![])),
        ]);
        let p = j.render_pretty();
        assert!(p.contains("\"a\": [\n"));
        assert!(p.contains("\"empty\": []"));
        assert!(p.ends_with("}\n"));
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::from(1042.0).render(), "1042");
        assert_eq!(Json::from(-2.0).render(), "-2");
        // Beyond exact-i64 range falls back to float form.
        assert_eq!(Json::from(1e16).render(), "10000000000000000");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures_and_accessors() {
        let j = Json::parse(r#"{"arrivals": [1, 2.5, 3], "name": "t", "ok": true}"#).unwrap();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("t"));
        let arr = j.get("arrivals").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert!(j.get("missing").is_none());
        assert!(Json::parse("[]").unwrap().as_arr().unwrap().is_empty());
        assert!(Json::parse("{}").unwrap().get("x").is_none());
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(
            Json::parse(r#""a\"b\\c\nd\u0041\u00e9""#).unwrap(),
            Json::Str("a\"b\\c\ndAé".into())
        );
        // Surrogate pair: U+1F600.
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "tru", "[1,", "[1 2]", "{\"a\"}", "{\"a\":}", "1 2", "\"unterminated",
            "[1],", "{'a':1}", "\"\\u12\"", "\"\\ud800\"", "-.5", "5.", "1e999",
            "+1", "-", "1e", "\"\\u+041\"", "01e", "01", "[-012.5]",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn parse_round_trips_render() {
        let j = Json::obj(vec![
            ("name", "noc".into()),
            ("rates", Json::Arr(vec![0.02.into(), 0.05.into()])),
            ("nested", Json::obj(vec![("deep", Json::Arr(vec![Json::Null]))])),
            ("esc", "line\nbreak \"q\"".into()),
        ]);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
        assert_eq!(Json::parse(&j.render_pretty()).unwrap(), j);
    }

    #[test]
    fn parse_depth_bounded() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(50) + &"]".repeat(50);
        assert!(Json::parse(&ok).is_ok());
    }
}
