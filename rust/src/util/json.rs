//! Minimal JSON emission (the offline vendored crate set has no `serde` —
//! DESIGN.md §1, substitution 4). Write-only: enough to publish
//! machine-readable bench results (`BENCH_noc.json`) for trend tracking.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always rendered as f64).
    Num(f64),
    /// A string (escaped on render).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    pad(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(kvs) if !kvs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in kvs.iter().enumerate() {
                    pad(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < kvs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(3u64).render(), "3");
        assert_eq!(Json::from(3.25).render(), "3.25");
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(
            Json::from("a\"b\\c\nd").render(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::from("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structure_renders() {
        let j = Json::obj(vec![
            ("name", "noc".into()),
            ("rates", Json::Arr(vec![0.02.into(), 0.05.into()])),
            ("ok", true.into()),
        ]);
        assert_eq!(
            j.render(),
            "{\"name\":\"noc\",\"rates\":[0.02,0.05],\"ok\":true}"
        );
    }

    #[test]
    fn pretty_round_trips_content() {
        let j = Json::obj(vec![
            ("a", Json::Arr(vec![1u64.into(), 2u64.into()])),
            ("b", Json::obj(vec![("c", Json::Null)])),
            ("empty", Json::Arr(vec![])),
        ]);
        let p = j.render_pretty();
        assert!(p.contains("\"a\": [\n"));
        assert!(p.contains("\"empty\": []"));
        assert!(p.ends_with("}\n"));
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::from(1042.0).render(), "1042");
        assert_eq!(Json::from(-2.0).render(), "-2");
        // Beyond exact-i64 range falls back to float form.
        assert_eq!(Json::from(1e16).render(), "10000000000000000");
    }
}
