//! Micro/macro benchmark harness (the vendored crate set has no criterion).
//!
//! Provides warmup, a target measurement time, outlier-robust statistics and
//! a criterion-like one-line report. Each `rust/benches/*.rs` binary builds
//! on this: `cargo bench` runs them all.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark: per-iteration wall times in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall-clock seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean sample (seconds).
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    /// Median sample (seconds).
    pub fn median(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    /// Sample standard deviation (seconds).
    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_duration(stats::percentile(&self.samples, 5.0)),
            fmt_duration(self.median()),
            fmt_duration(stats::percentile(&self.samples, 95.0)),
            self.samples.len(),
        )
    }
}

/// Format seconds with an auto-scaled unit, criterion style.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner with warmup and a measurement budget.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(300), Duration::from_secs(2), 200)
    }
}

impl Bencher {
    /// A harness with explicit warmup/measurement budgets.
    pub fn new(warmup: Duration, measure: Duration, max_samples: usize) -> Self {
        Self {
            warmup,
            measure,
            max_samples,
            results: Vec::new(),
        }
    }

    /// Quick harness for long-running macro benches: fewer, longer samples.
    pub fn macro_bench() -> Self {
        Self::new(Duration::ZERO, Duration::from_secs(1), 10)
    }

    /// Run `f` repeatedly; `f` returns a value that is black-boxed to stop
    /// the optimizer eliding the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        if samples.is_empty() {
            // Guarantee at least one sample for pathological cases.
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            samples,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Results of every benchmark run so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Opaque value sink (stable-Rust `black_box` substitute usable pre-1.66 and
/// guaranteed side-effectful via `read_volatile`).
pub fn black_box<T>(x: T) -> T {
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::new(Duration::ZERO, Duration::from_millis(50), 20);
        let r = b.bench("noop", || 1 + 1);
        assert!(!r.samples.is_empty());
        assert!(r.samples.len() <= 20);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).ends_with(" us"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn report_contains_name() {
        let r = BenchResult {
            name: "abc".into(),
            samples: vec![0.001, 0.002, 0.0015],
        };
        assert!(r.report().contains("abc"));
    }
}
