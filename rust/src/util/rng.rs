//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so the simulators use a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256** for the
//! stream (Blackman & Vigna). Determinism matters more than statistical
//! perfection here — every benchmark run must be exactly reproducible from
//! its seed, and the NoC property tests replay failures by seed.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed a SplitMix64 stream.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the workhorse PRNG for traffic generation and sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's unbiased method, simplified
    /// rejection form — bound is tiny relative to 2^64 in all our uses).
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection sampling over the largest multiple of `bound`.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (e.g. one per router) with decorrelated state.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_unbiased_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| r.below(100)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 49.5).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left identity");
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(5);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }

    #[test]
    fn forked_streams_decorrelated() {
        let mut root = Rng::new(0);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
