//! Property-testing micro-framework (the vendored crate set has no
//! `proptest`), used by `rust/tests/prop_*.rs`.
//!
//! Philosophy: a property is a function `Fn(&mut Rng) -> Result<(), String>`
//! that draws its own random case and checks an invariant. The runner
//! executes many seeded cases; on failure it retries the failing seed with
//! progressively "smaller" size hints (a lightweight stand-in for proptest
//! shrinking — generators take the size from [`Gen::size`]) and reports the
//! seed so the failure replays deterministically.

use super::rng::Rng;

/// Generation context: a seeded RNG plus a size hint in `[0, 100]`.
pub struct Gen {
    /// Deterministic per-case RNG.
    pub rng: Rng,
    size: u32,
}

impl Gen {
    /// A generator for one case with its derived seed and size.
    pub fn new(seed: u64, size: u32) -> Self {
        Self {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Size hint; generators should scale collection lengths / magnitudes by
    /// this so shrink passes produce smaller counterexamples.
    pub fn size(&self) -> u32 {
        self.size
    }

    /// An integer in `[1, max]` scaled by the size hint (at least 1).
    pub fn scaled(&mut self, max: usize) -> usize {
        let eff = ((max as u64 * self.size as u64) / 100).max(1);
        1 + self.rng.below(eff) as usize
    }

    /// A vector with scaled length, elements from `f`.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let len = self.scaled(max_len);
        (0..len).map(|_| f(&mut self.rng)).collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    /// Cases to run per property.
    pub cases: u32,
    /// Base seed (case i derives from it).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // SMART_PIM_PROP_CASES / SMART_PIM_PROP_SEED override for deep runs
        // and failure replay.
        let cases = std::env::var("SMART_PIM_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("SMART_PIM_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        Self { cases, seed }
    }
}

/// Run `prop` for `cfg.cases` random cases. Panics with the failing seed and
/// the smallest size at which the failure reproduces.
pub fn check(name: &str, cfg: &Config, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut g = Gen::new(case_seed, 100);
        if let Err(msg) = prop(&mut g) {
            // "Shrink": find the smallest size hint that still fails.
            let mut best = (100u32, msg);
            for size in [50, 25, 12, 6, 3, 1] {
                let mut g = Gen::new(case_seed, size);
                if let Err(m) = prop(&mut g) {
                    best = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 min failing size {}): {}\nreplay: SMART_PIM_PROP_SEED={} cargo test",
                best.0, best.1, cfg.seed
            );
        }
    }
}

/// Assert-like helper returning `Err` for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality helper with value dump.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config {
            cases: 32,
            seed: 1,
        };
        check("reverse-involutive", &cfg, |g| {
            let v = g.vec_of(64, |r| r.next_u64());
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert_eq!(v, w);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        let cfg = Config { cases: 4, seed: 2 };
        check("always-fails", &cfg, |_g| Err("nope".into()));
    }

    #[test]
    fn scaled_respects_size() {
        let mut g = Gen::new(3, 1);
        for _ in 0..100 {
            assert!(g.scaled(100) <= 2);
        }
        let mut g = Gen::new(3, 100);
        let mut saw_big = false;
        for _ in 0..100 {
            saw_big |= g.scaled(100) > 50;
        }
        assert!(saw_big);
    }
}
