//! Minimal `anyhow`-style error handling (the offline vendored crate set
//! has no `anyhow` — DESIGN.md §1, substitution 4).
//!
//! Provides the subset the code-base uses: a string-backed [`Error`], a
//! [`Result`] alias with a defaulted error type, the [`Context`] extension
//! trait (`.context(..)` / `.with_context(..)` on `Result` and `Option`),
//! and the [`crate::bail!`] / [`crate::format_err!`] macros. Context is
//! flattened into the message eagerly (`outer: inner`), which keeps the
//! type `Send + Sync + 'static` and one word wide.

use std::fmt;

/// A flattened error: the full context chain rendered into one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error { msg: m }
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error { msg: m.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type (anyhow-style).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates.
pub trait Context<T> {
    /// Wrap the error as `"{msg}: {inner}"`.
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    /// Like [`Context::context`] but lazily built.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string
/// (`anyhow::anyhow!` substitute).
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string (`anyhow::bail!`
/// substitute).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42);
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let e = Err::<(), _>("deep").with_context(|| format!("at {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "at 7: deep");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<Vec<u8>> {
            let b = std::fs::read("/definitely/not/a/file")?;
            Ok(b)
        }
        assert!(read().is_err());
    }

    #[test]
    fn alternate_display_is_stable() {
        // Callers print `{e:#}` (anyhow chain form); our flattened message
        // must render identically either way.
        let e = format_err!("a: {}", "b");
        assert_eq!(format!("{e}"), format!("{e:#}"));
    }
}
