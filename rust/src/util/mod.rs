//! Self-contained utility substrate: PRNG, statistics, tables, CLI parsing,
//! bench harness and a property-testing micro-framework.
//!
//! These exist because the build environment is fully offline: the vendored
//! crate set has no `rand`, `clap`, `criterion` or `proptest`
//! (DESIGN.md §1, substitution 4).

pub mod bench;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use table::Table;
