//! Self-contained utility substrate: PRNG, statistics, tables, CLI parsing,
//! bench harness, error handling, JSON emission and a property-testing
//! micro-framework.
//!
//! These exist because the build environment is fully offline: the vendored
//! crate set has no `rand`, `clap`, `criterion`, `proptest`, `anyhow` or
//! `serde` (DESIGN.md §1, substitution 4).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Rng;
pub use table::Table;
