//! Small statistics helpers used by the simulators and the bench harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; the paper reports all cross-workload speedups this way.
/// Panics on non-positive input (a speedup of <= 0 is a bug upstream).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile by linear interpolation on the sorted copy, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// Minimum of a slice (NaN-free inputs assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum of a slice (NaN-free inputs assumed).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford) for streaming simulators that
/// must not buffer per-packet samples.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample in.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Running sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 2.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn stddev_known_value() {
        // Sample stddev of [2,4,4,4,5,5,7,9] with n-1 = 2.138...
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.add(x);
        }
        assert_eq!(acc.count(), 100);
        assert!((acc.mean() - mean(&xs)).abs() < 1e-9);
        assert!((acc.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(acc.min(), min(&xs));
        assert_eq!(acc.max(), max(&xs));
    }
}
