//! Aligned ASCII table rendering — every `fig*` command and bench prints the
//! paper's tables through this, so the output reads like the paper.

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with a title and column headers.
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let strs: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&strs)
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to an aligned ASCII string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `prec` digits, trimming to a compact form.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1.0".into()]);
        t.row(&["longer-name".into(), "2.25".into()]);
        let out = t.render();
        assert!(out.contains("== demo =="));
        assert!(out.contains("longer-name"));
        // All data lines have equal width.
        let lines: Vec<&str> = out.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{out}");
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fnum(40.4027, 4), "40.4027");
    }
}
