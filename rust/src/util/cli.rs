//! Minimal CLI argument parsing (the vendored crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments,
//! which covers every binary in this repository. Unknown flags are an error
//! so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
/// Parsed `--key value` options, boolean flags, and positionals.
pub struct Args {
    /// `--key value` / `--key=value` options.
    opts: BTreeMap<String, String>,
    /// bare `--flag` switches.
    flags: Vec<String>,
    /// positional arguments in order.
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    ///
    /// `known_flags` lists switches that take no value; everything else that
    /// starts with `--` is treated as a key expecting a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} expects a value"))?;
                    out.opts.insert(body.to_string(), v);
                }
            } else {
                out.pos.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env(known_flags: &[&str]) -> Result<Self, String> {
        Self::parse(std::env::args().skip(1), known_flags)
    }

    /// Was the boolean flag given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or the default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parsed value of `--name`, if present.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{name} {s:?}: {e}")),
        }
    }

    /// Parsed value of `--name`, or the default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    /// Positional (non-option) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.pos
    }

    /// Reject any option not in `allowed` (flags were validated at parse).
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.opts.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown option --{k} (allowed: {allowed:?})"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str], flags: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn parses_key_value_forms() {
        let a = parse(&["--seed", "42", "--mesh=8x8", "run"], &[]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("mesh"), Some("8x8"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn parses_flags() {
        let a = parse(&["--verbose", "--n", "3"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_parse_or::<u32>("n", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--seed".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["--n", "abc"], &[]);
        assert!(a.get_parse::<u32>("n").is_err());
    }

    #[test]
    fn check_known_rejects_typos() {
        let a = parse(&["--sed", "42"], &[]);
        assert!(a.check_known(&["seed"]).is_err());
        let a = parse(&["--seed", "42"], &[]);
        assert!(a.check_known(&["seed"]).is_ok());
    }
}
