//! The paper's 60-benchmark evaluation grid (Sec. VI-B): 5 VGG variants x
//! 4 pipelining scenarios x 3 NoC flow controls.

/// The four pipelining scenarios of Sec. VI-B.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// (1) no weight replication, no batch pipelining — the baseline.
    Baseline,
    /// (2) no weight replication, with batch pipelining.
    BatchOnly,
    /// (3) with weight replication, no batch pipelining.
    ReplicationOnly,
    /// (4) with weight replication and batch pipelining — best case.
    ReplicationBatch,
}

impl Scenario {
    /// The paper's four scenarios, in Fig. 5 order.
    pub const ALL: [Scenario; 4] = [
        Scenario::Baseline,
        Scenario::BatchOnly,
        Scenario::ReplicationOnly,
        Scenario::ReplicationBatch,
    ];

    /// Does this scenario replicate weights (Fig. 7 plans)?
    pub fn replication(&self) -> bool {
        matches!(self, Scenario::ReplicationOnly | Scenario::ReplicationBatch)
    }

    /// Does this scenario enable batch pipelining?
    pub fn batch(&self) -> bool {
        matches!(self, Scenario::BatchOnly | Scenario::ReplicationBatch)
    }

    /// Paper's "(1)".."(4)" labels.
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Baseline => "(1)",
            Scenario::BatchOnly => "(2)",
            Scenario::ReplicationOnly => "(3)",
            Scenario::ReplicationBatch => "(4)",
        }
    }

    /// Long name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Baseline => "no-repl/no-batch",
            Scenario::BatchOnly => "no-repl/batch",
            Scenario::ReplicationOnly => "repl/no-batch",
            Scenario::ReplicationBatch => "repl/batch",
        }
    }
}

/// NoC flow-control selection (Sec. V / VI-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NocKind {
    /// Wormhole flow control — the interconnect baseline.
    Wormhole,
    /// SMART single-cycle multi-hop bypass.
    Smart,
    /// Ideal 1-cycle fully-connected-equivalent interconnect.
    Ideal,
}

impl NocKind {
    /// Every interconnect model, in Fig. 8 row order.
    pub const ALL: [NocKind; 3] = [NocKind::Wormhole, NocKind::Smart, NocKind::Ideal];

    /// Interconnect name (`wormhole` / `smart` / `ideal`).
    pub fn name(&self) -> &'static str {
        match self {
            NocKind::Wormhole => "wormhole",
            NocKind::Smart => "smart",
            NocKind::Ideal => "ideal",
        }
    }
}

/// NoC topology selection (PR 10: the fabric behind the flow control —
/// [`crate::noc::AnyTopology`] is built from this plus the tile grid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// The paper's 2D mesh (the default; all pinned claims use it).
    Mesh,
    /// 2D torus: mesh plus wrap links, shortest-direction routing.
    Torus,
    /// Parallel-Prism-style chain-with-stride pipeline fabric
    /// (arxiv 1906.03474).
    Prism,
}

impl TopologyKind {
    /// Every topology, in reporting order (mesh first: the pinned claim).
    pub const ALL: [TopologyKind; 3] =
        [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Prism];

    /// Topology name (`mesh` / `torus` / `prism`).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Torus => "torus",
            TopologyKind::Prism => "prism",
        }
    }
}

impl std::str::FromStr for TopologyKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "mesh" => Ok(TopologyKind::Mesh),
            "torus" => Ok(TopologyKind::Torus),
            "prism" => Ok(TopologyKind::Prism),
            other => Err(format!("unknown topology {other:?} (mesh|torus|prism)")),
        }
    }
}

impl std::str::FromStr for NocKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "wormhole" => Ok(NocKind::Wormhole),
            "smart" => Ok(NocKind::Smart),
            "ideal" => Ok(NocKind::Ideal),
            other => Err(format!("unknown NoC kind {other:?}")),
        }
    }
}

impl std::str::FromStr for Scenario {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "1" | "baseline" => Ok(Scenario::Baseline),
            "2" | "batch" => Ok(Scenario::BatchOnly),
            "3" | "repl" => Ok(Scenario::ReplicationOnly),
            "4" | "repl-batch" => Ok(Scenario::ReplicationBatch),
            other => Err(format!("unknown scenario {other:?} (1|2|3|4)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_predicates() {
        assert!(!Scenario::Baseline.replication());
        assert!(!Scenario::Baseline.batch());
        assert!(Scenario::BatchOnly.batch() && !Scenario::BatchOnly.replication());
        assert!(Scenario::ReplicationOnly.replication() && !Scenario::ReplicationOnly.batch());
        assert!(Scenario::ReplicationBatch.replication() && Scenario::ReplicationBatch.batch());
    }

    #[test]
    fn parse_round_trip() {
        for s in ["wormhole", "smart", "ideal"] {
            let k: NocKind = s.parse().unwrap();
            assert_eq!(k.name(), s);
        }
        assert!("toroidal".parse::<NocKind>().is_err());
        for s in ["mesh", "torus", "prism"] {
            let t: TopologyKind = s.parse().unwrap();
            assert_eq!(t.name(), s);
        }
        assert!("hypercube".parse::<TopologyKind>().is_err());
        for (s, want) in [("1", Scenario::Baseline), ("4", Scenario::ReplicationBatch)] {
            assert_eq!(s.parse::<Scenario>().unwrap(), want);
        }
    }

    #[test]
    fn grid_is_sixty_benchmarks() {
        // 5 VGGs x 4 scenarios x 3 NoCs = 60 (Sec. VI-B).
        assert_eq!(5 * Scenario::ALL.len() * NocKind::ALL.len(), 60);
    }
}
