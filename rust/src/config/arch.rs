//! Architecture configuration: the paper's node / tile / core / subarray
//! hierarchy (Sec. III) plus the timing calibration constants (DESIGN.md §5).

use super::TopologyKind;

/// Geometry and electrical parameters of one PIM node.
///
/// Defaults reproduce the paper's node: a 16x20 mesh of tiles, 12 cores per
/// tile, 8 subarrays of 128x128 2-bit-MLC ReRAM per core, 16-bit weights and
/// feature maps, 1-bit DACs (bit-serial input over 16 phases) and 8-bit ADCs.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// NoC grid width in tiles (X dimension).
    pub tiles_x: usize,
    /// NoC grid height in tiles (Y dimension).
    pub tiles_y: usize,
    /// NoC topology over the tile grid (paper: 2D mesh; torus and
    /// Parallel-Prism are PR-10 study axes — the pinned claims stay mesh).
    pub topology: TopologyKind,
    /// Cores per tile.
    pub cores_per_tile: usize,
    /// ReRAM subarrays per core.
    pub subarrays_per_core: usize,
    /// Subarray rows (word lines).
    pub subarray_rows: usize,
    /// Subarray columns (bit lines).
    pub subarray_cols: usize,
    /// Bits stored per ReRAM cell (MLC level).
    pub cell_bits: usize,
    /// Weight precision in bits.
    pub weight_bits: usize,
    /// Activation (IFM) precision in bits == DAC phases (1-bit DAC).
    pub act_bits: usize,
    /// ADC resolution in bits.
    pub adc_bits: usize,
    /// NoC link width in bits == flit size (Sec. V: 128).
    pub flit_bits: usize,
    /// Duration of one *logical* cycle (one intra-layer pipeline beat:
    /// 16 bit-serial phases with ADC-pipelined column conversion) in ns.
    /// Calibrated so ideal-NoC VGG-E scenario (4) lands at the paper's
    /// 1042 FPS: 1 / (1042 x 3136) ≈ 306 ns (DESIGN.md §5).
    pub logical_cycle_ns: f64,
    /// NoC router clock period in ns (garnet-style 1 GHz router).
    pub noc_cycle_ns: f64,
    /// SMART: maximum hops bypassed in one cycle (HPC_max; paper Sec. VII
    /// assumes >= 14 for a chip this size).
    pub hpc_max: usize,
    /// Router pipeline depth in NoC cycles for the wormhole baseline
    /// (BW / RC+SA / ST stages, garnet2.0-like 3-stage + link).
    pub router_latency: usize,
    /// Per-input-port flit buffer depth (wormhole).
    pub buffer_depth: usize,
    /// FC layers exceed on-chip capacity and time-multiplex their crossbars;
    /// number of sequential reload rounds charged per FC layer (DESIGN.md §1).
    /// 8 is the smallest power of two under which every Fig. 7 plan meets
    /// the paper's 320-tile constraint.
    pub fc_reload_rounds: u64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::paper_node()
    }
}

impl ArchConfig {
    /// The paper's node exactly as specified in Sec. III / Fig. 4.
    pub fn paper_node() -> Self {
        Self {
            tiles_x: 16,
            tiles_y: 20,
            topology: TopologyKind::Mesh,
            cores_per_tile: 12,
            subarrays_per_core: 8,
            subarray_rows: 128,
            subarray_cols: 128,
            cell_bits: 2,
            weight_bits: 16,
            act_bits: 16,
            adc_bits: 8,
            flit_bits: 128,
            logical_cycle_ns: 306.0,
            noc_cycle_ns: 1.0,
            hpc_max: 14,
            router_latency: 3,
            buffer_depth: 4,
            fc_reload_rounds: 8,
        }
    }

    /// A small node for fast unit tests (same ratios, 4x4 tiles).
    pub fn test_node() -> Self {
        Self {
            tiles_x: 4,
            tiles_y: 4,
            ..Self::paper_node()
        }
    }

    /// Total tiles on the node (paper: 320).
    pub fn total_tiles(&self) -> usize {
        self.tiles_x * self.tiles_y
    }

    /// Subarrays per tile (paper: 96).
    pub fn subarrays_per_tile(&self) -> usize {
        self.cores_per_tile * self.subarrays_per_core
    }

    /// Total subarrays on the node (paper: 30720).
    pub fn total_subarrays(&self) -> usize {
        self.total_tiles() * self.subarrays_per_tile()
    }

    /// Cell columns needed to store one weight (paper: 16/2 = 8 slices).
    pub fn slices_per_weight(&self) -> usize {
        debug_assert_eq!(self.weight_bits % self.cell_bits, 0);
        self.weight_bits / self.cell_bits
    }

    /// Whole weights stored per subarray row (paper: 128/8 = 16).
    pub fn weights_per_row(&self) -> usize {
        self.subarray_cols / self.slices_per_weight()
    }

    /// On-chip weight capacity in bits.
    pub fn weight_capacity_bits(&self) -> u64 {
        (self.total_subarrays() * self.subarray_rows * self.subarray_cols) as u64
            * self.cell_bits as u64
    }

    /// NoC cycles elapsed in one logical cycle.
    pub fn noc_cycles_per_logical(&self) -> f64 {
        self.logical_cycle_ns / self.noc_cycle_ns
    }

    /// 16-bit values carried per flit (paper: 128/16 = 8).
    pub fn values_per_flit(&self) -> usize {
        self.flit_bits / self.act_bits
    }

    /// Validate internal consistency; returns a human-readable error list.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        if self.tiles_x == 0 || self.tiles_y == 0 {
            errs.push("mesh dimensions must be positive".into());
        }
        if self.weight_bits % self.cell_bits != 0 {
            errs.push(format!(
                "weight_bits {} not divisible by cell_bits {}",
                self.weight_bits, self.cell_bits
            ));
        } else if self.subarray_cols % self.slices_per_weight().max(1) != 0 {
            errs.push("subarray columns must hold whole weights".into());
        }
        if self.flit_bits % self.act_bits != 0 {
            errs.push("flit must carry whole values".into());
        }
        if self.logical_cycle_ns <= 0.0 || self.noc_cycle_ns <= 0.0 {
            errs.push("cycle times must be positive".into());
        }
        if self.hpc_max == 0 {
            errs.push("hpc_max must be >= 1".into());
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_node_derived_quantities() {
        let a = ArchConfig::paper_node();
        assert_eq!(a.total_tiles(), 320);
        assert_eq!(a.subarrays_per_tile(), 96);
        assert_eq!(a.total_subarrays(), 30720);
        assert_eq!(a.slices_per_weight(), 8);
        assert_eq!(a.weights_per_row(), 16);
        assert_eq!(a.values_per_flit(), 8);
        a.validate().expect("paper node must validate");
    }

    #[test]
    fn capacity_is_one_gigabit_class() {
        let a = ArchConfig::paper_node();
        // 30720 subarrays x 16384 cells x 2 bits ≈ 1.007 Gbit.
        assert_eq!(a.weight_capacity_bits(), 30720 * 16384 * 2);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut a = ArchConfig::paper_node();
        a.weight_bits = 15; // not divisible by 2
        assert!(a.validate().is_err());
        let mut b = ArchConfig::paper_node();
        b.hpc_max = 0;
        assert!(b.validate().is_err());
    }
}
