//! Config-file support: a minimal `key = value` format (TOML-subset; the
//! vendored crate set has no serde/toml) so deployments can override the
//! paper's node without recompiling.
//!
//! ```text
//! # smart-pim architecture config
//! tiles_x = 16
//! tiles_y = 20
//! cores_per_tile = 12
//! logical_cycle_ns = 306.0
//! hpc_max = 14
//! ```
//!
//! Unknown keys are errors (typos must fail loudly); omitted keys keep the
//! paper-node defaults; the result is re-validated.

use super::arch::ArchConfig;

/// Parse a config string on top of `base`.
pub fn parse_arch(text: &str, base: &ArchConfig) -> Result<ArchConfig, String> {
    let mut cfg = base.clone();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let value = value.trim();
        apply(&mut cfg, key, value).map_err(|e| format!("line {}: {e}", lineno + 1))?;
    }
    cfg.validate()
        .map_err(|errs| format!("invalid config: {}", errs.join("; ")))?;
    Ok(cfg)
}

/// Load from a file path.
pub fn load_arch(path: &str, base: &ArchConfig) -> Result<ArchConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_arch(&text, base)
}

fn apply(cfg: &mut ArchConfig, key: &str, value: &str) -> Result<(), String> {
    fn p<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        value
            .parse::<T>()
            .map_err(|e| format!("{key} = {value:?}: {e}"))
    }
    match key {
        "tiles_x" => cfg.tiles_x = p(key, value)?,
        "tiles_y" => cfg.tiles_y = p(key, value)?,
        "topology" => cfg.topology = p(key, value)?,
        "cores_per_tile" => cfg.cores_per_tile = p(key, value)?,
        "subarrays_per_core" => cfg.subarrays_per_core = p(key, value)?,
        "subarray_rows" => cfg.subarray_rows = p(key, value)?,
        "subarray_cols" => cfg.subarray_cols = p(key, value)?,
        "cell_bits" => cfg.cell_bits = p(key, value)?,
        "weight_bits" => cfg.weight_bits = p(key, value)?,
        "act_bits" => cfg.act_bits = p(key, value)?,
        "adc_bits" => cfg.adc_bits = p(key, value)?,
        "flit_bits" => cfg.flit_bits = p(key, value)?,
        "logical_cycle_ns" => cfg.logical_cycle_ns = p(key, value)?,
        "noc_cycle_ns" => cfg.noc_cycle_ns = p(key, value)?,
        "hpc_max" => cfg.hpc_max = p(key, value)?,
        "router_latency" => cfg.router_latency = p(key, value)?,
        "buffer_depth" => cfg.buffer_depth = p(key, value)?,
        "fc_reload_rounds" => cfg.fc_reload_rounds = p(key, value)?,
        other => {
            return Err(format!(
                "unknown key {other:?} (see config/parse.rs for the schema)"
            ))
        }
    }
    Ok(())
}

/// Render a config back to the file format (round-trips through
/// `parse_arch`; used by `smart-pim` to dump the active config).
pub fn render_arch(cfg: &ArchConfig) -> String {
    format!(
        "# smart-pim architecture config\n\
         tiles_x = {}\ntiles_y = {}\ntopology = {}\ncores_per_tile = {}\n\
         subarrays_per_core = {}\nsubarray_rows = {}\nsubarray_cols = {}\n\
         cell_bits = {}\nweight_bits = {}\nact_bits = {}\nadc_bits = {}\n\
         flit_bits = {}\nlogical_cycle_ns = {}\nnoc_cycle_ns = {}\n\
         hpc_max = {}\nrouter_latency = {}\nbuffer_depth = {}\n\
         fc_reload_rounds = {}\n",
        cfg.tiles_x,
        cfg.tiles_y,
        cfg.topology.name(),
        cfg.cores_per_tile,
        cfg.subarrays_per_core,
        cfg.subarray_rows,
        cfg.subarray_cols,
        cfg.cell_bits,
        cfg.weight_bits,
        cfg.act_bits,
        cfg.adc_bits,
        cfg.flit_bits,
        cfg.logical_cycle_ns,
        cfg.noc_cycle_ns,
        cfg.hpc_max,
        cfg.router_latency,
        cfg.buffer_depth,
        cfg.fc_reload_rounds,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_keeps_defaults() {
        let base = ArchConfig::paper_node();
        let cfg = parse_arch("", &base).unwrap();
        assert_eq!(cfg, base);
    }

    #[test]
    fn overrides_apply() {
        let base = ArchConfig::paper_node();
        let cfg = parse_arch(
            "tiles_x = 8\n# comment\nhpc_max=7\nlogical_cycle_ns = 100.5\n",
            &base,
        )
        .unwrap();
        assert_eq!(cfg.tiles_x, 8);
        assert_eq!(cfg.hpc_max, 7);
        assert_eq!(cfg.logical_cycle_ns, 100.5);
        assert_eq!(cfg.tiles_y, base.tiles_y);
    }

    #[test]
    fn unknown_key_rejected_with_line() {
        let err = parse_arch("tiles = 8\n", &ArchConfig::paper_node()).unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("unknown key"), "{err}");
    }

    #[test]
    fn bad_value_rejected() {
        let err = parse_arch("tiles_x = lots\n", &ArchConfig::paper_node()).unwrap_err();
        assert!(err.contains("tiles_x"), "{err}");
    }

    #[test]
    fn invalid_result_rejected() {
        // weight_bits 15 not divisible by cell_bits 2 -> validation error.
        let err = parse_arch("weight_bits = 15\n", &ArchConfig::paper_node()).unwrap_err();
        assert!(err.contains("invalid config"), "{err}");
    }

    #[test]
    fn missing_equals_rejected() {
        let err = parse_arch("tiles_x 8\n", &ArchConfig::paper_node()).unwrap_err();
        assert!(err.contains("expected key = value"), "{err}");
    }

    #[test]
    fn render_round_trips() {
        let mut base = ArchConfig::paper_node();
        base.tiles_x = 4;
        base.hpc_max = 9;
        base.topology = crate::config::TopologyKind::Torus;
        let text = render_arch(&base);
        let parsed = parse_arch(&text, &ArchConfig::paper_node()).unwrap();
        assert_eq!(parsed, base);
    }

    #[test]
    fn topology_key_parses() {
        let cfg = parse_arch("topology = prism\n", &ArchConfig::paper_node()).unwrap();
        assert_eq!(cfg.topology, crate::config::TopologyKind::Prism);
        let err = parse_arch("topology = ring\n", &ArchConfig::paper_node()).unwrap_err();
        assert!(err.contains("unknown topology"), "{err}");
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let cfg = parse_arch("\n# only comments\n\n   \n", &ArchConfig::paper_node()).unwrap();
        assert_eq!(cfg, ArchConfig::paper_node());
    }
}
