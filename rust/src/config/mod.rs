//! Configuration layer: architecture geometry/timing and the evaluation
//! grid (scenarios x NoCs x workloads).

pub mod arch;
pub mod parse;
pub mod scenario;

pub use arch::ArchConfig;
pub use parse::{load_arch, parse_arch, render_arch};
pub use scenario::{NocKind, Scenario, TopologyKind};
