//! Area accounting (Fig. 4, area column).

use super::components::aggregates as agg;
use crate::config::ArchConfig;

/// Node area breakdown in mm^2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    /// Tile silicon area (cores + peripherals).
    pub tiles_mm2: f64,
    /// Router array area.
    pub routers_mm2: f64,
}

impl AreaBreakdown {
    /// The paper's node: 320 tiles + routers = 124.848 mm^2.
    pub fn node(arch: &ArchConfig) -> Self {
        let n = arch.total_tiles() as f64;
        Self {
            tiles_mm2: agg::TILE_AREA_MM2 * n,
            routers_mm2: agg::ROUTERS_AREA_MM2 * n / 320.0,
        }
    }

    /// Total node area.
    pub fn total_mm2(&self) -> f64 {
        self.tiles_mm2 + self.routers_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_node_area() {
        let a = AreaBreakdown::node(&ArchConfig::paper_node());
        assert!((a.total_mm2() - 124.848).abs() < 0.01, "{}", a.total_mm2());
    }

    #[test]
    fn scales_with_tile_count() {
        let half = ArchConfig {
            tiles_y: 10,
            ..ArchConfig::paper_node()
        };
        let a = AreaBreakdown::node(&half);
        assert!((a.total_mm2() - 124.848 / 2.0).abs() < 0.01);
    }
}
