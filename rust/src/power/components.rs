//! Fig. 4 — power and area of each hardware component (32 nm, from
//! PUMA [4] and ISAAC [5]), stored verbatim.
//!
//! NOTE (DESIGN.md §5): the paper's leaf rows do not sum to its own stated
//! aggregates (e.g. 1024 DACs at the printed 4 mW would alone exceed the
//! printed 25.081 mW core). The hierarchy rows (core / tile / node) *are*
//! mutually consistent (12 x core + peripherals = tile; 320 x tile + routers
//! = node, matching the stated 108.26944 W and 124.848 mm^2), so energy
//! accounting uses the aggregate rows as authoritative and keeps the leaf
//! rows for reference. A unit test pins every roll-up the paper satisfies.

/// One row of Fig. 4.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComponentRow {
    /// Component name as printed in Fig. 4.
    pub name: &'static str,
    /// Area in mm^2 (per instance unless noted).
    pub area_mm2: f64,
    /// Power in mW when functioning.
    pub power_mw: f64,
    /// Instances at the level the row describes (0 = N/A in the paper).
    pub count: usize,
    /// Free-text spec column (resolution, size, ...).
    pub spec: &'static str,
}

/// Core-level rows (per core).
#[rustfmt::skip]
pub const CORE_ROWS: &[ComponentRow] = &[
    ComponentRow { name: "SUB", area_mm2: 0.0002, power_mw: 2.4, count: 8, spec: "128 x 128, 2-bit MLC" },
    ComponentRow { name: "DAC", area_mm2: 0.00017, power_mw: 4.0, count: 1024, spec: "1-bit resolution" },
    ComponentRow { name: "ADC", area_mm2: 0.0096, power_mw: 16.0, count: 8, spec: "8-bit, 1.28 GS/s" },
    ComponentRow { name: "S&H", area_mm2: 0.00004, power_mw: 0.001, count: 1024, spec: "sample & hold" },
    ComponentRow { name: "S&A", area_mm2: 0.00024, power_mw: 0.2, count: 4, spec: "shift & add" },
    ComponentRow { name: "IR", area_mm2: 0.0021, power_mw: 1.24, count: 1, spec: "2KB eDRAM input reg" },
    ComponentRow { name: "OR", area_mm2: 0.0021, power_mw: 1.24, count: 1, spec: "2KB eDRAM output reg" },
];

/// Tile-level rows (per tile, excluding the 12 cores).
#[rustfmt::skip]
pub const TILE_ROWS: &[ComponentRow] = &[
    ComponentRow { name: "MEM", area_mm2: 0.086, power_mw: 17.66, count: 1, spec: "64KB eDRAM" },
    ComponentRow { name: "TileBus", area_mm2: 0.09, power_mw: 7.0, count: 1, spec: "bus width 384 bit" },
    ComponentRow { name: "SIG", area_mm2: 0.0006, power_mw: 0.52, count: 2, spec: "sigmoid unit" },
    ComponentRow { name: "S&A", area_mm2: 0.00006, power_mw: 0.05, count: 1, spec: "tile shift & add" },
    ComponentRow { name: "MP", area_mm2: 0.00024, power_mw: 0.4, count: 1, spec: "max pooling" },
    ComponentRow { name: "OR", area_mm2: 0.0021, power_mw: 1.24, count: 1, spec: "2KB eDRAM output reg" },
];

/// Aggregate figures as printed in Fig. 4 (authoritative for energy).
pub mod aggregates {
    /// One core, functioning (mW).
    pub const CORE_POWER_MW: f64 = 25.081;
    /// One core (mm^2).
    pub const CORE_AREA_MM2: f64 = 0.01445;
    /// 12 cores (mW).
    pub const CORES_PER_TILE_POWER_MW: f64 = 300.972;
    /// One tile = 12 cores + peripherals (mW).
    pub const TILE_POWER_MW: f64 = 327.842;
    /// One tile (mm^2).
    pub const TILE_AREA_MM2: f64 = 0.3524;
    /// 320 tiles (mW).
    pub const TILES_POWER_MW: f64 = 104909.44;
    /// 320 tiles (mm^2).
    pub const TILES_AREA_MM2: f64 = 112.768;
    /// All 320 routers, total (mW).
    pub const ROUTERS_POWER_MW: f64 = 3360.0;
    /// All 320 routers, total (mm^2).
    pub const ROUTERS_AREA_MM2: f64 = 12.08;
    /// Node peak power (mW) — "every component functioning every cycle".
    pub const NODE_POWER_MW: f64 = 108269.44;
    /// Node area (mm^2).
    pub const NODE_AREA_MM2: f64 = 124.848;

    /// Tile peripherals = tile minus its 12 cores (mW).
    pub const TILE_PERIPHERAL_POWER_MW: f64 = TILE_POWER_MW - CORES_PER_TILE_POWER_MW;
    /// One router (mW).
    pub const ROUTER_POWER_MW: f64 = ROUTERS_POWER_MW / 320.0;
    /// Always-on idle floor of one node (mW): the eDRAM buffers / tile
    /// peripherals (refresh never power-gates) of all 320 tiles plus every
    /// mesh router. This is what an allocated-but-idle fleet replica burns
    /// per the cluster energy model (DESIGN.md §5) — about 11.96 W, ~11 %
    /// of the 108.27 W all-units-firing peak.
    pub const NODE_IDLE_POWER_MW: f64 = TILE_PERIPHERAL_POWER_MW * 320.0 + ROUTERS_POWER_MW;
}

#[cfg(test)]
mod tests {
    use super::aggregates as agg;
    use super::*;

    #[test]
    fn paper_rollups_hold() {
        // The roll-ups the paper's Fig. 4 actually satisfies:
        assert!((agg::CORE_POWER_MW * 12.0 - agg::CORES_PER_TILE_POWER_MW).abs() < 1e-6);
        assert!((agg::TILE_POWER_MW * 320.0 - agg::TILES_POWER_MW).abs() < 0.5);
        assert!(
            (agg::TILES_POWER_MW + agg::ROUTERS_POWER_MW - agg::NODE_POWER_MW).abs() < 1e-6
        );
        assert!(
            (agg::TILES_AREA_MM2 + agg::ROUTERS_AREA_MM2 - agg::NODE_AREA_MM2).abs() < 1e-6
        );
        assert!((agg::TILE_AREA_MM2 * 320.0 - agg::TILES_AREA_MM2).abs() < 0.1);
    }

    #[test]
    fn node_peak_is_108_w() {
        assert!((agg::NODE_POWER_MW / 1000.0 - 108.26944).abs() < 1e-9);
        assert!((agg::NODE_AREA_MM2 - 124.848).abs() < 1e-9);
    }

    #[test]
    fn leaf_rows_present() {
        assert_eq!(CORE_ROWS.len(), 7);
        assert_eq!(TILE_ROWS.len(), 6);
        assert_eq!(CORE_ROWS[0].name, "SUB");
        assert_eq!(CORE_ROWS[0].count, 8);
    }

    #[test]
    fn documented_inconsistency_is_real() {
        // Guard the DESIGN.md note: the printed leaf rows really don't sum
        // to the printed core power (this is the paper, not a typo here).
        let leaf_sum: f64 = CORE_ROWS
            .iter()
            .map(|r| r.power_mw * r.count as f64)
            .sum();
        assert!(leaf_sum > 2.0 * agg::CORE_POWER_MW, "leaf sum {leaf_sum}");
    }

    #[test]
    fn tile_peripheral_power_positive() {
        assert!(agg::TILE_PERIPHERAL_POWER_MW > 0.0);
        assert!(agg::TILE_PERIPHERAL_POWER_MW < 30.0);
        assert!((agg::ROUTER_POWER_MW - 10.5).abs() < 1e-9);
    }

    #[test]
    fn idle_floor_is_a_small_fraction_of_peak() {
        // 320 x 26.87 mW peripherals + 3.36 W routers ≈ 11.958 W.
        assert!((agg::NODE_IDLE_POWER_MW - 11_958.4).abs() < 0.5);
        let frac = agg::NODE_IDLE_POWER_MW / agg::NODE_POWER_MW;
        assert!((0.05..0.2).contains(&frac), "idle fraction {frac}");
    }
}
