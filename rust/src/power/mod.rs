//! Power, energy and area models — the Fig. 4 component table and the
//! per-stage energy accounting behind Fig. 9's TOPS/W.

pub mod area;
pub mod components;
pub mod energy;

pub use area::AreaBreakdown;
pub use energy::{EnergyBreakdown, EnergyModel};
