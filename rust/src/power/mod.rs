//! Power, energy and area models — the Fig. 4 component table, the
//! per-stage energy accounting behind Fig. 9's TOPS/W, and the ReRAM
//! weight-programming (write) cost model behind model swaps.

pub mod area;
pub mod components;
pub mod energy;
pub mod write;

pub use area::AreaBreakdown;
pub use energy::{EnergyBreakdown, EnergyModel};
pub use write::{WriteCost, ROW_WRITE_ENERGY_J, ROW_WRITE_LATENCY_S};
