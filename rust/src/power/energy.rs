//! Energy accounting (Fig. 9): per-image energy by summing the consumed
//! energy of each pipeline stage, as the paper does (Sec. III / VI-D).
//!
//! Model: a layer's replicas collectively process its `out_pixels` positions,
//! one position per core-group logical cycle (or `parallel_windows` positions
//! per cycle under a VW-SDK packing), so the layer's crossbar work is
//! `ceil(out_pixels / parallel_windows) x cores_per_copy` core-cycles
//! *independent of replication* — which is exactly why the paper observes
//! that replication and batch pipelining barely move TOPS/W.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;

use super::components::aggregates as agg;

/// Per-image energy breakdown in millijoules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    /// Crossbar core energy (subarrays + DACs + ADCs + core S&A + IR/OR).
    pub core_mj: f64,
    /// Tile peripheral energy (eDRAM, bus, sigmoid, pool, tile S&A/OR).
    pub tile_mj: f64,
    /// NoC router/link energy.
    pub noc_mj: f64,
}

impl EnergyBreakdown {
    /// Total per-image energy.
    pub fn total_mj(&self) -> f64 {
        self.core_mj + self.tile_mj + self.noc_mj
    }

    /// The additive identity (fold seed for per-layer sums).
    pub fn zero() -> Self {
        Self {
            core_mj: 0.0,
            tile_mj: 0.0,
            noc_mj: 0.0,
        }
    }
}

/// Energy model over a mapped network.
#[derive(Debug, Clone)]
pub struct EnergyModel<'a> {
    arch: &'a ArchConfig,
    /// Energy per flit-hop in pJ (router power / clock, Fig. 4 router row).
    pub flit_hop_pj: f64,
}

impl<'a> EnergyModel<'a> {
    /// An energy model for one architecture.
    pub fn new(arch: &'a ArchConfig) -> Self {
        // 10.5 mW per router at the NoC clock -> pJ per cycle of traversal.
        let flit_hop_pj = agg::ROUTER_POWER_MW * arch.noc_cycle_ns;
        Self { arch, flit_hop_pj }
    }

    /// Active crossbar core-cycles of one layer for one image. Dataflow
    /// stages (`Add` / `Concat` / `GlobalAvgPool`) own zero subarrays
    /// (`SubarrayDemand::subarrays() == 0`), so their core contribution is
    /// structurally 0 — they execute in the tile's S&A/OR path, which is
    /// charged by [`Self::tile_cycles`] instead.
    fn layer_core_cycles(&self, l: &crate::cnn::Layer, lm: &crate::mapping::LayerMapping) -> u64 {
        let cores_per_copy = lm
            .demand
            .subarrays()
            .div_ceil(self.arch.subarrays_per_core) as u64;
        // A VW-SDK packing retires `parallel_windows` output positions per
        // logical cycle from one (larger) copy; im2col has pw = 1.
        l.out_pixels().div_ceil(lm.parallel_windows) * cores_per_copy * lm.reload_rounds
    }

    /// Tile-peripheral cycles of one layer for one image: every tile the
    /// layer owns is powered while the layer streams. For a dataflow stage
    /// this is its single buffer tile over its full streaming window — the
    /// "buffer energy" a weight-less merge/pool stage costs.
    fn layer_tile_cycles(&self, l: &crate::cnn::Layer, lm: &crate::mapping::LayerMapping) -> u64 {
        let rate = lm.replication as u64 * lm.parallel_windows;
        let occupancy = l.out_pixels().div_ceil(rate) * lm.reload_rounds;
        occupancy * lm.tile_ids.len() as u64
    }

    /// Active crossbar core-cycles for one image (replication-invariant).
    pub fn core_cycles(&self, net: &Network, mapping: &NetworkMapping) -> u64 {
        net.layers()
            .iter()
            .zip(&mapping.layers)
            .map(|(l, lm)| self.layer_core_cycles(l, lm))
            .sum()
    }

    /// Tile-cycles: each layer's tiles are powered while the layer streams.
    pub fn tile_cycles(&self, net: &Network, mapping: &NetworkMapping) -> u64 {
        net.layers()
            .iter()
            .zip(&mapping.layers)
            .map(|(l, lm)| self.layer_tile_cycles(l, lm))
            .sum()
    }

    /// Total flit-hops for one image: every OFM value moves from its
    /// producer tile to each consumer layer's tiles over the mesh.
    /// `hops[i]` must be the layer's summed per-successor mean hop count
    /// ([`crate::sim::LayerFlows::copy_hops`]): at a DAG branch point every
    /// successor receives a full OFM copy (matching
    /// `sim::traffic::extract_flows`), so the layer's hop weight is the
    /// sum of its copies' means — on a chain, just the plain mean.
    pub fn flit_hops(&self, net: &Network, _mapping: &NetworkMapping, hops: &[f64]) -> f64 {
        net.layers()
            .iter()
            .zip(hops)
            .map(|(l, &h)| self.layer_flit_hops(l, h))
            .sum()
    }

    /// Flit-hops one layer injects for one image at hop weight `h` (its
    /// summed per-successor mean hop count — fan-out is already folded in).
    fn layer_flit_hops(&self, l: &crate::cnn::Layer, h: f64) -> f64 {
        let vals_per_flit = self.arch.values_per_flit() as f64;
        let values = (l.out_pixels() * l.out_ch() as u64) as f64
            / if l.has_pool() { 4.0 } else { 1.0 };
        (values / vals_per_flit).ceil() * h.max(1.0)
    }

    /// Per-image energy. `mean_hops[i]` is the layer's hop weight: the
    /// summed per-successor mean hop count from layer i's tiles to each
    /// consumer's tiles (see [`EnergyModel::flit_hops`]; sink layers
    /// stream to the output port).
    pub fn image_energy(
        &self,
        net: &Network,
        mapping: &NetworkMapping,
        mean_hops: &[f64],
    ) -> EnergyBreakdown {
        self.layer_energy(net, mapping, mean_hops)
            .iter()
            .fold(EnergyBreakdown::zero(), |acc, e| EnergyBreakdown {
                core_mj: acc.core_mj + e.core_mj,
                tile_mj: acc.tile_mj + e.tile_mj,
                noc_mj: acc.noc_mj + e.noc_mj,
            })
    }

    /// Per-layer energy breakdown for one image, aligned with
    /// `Network::layers()` ([`Self::image_energy`] is its sum). This is the
    /// DAG-aware decomposition: crossbar layers pay core + tile + NoC;
    /// dataflow stages (`Add` / `Concat` / `GlobalAvgPool`) own no
    /// crossbars, so their `core_mj` is exactly 0 and they pay only their
    /// buffer tile plus the fan-out NoC cost already folded into
    /// `mean_hops` (one full OFM copy per DAG successor,
    /// [`crate::sim::LayerFlows::copy_hops`]).
    pub fn layer_energy(
        &self,
        net: &Network,
        mapping: &NetworkMapping,
        mean_hops: &[f64],
    ) -> Vec<EnergyBreakdown> {
        let t_log_s = self.arch.logical_cycle_ns * 1e-9;
        net.layers()
            .iter()
            .zip(&mapping.layers)
            .zip(mean_hops)
            .map(|((l, lm), &h)| EnergyBreakdown {
                // mW x s = mJ on both cycle terms.
                core_mj: self.layer_core_cycles(l, lm) as f64 * agg::CORE_POWER_MW * t_log_s,
                tile_mj: self.layer_tile_cycles(l, lm) as f64
                    * agg::TILE_PERIPHERAL_POWER_MW
                    * t_log_s,
                noc_mj: self.layer_flit_hops(l, h) * self.flit_hop_pj * 1e-9,
            })
            .collect()
    }

    /// Mean per-link energy for one image (mJ): the total flit-hop energy
    /// spread over the topology's directed link set
    /// ([`crate::noc::Topology::n_links`] via the [`AnyTopology`] carrier).
    /// A fleet-planning number: under uniform link utilization this is
    /// what each physical link dissipates per image, and it shifts with
    /// the fabric (a torus moves the same traffic over fewer hops; the
    /// prism's chain links carry pipeline-adjacent traffic at one hop).
    ///
    /// [`AnyTopology`]: crate::noc::AnyTopology
    pub fn mean_link_energy_mj(
        &self,
        topo: &crate::noc::AnyTopology,
        net: &Network,
        mapping: &NetworkMapping,
        hops: &[f64],
    ) -> f64 {
        self.flit_hops(net, mapping, hops) * self.flit_hop_pj * 1e-9 / topo.n_links() as f64
    }

    /// Tera-operations per second per watt given per-image energy.
    /// Dataflow layers contribute 0 MACs to `Network::ops` and 0 core
    /// energy, so DAG workloads divide compute ops by compute-plus-buffer
    /// energy — no double counting. Returns 0 for a zero-energy breakdown
    /// (a weight-less network performs no crossbar ops; reporting 0 beats
    /// the silent NaN/inf a bare division would produce).
    pub fn tops_per_watt(&self, net: &Network, energy: &EnergyBreakdown) -> f64 {
        let mj = energy.total_mj();
        if mj <= 0.0 {
            return 0.0;
        }
        // ops / (energy in J) = ops/J = ops/s per W; scale to tera.
        net.ops() as f64 / (mj * 1e-3) / 1e12
    }

    /// Average power draw (W) at a given throughput, and its fraction of
    /// the node's 108.27 W peak (Fig. 4's "every component functioning"
    /// bound): energy/image x images/second. A non-positive or non-finite
    /// `fps` means "no throughput measured" and reports 0 W rather than
    /// silently propagating 0/NaN/inf into downstream tables.
    pub fn avg_power_w(&self, energy: &EnergyBreakdown, fps: f64) -> f64 {
        if !fps.is_finite() || fps <= 0.0 {
            return 0.0;
        }
        energy.total_mj() * 1e-3 * fps
    }

    /// Fraction of the Fig. 4 peak-power envelope actually used (0 when
    /// `fps` is non-positive or non-finite, like [`Self::avg_power_w`]).
    pub fn peak_utilization(&self, energy: &EnergyBreakdown, fps: f64) -> f64 {
        self.avg_power_w(energy, fps) / (agg::NODE_POWER_MW / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;

    fn setup(v: VggVariant, repl: bool) -> (Network, NetworkMapping, ArchConfig) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let plan = if repl {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        (net, m, arch)
    }

    #[test]
    fn vgg_e_efficiency_in_paper_band() {
        // Fig. 9: VGG-E at 3.5914 TOPS/W; our principled model must land in
        // the same band (2.5 - 4.5 TOPS/W).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        let tpw = em.tops_per_watt(&net, &e);
        assert!((2.0..5.0).contains(&tpw), "VGG-E TOPS/W = {tpw}");
    }

    #[test]
    fn replication_barely_moves_efficiency() {
        // Sec. VI-D: replication/batch don't affect energy efficiency much.
        let (net, m0, arch) = setup(VggVariant::D, false);
        let (_, m1, _) = setup(VggVariant::D, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e0 = em.image_energy(&net, &m0, &hops);
        let e1 = em.image_energy(&net, &m1, &hops);
        let (t0, t1) = (
            em.tops_per_watt(&net, &e0),
            em.tops_per_watt(&net, &e1),
        );
        let ratio = t1 / t0;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn core_cycles_replication_invariant() {
        let (net, m0, arch) = setup(VggVariant::B, false);
        let (_, m1, _) = setup(VggVariant::B, true);
        let em = EnergyModel::new(&arch);
        assert_eq!(em.core_cycles(&net, &m0), em.core_cycles(&net, &m1));
    }

    #[test]
    fn breakdown_is_core_dominated() {
        // The crossbars, not the NoC, dominate energy (paper Sec. VIII).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![3.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        assert!(e.core_mj > e.noc_mj, "core {} vs noc {}", e.core_mj, e.noc_mj);
        assert!(e.core_mj > e.tile_mj);
        assert!(e.total_mj() > 0.0);
    }

    #[test]
    fn peak_utilization_below_one() {
        // Even at the paper's best throughput the node must stay inside its
        // own peak envelope (not every unit fires every cycle).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        let util = em.peak_utilization(&e, 1042.0);
        assert!(util > 0.02, "util {util} implausibly low");
        assert!(util < 1.0, "util {util} exceeds peak envelope");
        assert!((em.avg_power_w(&e, 1042.0) - e.total_mj() * 1.042).abs() < 1e-9);
    }

    #[test]
    fn layer_energy_sums_to_image_energy() {
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.5; net.len()];
        let per_layer = em.layer_energy(&net, &m, &hops);
        assert_eq!(per_layer.len(), net.len());
        let total = em.image_energy(&net, &m, &hops);
        let sum: f64 = per_layer.iter().map(|e| e.total_mj()).sum();
        assert!((sum - total.total_mj()).abs() < 1e-9, "{sum} vs {}", total.total_mj());
    }

    #[test]
    fn dataflow_layers_charge_buffer_and_noc_only() {
        // ResNet's Add / GlobalAvgPool stages own no crossbars: zero core
        // energy, but a positive buffer-tile and fan-out NoC cost.
        use crate::cnn::{resnet, ResNetVariant};
        let arch = ArchConfig::paper_node();
        let net = resnet::build(ResNetVariant::R18);
        let m = NetworkMapping::build(&net, &arch, &ReplicationPlan::none(&net)).unwrap();
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let per_layer = em.layer_energy(&net, &m, &hops);
        let mut dataflow = 0;
        for (l, e) in net.layers().iter().zip(&per_layer) {
            if !l.is_crossbar() {
                dataflow += 1;
                assert_eq!(e.core_mj, 0.0, "{}: dataflow stage drew core energy", l.name);
                assert!(e.tile_mj > 0.0, "{}: buffer tile must cost energy", l.name);
                assert!(e.noc_mj > 0.0, "{}: OFM copies must cost NoC energy", l.name);
            } else {
                assert!(e.core_mj > 0.0, "{}: crossbar layer drew no core energy", l.name);
            }
        }
        assert_eq!(dataflow, 9, "8 Adds + 1 GAP in ResNet-18");
    }

    #[test]
    fn mean_link_energy_sums_back_to_noc_energy() {
        use crate::noc::AnyTopology;
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let topo = AnyTopology::for_node(&arch);
        let per_link = em.mean_link_energy_mj(&topo, &net, &m, &hops);
        let e = em.image_energy(&net, &m, &hops);
        // per-link mean x directed link count == total NoC energy.
        assert!(
            (per_link * topo.n_links() as f64 - e.noc_mj).abs() < 1e-9,
            "{} vs {}",
            per_link * topo.n_links() as f64,
            e.noc_mj
        );
        assert!(per_link > 0.0);
    }

    #[test]
    fn zero_fps_reports_zero_power_not_nan() {
        let (net, m, arch) = setup(VggVariant::A, false);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(em.avg_power_w(&e, bad), 0.0, "fps {bad}");
            assert_eq!(em.peak_utilization(&e, bad), 0.0, "fps {bad}");
        }
        assert!(em.avg_power_w(&e, 100.0) > 0.0, "valid fps must still report");
    }

    #[test]
    fn zero_energy_reports_zero_efficiency_not_inf() {
        let (net, _, arch) = setup(VggVariant::A, false);
        let em = EnergyModel::new(&arch);
        let tpw = em.tops_per_watt(&net, &EnergyBreakdown::zero());
        assert_eq!(tpw, 0.0, "zero energy must not divide to inf/NaN");
    }

    #[test]
    fn vwsdk_mapping_never_costs_more_core_cycles() {
        // VW-SDK retires `parallel_windows` positions per cycle from one
        // (larger) copy; its denser core packing can only reduce the
        // crossbar cycle count (strictly on the VGG stem, tie elsewhere).
        use crate::mapping::{MappingKind, MappingSelection};
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        let m0 = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let m1 = NetworkMapping::build_with(
            &net,
            &arch,
            &plan,
            &MappingSelection::uniform(MappingKind::VwSdk, net.len()),
        )
        .unwrap();
        let em = EnergyModel::new(&arch);
        assert!(
            em.core_cycles(&net, &m1) < em.core_cycles(&net, &m0),
            "stem pw=16 must cut VGG-A crossbar cycles"
        );
        assert!(em.tile_cycles(&net, &m1) <= em.tile_cycles(&net, &m0));
    }

    #[test]
    fn deeper_vgg_more_efficient() {
        // Fig. 9 trend: E > D > A/B/C (more ops per pixel moved).
        let em_of = |v| {
            let (net, m, arch) = setup(v, true);
            let em = EnergyModel::new(&arch);
            let hops = vec![2.0; net.len()];
            let e = em.image_energy(&net, &m, &hops);
            em.tops_per_watt(&net, &e)
        };
        assert!(em_of(VggVariant::E) > em_of(VggVariant::A));
    }
}
