//! Energy accounting (Fig. 9): per-image energy by summing the consumed
//! energy of each pipeline stage, as the paper does (Sec. III / VI-D).
//!
//! Model: a layer's replicas collectively process its `out_pixels` positions,
//! one position per core-group logical cycle, so the layer's crossbar work is
//! `out_pixels x cores_per_copy` core-cycles *independent of replication* —
//! which is exactly why the paper observes that replication and batch
//! pipelining barely move TOPS/W.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;

use super::components::aggregates as agg;

/// Per-image energy breakdown in millijoules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    /// Crossbar core energy (subarrays + DACs + ADCs + core S&A + IR/OR).
    pub core_mj: f64,
    /// Tile peripheral energy (eDRAM, bus, sigmoid, pool, tile S&A/OR).
    pub tile_mj: f64,
    /// NoC router/link energy.
    pub noc_mj: f64,
}

impl EnergyBreakdown {
    /// Total per-image energy.
    pub fn total_mj(&self) -> f64 {
        self.core_mj + self.tile_mj + self.noc_mj
    }
}

/// Energy model over a mapped network.
#[derive(Debug, Clone)]
pub struct EnergyModel<'a> {
    arch: &'a ArchConfig,
    /// Energy per flit-hop in pJ (router power / clock, Fig. 4 router row).
    pub flit_hop_pj: f64,
}

impl<'a> EnergyModel<'a> {
    /// An energy model for one architecture.
    pub fn new(arch: &'a ArchConfig) -> Self {
        // 10.5 mW per router at the NoC clock -> pJ per cycle of traversal.
        let flit_hop_pj = agg::ROUTER_POWER_MW * arch.noc_cycle_ns;
        Self { arch, flit_hop_pj }
    }

    /// Active crossbar core-cycles for one image (replication-invariant).
    pub fn core_cycles(&self, net: &Network, mapping: &NetworkMapping) -> u64 {
        net.layers()
            .iter()
            .zip(&mapping.layers)
            .map(|(l, lm)| {
                let cores_per_copy = lm
                    .demand
                    .subarrays()
                    .div_ceil(self.arch.subarrays_per_core)
                    as u64;
                l.out_pixels() * cores_per_copy * lm.reload_rounds
            })
            .sum()
    }

    /// Tile-cycles: each layer's tiles are powered while the layer streams.
    pub fn tile_cycles(&self, net: &Network, mapping: &NetworkMapping) -> u64 {
        net.layers()
            .iter()
            .zip(&mapping.layers)
            .map(|(l, lm)| {
                let occupancy = l.out_pixels().div_ceil(lm.replication as u64)
                    * lm.reload_rounds;
                occupancy * lm.tile_ids.len() as u64
            })
            .sum()
    }

    /// Total flit-hops for one image: every OFM value moves from its
    /// producer tile to each consumer layer's tiles over the mesh.
    /// `hops[i]` must be the layer's summed per-successor mean hop count
    /// ([`crate::sim::LayerFlows::copy_hops`]): at a DAG branch point every
    /// successor receives a full OFM copy (matching
    /// `sim::traffic::extract_flows`), so the layer's hop weight is the
    /// sum of its copies' means — on a chain, just the plain mean.
    pub fn flit_hops(&self, net: &Network, _mapping: &NetworkMapping, hops: &[f64]) -> f64 {
        let vals_per_flit = self.arch.values_per_flit() as f64;
        net.layers()
            .iter()
            .zip(hops)
            .map(|(l, &h)| {
                let values = (l.out_pixels() * l.out_ch() as u64) as f64
                    / if l.has_pool() { 4.0 } else { 1.0 };
                (values / vals_per_flit).ceil() * h.max(1.0)
            })
            .sum()
    }

    /// Per-image energy. `mean_hops[i]` is the layer's hop weight: the
    /// summed per-successor mean hop count from layer i's tiles to each
    /// consumer's tiles (see [`EnergyModel::flit_hops`]; sink layers
    /// stream to the output port).
    pub fn image_energy(
        &self,
        net: &Network,
        mapping: &NetworkMapping,
        mean_hops: &[f64],
    ) -> EnergyBreakdown {
        let t_log_s = self.arch.logical_cycle_ns * 1e-9;
        let core_mj = self.core_cycles(net, mapping) as f64
            * agg::CORE_POWER_MW
            * t_log_s; // mW * s = mJ? mW*s = mJ yes (1e-3 J)
        let tile_mj = self.tile_cycles(net, mapping) as f64
            * agg::TILE_PERIPHERAL_POWER_MW
            * t_log_s;
        let noc_mj = self.flit_hops(net, mapping, mean_hops) * self.flit_hop_pj * 1e-9;
        EnergyBreakdown {
            core_mj,
            tile_mj,
            noc_mj,
        }
    }

    /// Tera-operations per second per watt given per-image energy.
    pub fn tops_per_watt(&self, net: &Network, energy: &EnergyBreakdown) -> f64 {
        // ops / (energy in J) = ops/J = ops/s per W; scale to tera.
        net.ops() as f64 / (energy.total_mj() * 1e-3) / 1e12
    }

    /// Average power draw (W) at a given throughput, and its fraction of
    /// the node's 108.27 W peak (Fig. 4's "every component functioning"
    /// bound): energy/image x images/second.
    pub fn avg_power_w(&self, energy: &EnergyBreakdown, fps: f64) -> f64 {
        energy.total_mj() * 1e-3 * fps
    }

    /// Fraction of the Fig. 4 peak-power envelope actually used.
    pub fn peak_utilization(&self, energy: &EnergyBreakdown, fps: f64) -> f64 {
        self.avg_power_w(energy, fps) / (agg::NODE_POWER_MW / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;

    fn setup(v: VggVariant, repl: bool) -> (Network, NetworkMapping, ArchConfig) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let plan = if repl {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        (net, m, arch)
    }

    #[test]
    fn vgg_e_efficiency_in_paper_band() {
        // Fig. 9: VGG-E at 3.5914 TOPS/W; our principled model must land in
        // the same band (2.5 - 4.5 TOPS/W).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        let tpw = em.tops_per_watt(&net, &e);
        assert!((2.0..5.0).contains(&tpw), "VGG-E TOPS/W = {tpw}");
    }

    #[test]
    fn replication_barely_moves_efficiency() {
        // Sec. VI-D: replication/batch don't affect energy efficiency much.
        let (net, m0, arch) = setup(VggVariant::D, false);
        let (_, m1, _) = setup(VggVariant::D, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e0 = em.image_energy(&net, &m0, &hops);
        let e1 = em.image_energy(&net, &m1, &hops);
        let (t0, t1) = (
            em.tops_per_watt(&net, &e0),
            em.tops_per_watt(&net, &e1),
        );
        let ratio = t1 / t0;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn core_cycles_replication_invariant() {
        let (net, m0, arch) = setup(VggVariant::B, false);
        let (_, m1, _) = setup(VggVariant::B, true);
        let em = EnergyModel::new(&arch);
        assert_eq!(em.core_cycles(&net, &m0), em.core_cycles(&net, &m1));
    }

    #[test]
    fn breakdown_is_core_dominated() {
        // The crossbars, not the NoC, dominate energy (paper Sec. VIII).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![3.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        assert!(e.core_mj > e.noc_mj, "core {} vs noc {}", e.core_mj, e.noc_mj);
        assert!(e.core_mj > e.tile_mj);
        assert!(e.total_mj() > 0.0);
    }

    #[test]
    fn peak_utilization_below_one() {
        // Even at the paper's best throughput the node must stay inside its
        // own peak envelope (not every unit fires every cycle).
        let (net, m, arch) = setup(VggVariant::E, true);
        let em = EnergyModel::new(&arch);
        let hops = vec![2.0; net.len()];
        let e = em.image_energy(&net, &m, &hops);
        let util = em.peak_utilization(&e, 1042.0);
        assert!(util > 0.02, "util {util} implausibly low");
        assert!(util < 1.0, "util {util} exceeds peak envelope");
        assert!((em.avg_power_w(&e, 1042.0) - e.total_mj() * 1.042).abs() < 1e-9);
    }

    #[test]
    fn deeper_vgg_more_efficient() {
        // Fig. 9 trend: E > D > A/B/C (more ops per pixel moved).
        let em_of = |v| {
            let (net, m, arch) = setup(v, true);
            let em = EnergyModel::new(&arch);
            let hops = vec![2.0; net.len()];
            let e = em.image_energy(&net, &m, &hops);
            em.tops_per_watt(&net, &e)
        };
        assert!(em_of(VggVariant::E) > em_of(VggVariant::A));
    }
}
