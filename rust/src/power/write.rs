//! ReRAM weight-programming (write) cost model.
//!
//! Crossbar reads stream one wordline activation per logical cycle, but
//! *writes* run a program-and-verify loop per row that is orders of
//! magnitude slower and more energetic. The constants follow the
//! leliyliu/trip evaluation model (SNIPPETS.md snippet 2): ~1.76e-4 s and
//! ~6.76e-7 J per crossbar row-write. Programming parallelism: every
//! allocated crossbar is programmed whole (unused cells still get driven
//! to their rest state, matching trip's per-allocated-crossbar
//! accounting), one row programs at a time per *core* (the
//! program-and-verify loop holds the core's shared write/verify
//! datapath), and cores program in parallel — so reprogram latency is the
//! busiest core's row count and reprogram energy is the total row count.
//!
//! [`WriteCost::of_mapping`] scales these constants by a model's mapped
//! subarray footprint from [`NetworkMapping`]; the derived anchors for
//! VGG-A/ResNet-18 are pinned in `rust/tests/golden_tenant.rs` (re-derived
//! in this PR's executable mirror, PRs 5-7 discipline). The cluster's
//! multi-tenant layer ([`crate::cluster::tenant`]) charges one
//! [`WriteCost`] per model swap into `FleetEnergy::weight_writes_j`.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;

/// Seconds to program-and-verify one crossbar row (trip: `write_latency`).
pub const ROW_WRITE_LATENCY_S: f64 = 1.76e-4;

/// Joules to program one crossbar row (trip: `write_energy`).
pub const ROW_WRITE_ENERGY_J: f64 = 6.76e-7;

/// The cost of programming one model's full resident weight footprint
/// onto a node — the price of a model swap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WriteCost {
    /// Total crossbar rows programmed (the energy driver): every resident
    /// subarray times the 128 rows of its array.
    pub rows: u64,
    /// Reprogram latency in logical cycles: the busiest core's rows times
    /// the row-write latency (cores program in parallel, rows within a
    /// core serially).
    pub latency_cycles: u64,
    /// Reprogram energy in joules: `rows x` [`ROW_WRITE_ENERGY_J`].
    pub energy_j: f64,
}

impl WriteCost {
    /// A free swap (useful as a test fixture and for synthetic tenants).
    pub fn zero() -> Self {
        Self {
            rows: 0,
            latency_cycles: 0,
            energy_j: 0.0,
        }
    }

    /// Derive the swap cost of a mapped network: conv layers program all
    /// `replication` copies, FC layers one reload round's share
    /// (successive rounds reuse the same physical arrays — their
    /// steady-state rewrites are the seed pipeline model's concern, not
    /// residency's), dataflow stages hold no weights. Per layer, rows
    /// spread over `tiles x cores_per_tile` cores; the slowest layer's
    /// busiest core sets the latency.
    pub fn of_mapping(net: &Network, mapping: &NetworkMapping, arch: &ArchConfig) -> Self {
        let mut rows_total: u64 = 0;
        let mut worst_rows_per_core: u64 = 0;
        for lm in &mapping.layers {
            let layer = &net.layers()[lm.layer_idx];
            let resident = lm.resident_subarrays(layer) as u64;
            if resident == 0 {
                continue;
            }
            let rows = resident * arch.subarray_rows as u64;
            let cores = (lm.tile_ids.len().max(1) * arch.cores_per_tile) as u64;
            worst_rows_per_core = worst_rows_per_core.max(rows.div_ceil(cores));
            rows_total += rows;
        }
        let cycle_s = arch.logical_cycle_ns * 1e-9;
        Self {
            rows: rows_total,
            latency_cycles: (worst_rows_per_core as f64 * ROW_WRITE_LATENCY_S / cycle_s)
                .ceil() as u64,
            energy_j: rows_total as f64 * ROW_WRITE_ENERGY_J,
        }
    }

    /// Reprogram latency in wall seconds.
    pub fn latency_s(&self, logical_cycle_ns: f64) -> f64 {
        self.latency_cycles as f64 * logical_cycle_ns * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;

    #[test]
    fn constants_match_the_trip_model() {
        assert_eq!(ROW_WRITE_LATENCY_S, 1.76e-4);
        assert_eq!(ROW_WRITE_ENERGY_J, 6.76e-7);
    }

    #[test]
    fn zero_cost_is_free() {
        let z = WriteCost::zero();
        assert_eq!(z.rows, 0);
        assert_eq!(z.latency_cycles, 0);
        assert_eq!(z.energy_j, 0.0);
    }

    #[test]
    fn replication_scales_energy_not_worst_core() {
        // fig7 programs strictly more rows than the unreplicated plan, but
        // both saturate a deep-layer core, so latency ties.
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let none =
            NetworkMapping::build(&net, &arch, &ReplicationPlan::none(&net)).unwrap();
        let fig7 =
            NetworkMapping::build(&net, &arch, &ReplicationPlan::fig7(VggVariant::A))
                .unwrap();
        let wn = WriteCost::of_mapping(&net, &none, &arch);
        let wf = WriteCost::of_mapping(&net, &fig7, &arch);
        assert!(wf.rows > wn.rows, "{} vs {}", wf.rows, wn.rows);
        assert!(wf.energy_j > wn.energy_j);
        assert_eq!(wf.latency_cycles, wn.latency_cycles);
    }

    #[test]
    fn latency_seconds_roundtrip() {
        let w = WriteCost {
            rows: 0,
            latency_cycles: 1_000_000,
            energy_j: 0.0,
        };
        let s = w.latency_s(306.0);
        assert!((s - 0.306).abs() < 1e-12, "{s}");
    }
}
