//! The paper's three pipelining schemes (Sec. IV): intra-layer pipeline
//! depths, inter-layer start conditions (Eqs. 1-2), and the static stage
//! plans consumed by the cycle-accurate engine. Batch pipelining is a
//! property of the engine's injection policy (`crate::sim::engine`).

pub mod inter;
pub mod intra;
pub mod schedule;

pub use inter::InputDemand;
pub use schedule::{build_plans, max_occupancy, StagePlan};
