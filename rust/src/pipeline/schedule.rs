//! Static per-layer pipeline parameters ("stage plans") assembled from the
//! network, its mapping, and the architecture — the input to the
//! cycle-accurate engine in [`crate::sim::engine`].

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;

use super::inter::{demand, InputDemand};
use super::intra;

/// Everything the engine needs to simulate one layer.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub name: String,
    /// Output units the stage emits per image. Conv: pre-pool OFM pixel
    /// positions. FC: its reload rounds (weight-serial crossbar loads).
    pub p_total: u64,
    /// Peak emission rate in units per logical cycle (the replication
    /// factor; FC emits one unit per cycle).
    pub rate: u64,
    /// Intra-layer pipeline depth (Sec. IV-A) in logical cycles.
    pub depth: u64,
    /// Input demand on the previous stage (Sec. IV-B); `stage 0` is fed by
    /// the host and its demand is ignored by the engine.
    pub demand: InputDemand,
}

/// Build stage plans for a mapped network.
pub fn build_plans(net: &Network, mapping: &NetworkMapping, arch: &ArchConfig) -> Vec<StagePlan> {
    let layers = net.layers();
    let mut plans = Vec::with_capacity(layers.len());
    for (i, layer) in layers.iter().enumerate() {
        let lm = &mapping.layers[i];
        let (p_total, rate) = if layer.is_conv() {
            (layer.out_pixels(), lm.replication as u64)
        } else {
            (arch.fc_reload_rounds.max(1), 1)
        };
        let dem = if i == 0 {
            // Fed by the host: the whole image is present at injection.
            InputDemand {
                head: 0,
                slope: 1,
                needs_all: false,
            }
        } else {
            demand(&layers[i - 1], layer)
        };
        plans.push(StagePlan {
            name: layer.name.clone(),
            p_total,
            rate,
            depth: intra::depth_of(lm, layer.has_pool()),
            demand: dem,
        });
    }
    plans
}

/// The injection interval lower bound: the busiest stage's occupancy
/// (`ceil(p_total / rate)`) — what batch pipelining converges to when the
/// NoC is not the bottleneck.
pub fn max_occupancy(plans: &[StagePlan]) -> u64 {
    plans
        .iter()
        .map(|p| p.p_total.div_ceil(p.rate))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;

    fn plans(v: VggVariant, repl: bool) -> Vec<StagePlan> {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let plan = if repl {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        build_plans(&net, &m, &arch)
    }

    #[test]
    fn vgg_e_fig7_interval_is_3136() {
        // conv1: 224*224 / 16 = 3136 — the paper's best-case beat
        // (DESIGN.md §5 calibration anchor).
        let p = plans(VggVariant::E, true);
        assert_eq!(max_occupancy(&p), 3136);
        assert_eq!(p[0].p_total, 224 * 224);
        assert_eq!(p[0].rate, 16);
    }

    #[test]
    fn no_replication_interval_is_50176() {
        let p = plans(VggVariant::E, false);
        assert_eq!(max_occupancy(&p), 50176);
    }

    #[test]
    fn depths_match_mapping() {
        let p = plans(VggVariant::E, true);
        // VGG-E conv1 (no pool) is single-tile under Fig. 7 -> 24 cycles.
        assert_eq!(p[0].depth, 24);
        // conv2 pools and spans multiple tiles at r=16 -> 31 cycles.
        assert_eq!(p[1].depth, 31, "{}", p[1].name);
        // deep 512-channel convs are multi-tile, no pool -> 26.
        let c13 = &p[12];
        assert_eq!(c13.depth, 26, "{}", c13.name);
    }

    #[test]
    fn fc_stages_use_reload_rounds() {
        let arch = ArchConfig::paper_node();
        let p = plans(VggVariant::A, false);
        let fc = &p[p.len() - 3];
        assert_eq!(fc.p_total, arch.fc_reload_rounds);
        assert!(fc.demand.needs_all);
    }

    #[test]
    fn stage0_demand_trivial() {
        let p = plans(VggVariant::A, false);
        assert_eq!(p[0].demand.head, 0);
        assert!(!p[0].demand.needs_all);
    }
}
