//! Static per-layer pipeline parameters ("stage plans") assembled from the
//! network, its mapping, and the architecture — the input to the
//! cycle-accurate engine in [`crate::sim::engine`].
//!
//! Stage plans mirror the network's DAG: each plan records its predecessor
//! stage indices and one [`InputDemand`] per incoming edge. A merge stage
//! (residual `Add` / `Concat`) can only emit once *every* predecessor has
//! covered the demand, so the engine naturally waits on the slowest input
//! path; a linear network degenerates to the seed's chain behavior
//! (`preds[i] == [i-1]`), bit-identically.

use crate::cnn::{LayerKind, Network};
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;

use super::inter::{demand_windowed, InputDemand};
use super::intra;

/// Everything the engine needs to simulate one layer.
#[derive(Debug, Clone)]
pub struct StagePlan {
    /// Layer name (reporting / traces).
    pub name: String,
    /// Output units the stage emits per image. Conv: pre-pool OFM pixel
    /// positions. FC: its reload rounds (weight-serial crossbar loads).
    /// Merge: its OFM pixel positions. Global pool: one.
    pub p_total: u64,
    /// Peak emission rate in units per logical cycle (replication factor x
    /// the mapping's parallel windows — `r` under im2col; FC emits one unit
    /// per cycle; merges pass through at the slowest input rate).
    pub rate: u64,
    /// Intra-layer pipeline depth (Sec. IV-A) in logical cycles.
    pub depth: u64,
    /// Predecessor stage indices (empty for the host-fed source stage).
    pub preds: Vec<usize>,
    /// Input demand on each predecessor (Sec. IV-B), aligned with `preds`.
    pub demands: Vec<InputDemand>,
}

/// Build stage plans for a mapped network.
pub fn build_plans(net: &Network, mapping: &NetworkMapping, arch: &ArchConfig) -> Vec<StagePlan> {
    let layers = net.layers();
    let mut plans: Vec<StagePlan> = Vec::with_capacity(layers.len());
    for (i, layer) in layers.iter().enumerate() {
        let lm = &mapping.layers[i];
        let preds: Vec<usize> = net.preds(i).to_vec();
        let (p_total, rate, depth) = match layer.kind {
            // A VW-SDK-mapped conv emits `parallel_windows` OFM positions
            // per copy per cycle; im2col packings have parallel_windows = 1,
            // reducing to the seed's rate = r.
            LayerKind::Conv { .. } => (
                layer.out_pixels(),
                lm.replication as u64 * lm.parallel_windows,
                intra::depth_of(lm, layer.has_pool()),
            ),
            LayerKind::Fc { .. } => (
                arch.fc_reload_rounds.max(1),
                1,
                intra::depth_of(lm, false),
            ),
            // A merge streams pixels through as fast as its slowest input
            // delivers them: its effective rate is the min over predecessor
            // stage rates (already resolved — preds precede i in topo
            // order), so replicating the convs around a merge lifts the
            // merge with them and it never becomes an artificial bottleneck.
            LayerKind::Add | LayerKind::Concat => (
                layer.out_pixels(),
                preds
                    .iter()
                    .map(|&p| plans[p].rate)
                    .min()
                    .unwrap_or(1)
                    .max(1),
                intra::DATAFLOW_DEPTH,
            ),
            // The global pool reduces the whole IFM into one emission.
            LayerKind::GlobalAvgPool => (1, 1, intra::DATAFLOW_DEPTH),
        };
        // Each edge's demand reflects the *consumer's* packing window:
        // lm.window is (l, l) under im2col (the seed formula) and the
        // enlarged (wh, ww) patch under VW-SDK.
        let demands: Vec<InputDemand> = preds
            .iter()
            .map(|&p| demand_windowed(&layers[p], layer, lm.window))
            .collect();
        plans.push(StagePlan {
            name: layer.name.clone(),
            p_total,
            rate,
            depth,
            preds,
            demands,
        });
    }
    plans
}

/// The injection interval lower bound: the busiest stage's occupancy
/// (`ceil(p_total / rate)`) — what batch pipelining converges to when the
/// NoC is not the bottleneck. On a DAG this is still exact: every stage
/// serves every image, wherever it sits in the graph.
pub fn max_occupancy(plans: &[StagePlan]) -> u64 {
    plans
        .iter()
        .map(|p| p.p_total.div_ceil(p.rate))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{resnet, vgg, ResNetVariant, VggVariant};
    use crate::mapping::ReplicationPlan;

    fn plans(v: VggVariant, repl: bool) -> Vec<StagePlan> {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let plan = if repl {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        build_plans(&net, &m, &arch)
    }

    #[test]
    fn vgg_e_fig7_interval_is_3136() {
        // conv1: 224*224 / 16 = 3136 — the paper's best-case beat
        // (DESIGN.md §5 calibration anchor).
        let p = plans(VggVariant::E, true);
        assert_eq!(max_occupancy(&p), 3136);
        assert_eq!(p[0].p_total, 224 * 224);
        assert_eq!(p[0].rate, 16);
    }

    #[test]
    fn no_replication_interval_is_50176() {
        let p = plans(VggVariant::E, false);
        assert_eq!(max_occupancy(&p), 50176);
    }

    #[test]
    fn depths_match_mapping() {
        let p = plans(VggVariant::E, true);
        // VGG-E conv1 (no pool) is single-tile under Fig. 7 -> 24 cycles.
        assert_eq!(p[0].depth, 24);
        // conv2 pools and spans multiple tiles at r=16 -> 31 cycles.
        assert_eq!(p[1].depth, 31, "{}", p[1].name);
        // deep 512-channel convs are multi-tile, no pool -> 26.
        let c13 = &p[12];
        assert_eq!(c13.depth, 26, "{}", c13.name);
    }

    #[test]
    fn vwsdk_mapping_scales_conv_rate() {
        use crate::mapping::{MappingKind, MappingSelection};
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        let sel = MappingSelection::uniform(MappingKind::VwSdk, net.len());
        let m = NetworkMapping::build_with(&net, &arch, &plan, &sel).unwrap();
        let p = build_plans(&net, &m, &arch);
        // Stem: (2,8) window -> 16 OFM positions/cycle from one copy.
        assert_eq!(p[0].rate, 16);
        assert_eq!(p[0].p_total, 224 * 224);
        // Deep convs fall back to (1,1): the interval now binds on conv2,
        // 4x better than the seed's unreplicated 50176.
        assert_eq!(max_occupancy(&p), 12544);
    }

    #[test]
    fn fc_stages_use_reload_rounds() {
        let arch = ArchConfig::paper_node();
        let p = plans(VggVariant::A, false);
        let fc = &p[p.len() - 3];
        assert_eq!(fc.p_total, arch.fc_reload_rounds);
        assert!(fc.demands[0].needs_all);
    }

    #[test]
    fn linear_plans_chain_preds() {
        let p = plans(VggVariant::A, false);
        assert!(p[0].preds.is_empty() && p[0].demands.is_empty());
        for (i, plan) in p.iter().enumerate().skip(1) {
            assert_eq!(plan.preds, vec![i - 1]);
            assert_eq!(plan.demands.len(), 1);
        }
    }

    #[test]
    fn resnet_merge_stages_track_slowest_input() {
        let arch = ArchConfig::paper_node();
        let net = resnet::build(ResNetVariant::R18);
        let plan = ReplicationPlan::none(&net);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let p = build_plans(&net, &m, &arch);
        for (i, layer) in net.layers().iter().enumerate() {
            if layer.is_merge() {
                assert_eq!(p[i].preds.len(), 2, "{}", p[i].name);
                assert_eq!(p[i].depth, intra::DATAFLOW_DEPTH);
                let min_pred = p[i].preds.iter().map(|&q| p[q].rate).min().unwrap();
                assert_eq!(p[i].rate, min_pred, "{}", p[i].name);
                assert_eq!(p[i].p_total, layer.out_pixels());
            }
        }
        // The GAP stage emits once and needs everything.
        let gap = &p[p.len() - 2];
        assert_eq!(gap.p_total, 1);
        assert!(gap.demands[0].needs_all);
    }
}
