//! Intra-layer pipelining (Sec. IV-A).
//!
//! One intra-layer pipeline processes one OFM pixel position (all channels)
//! per logical cycle: IR read → DAC → crossbar → S&H → ADC → shift&add →
//! (inter-tile merge) → sigmoid → (maxpool) → OR write. The paper gives four
//! pipeline depths depending on whether the layer maps to a single tile and
//! whether it fuses a pooling step:
//!
//! | mapping      | no pool | pool |
//! |--------------|---------|------|
//! | single tile  | 24      | 29   |
//! | multi tile   | 26      | 31   |

use crate::mapping::LayerMapping;

/// Pipeline depth in logical cycles for a single-tile layer without pooling.
pub const DEPTH_SINGLE: u64 = 24;
/// Additional stages when the layer's replicas span multiple tiles (the
/// partial sums cross the tile boundary through MEM + tile S&A).
pub const MULTI_TILE_EXTRA: u64 = 2;
/// Additional stages for the fused 2x2 max-pool (the MP unit must gather
/// pooled operands from the OR).
pub const POOL_EXTRA: u64 = 5;

/// Pipeline depth of a dataflow stage (`Add` / `Concat` /
/// `GlobalAvgPool`): no crossbar traversal, just an OR read, the
/// shift-and-add (or accumulator) step, and an OR write.
pub const DATAFLOW_DEPTH: u64 = 2;

/// Intra-layer pipeline depth for a mapped layer (Sec. IV-A's four cases).
pub fn depth(single_tile: bool, pool: bool) -> u64 {
    DEPTH_SINGLE
        + if single_tile { 0 } else { MULTI_TILE_EXTRA }
        + if pool { POOL_EXTRA } else { 0 }
}

/// Depth from a resolved mapping entry.
pub fn depth_of(lm: &LayerMapping, pool: bool) -> u64 {
    depth(lm.single_tile, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_four_cases() {
        // Sec. IV-A: 24 / 29 / 26 / 31 cycles.
        assert_eq!(depth(true, false), 24);
        assert_eq!(depth(true, true), 29);
        assert_eq!(depth(false, false), 26);
        assert_eq!(depth(false, true), 31);
    }
}
