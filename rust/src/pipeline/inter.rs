//! Inter-layer pipelining (Sec. IV-B, Eqs. (1)-(2)).
//!
//! Layer i+1 starts as soon as enough of layer i's OFM exists to cover its
//! first kernel window: with a row-major stride,
//!
//!   valuesWait = (w x (l-1) + l) x n        (1)
//!   cyclesWait =  w x (l-1) + l             (2)
//!
//! Pooling between the layers stretches the wait (Sec. VI-C): the consumer's
//! first pooled row needs *two* producer rows, and every consumed pixel
//! needs four produced pixels. We capture both with a linear input-demand
//! model: producing the consumer's output pixel `p` requires
//!
//!   A(p) = pool_factor x (w*(l-1) + l + p) + pool_head
//!
//! producer pixels, where `pool_factor` is 1 (no pool) or 4 (2x2 pool) and
//! `pool_head` adds the extra leading row. A stride-`s` consumer (ResNet
//! downsample convs) advances its window `s` rows/cols per output pixel
//! and therefore consumes `s^2` IFM pixels per output: the slope scales by
//! `s^2` while the first-window head stays `base`. Merge nodes (`Add` /
//! `Concat`) consume pixel-for-pixel (a 1x1 window) on every incoming
//! edge; FC and global-average-pool layers need the whole IFM
//! (`A(p) = everything`).

use crate::cnn::{Layer, LayerKind};

/// Linear input-demand: producer pixels needed before the consumer can emit
/// its p-th output pixel (0-based): `head + slope * p`, saturated at the
/// producer's total output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InputDemand {
    /// Producer pixels needed before the first output pixel.
    pub head: u64,
    /// Additional producer pixels per further output pixel.
    pub slope: u64,
    /// If true the consumer needs the producer's entire OFM first (FC).
    pub needs_all: bool,
}

impl InputDemand {
    /// Producer pixels required to emit output pixel index `p` (0-based),
    /// clamped to `producer_total`.
    pub fn required(&self, p: u64, producer_total: u64) -> u64 {
        if self.needs_all {
            return producer_total;
        }
        (self.head + self.slope * p).min(producer_total)
    }

    /// Largest output pixel count emittable given `avail` producer pixels
    /// (and the producer's total); respects `out_total`.
    pub fn emittable(&self, avail: u64, producer_total: u64, out_total: u64) -> u64 {
        if self.needs_all {
            return if avail >= producer_total { out_total } else { 0 };
        }
        if avail >= producer_total {
            return out_total;
        }
        if avail < self.head {
            return 0;
        }
        (((avail - self.head) / self.slope) + 1).min(out_total)
    }
}

/// Eq. (2): cycles of producer output the consumer waits for (no pooling,
/// unit replication).
pub fn cycles_wait(consumer_ifm_w: usize, consumer_ksize: usize) -> u64 {
    (consumer_ifm_w * (consumer_ksize - 1) + consumer_ksize) as u64
}

/// Eq. (1): values (pixels x kernels) the consumer waits for.
pub fn values_wait(consumer_ifm_w: usize, consumer_ksize: usize, producer_kernels: usize) -> u64 {
    cycles_wait(consumer_ifm_w, consumer_ksize) * producer_kernels as u64
}

/// Build the input-demand model for `consumer` fed by `producer` — one
/// demand per DAG edge. A merge node carries one `InputDemand` per
/// predecessor and can only emit a pixel once **every** input has covered
/// it, so in the engine it waits on the slowest predecessor.
pub fn demand(producer: &Layer, consumer: &Layer) -> InputDemand {
    let k = consumer.ksize();
    demand_windowed(producer, consumer, (k, k))
}

/// [`demand`] for a consumer whose mapping consumes a `(wh, ww)` IFM patch
/// per logical cycle (VW-SDK parallel windows; `(l, l)` reproduces the seed
/// formula exactly). Only the conv head changes — the first emission needs
/// `w*(wh-1) + ww` producer pixels instead of `w*(l-1) + l`; the slope is
/// an amortized per-output-pixel quantity and stays `s^2` (x4 through a
/// pool). Non-conv consumers have no spatial window and ignore `window`.
pub fn demand_windowed(
    producer: &Layer,
    consumer: &Layer,
    window: (usize, usize),
) -> InputDemand {
    match consumer.kind {
        // FC consumes the whole IFM; the global pool likewise reduces over
        // every pixel before it can emit its single output.
        LayerKind::Fc { .. } | LayerKind::GlobalAvgPool => InputDemand {
            head: 0,
            slope: 1,
            needs_all: true,
        },
        // Element-wise merges consume pixel-for-pixel: emitting output
        // pixel p needs input pixel p from this producer (a 1x1 window,
        // so the head is the same as a 1x1 conv's), quadrupled through a
        // pooled producer exactly like the conv case.
        LayerKind::Add | LayerKind::Concat => {
            if producer.has_pool() {
                InputDemand {
                    head: 4 + producer.conv_out_hw().1 as u64,
                    slope: 4,
                    needs_all: false,
                }
            } else {
                InputDemand {
                    head: 1,
                    slope: 1,
                    needs_all: false,
                }
            }
        }
        LayerKind::Conv { stride, .. } => {
            let (wh, ww) = window;
            let base = (consumer.in_w * (wh - 1) + ww) as u64;
            // A stride-s conv advances its window s rows/cols per output
            // pixel, consuming ~s^2 IFM pixels per output (the row-major
            // linear envelope, exactly like the pool rule's factor 4). The
            // first window still needs only `base` pixels.
            let sf = (stride * stride) as u64;
            if producer.has_pool() {
                // 2x2 pool: 4 producer pixels per consumer IFM pixel plus
                // one extra leading producer row.
                InputDemand {
                    head: 4 * base + producer.conv_out_hw().1 as u64,
                    slope: 4 * sf,
                    needs_all: false,
                }
            } else {
                InputDemand {
                    head: base,
                    slope: sf,
                    needs_all: false,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::Layer;

    #[test]
    fn eq2_matches_paper_formula() {
        // w = 224, l = 3: 224*2 + 3 = 451 cycles.
        assert_eq!(cycles_wait(224, 3), 451);
        // 1x1 conv: l = 1 -> wait 1 value.
        assert_eq!(cycles_wait(56, 1), 1);
    }

    #[test]
    fn eq1_scales_by_kernels() {
        assert_eq!(values_wait(224, 3, 64), 451 * 64);
    }

    #[test]
    fn demand_no_pool_is_linear_slope_one() {
        let p = Layer::conv("p", (224, 224), 3, 64, 3, false);
        let c = Layer::conv("c", (224, 224), 64, 64, 3, false);
        let d = demand(&p, &c);
        assert_eq!(d.slope, 1);
        assert_eq!(d.head, 451);
        assert!(!d.needs_all);
        assert_eq!(d.required(0, 50176), 451);
        assert_eq!(d.required(49_999, 50176), 50176); // clamped
    }

    #[test]
    fn demand_after_pool_quadruples() {
        let p = Layer::conv("p", (224, 224), 3, 64, 3, true); // pools to 112
        let c = Layer::conv("c", (112, 112), 64, 128, 3, true);
        let d = demand(&p, &c);
        assert_eq!(d.slope, 4);
        // head = 4*(112*2+3) + 224 = 908 + 224
        assert_eq!(d.head, 4 * 227 + 224);
    }

    #[test]
    fn strided_conv_demand_scales_slope_not_head() {
        // ResNet downsample: stride-2 conv consumes ~4 producer pixels per
        // output; the first window still needs only the base head.
        let p = Layer::conv("p", (56, 56), 64, 64, 3, false);
        let c = Layer::conv_s("c", (56, 56), 64, 128, 3, 2, 1, false);
        let d = demand(&p, &c);
        assert_eq!(d.head, 56 * 2 + 3);
        assert_eq!(d.slope, 4);
        // 1x1/2 projection: head 1, slope 4.
        let proj = Layer::conv_s("d", (56, 56), 64, 128, 1, 2, 0, false);
        let dp = demand(&p, &proj);
        assert_eq!((dp.head, dp.slope), (1, 4));
    }

    #[test]
    fn merge_demand_is_pixel_for_pixel() {
        let p = Layer::conv("p", (56, 56), 64, 64, 3, false);
        let c = Layer::add("sum", (56, 56), 64);
        let d = demand(&p, &c);
        assert_eq!((d.head, d.slope), (1, 1));
        assert!(!d.needs_all);
        // Through a pooled producer the 4x rule applies like for convs.
        let pp = Layer::conv("p", (112, 112), 64, 64, 3, true);
        let c2 = Layer::add("sum", (56, 56), 64);
        let d2 = demand(&pp, &c2);
        assert_eq!((d2.head, d2.slope), (4 + 112, 4));
    }

    #[test]
    fn windowed_demand_at_kernel_size_is_seed_demand() {
        let p = Layer::conv("p", (224, 224), 3, 64, 3, true);
        let c = Layer::conv("c", (112, 112), 64, 128, 3, true);
        assert_eq!(demand_windowed(&p, &c, (3, 3)), demand(&p, &c));
        // A (2,8) parallel window (4x10 patch) enlarges only the head.
        let d = demand_windowed(&p, &c, (4, 10));
        assert_eq!(d.head, 4 * (112 * 3 + 10) as u64 + 224);
        assert_eq!(d.slope, 4);
        // Non-conv consumers ignore the window.
        let fc = Layer::fc("fc", 25088, 4096);
        assert_eq!(demand_windowed(&p, &fc, (9, 9)), demand(&p, &fc));
    }

    #[test]
    fn gap_needs_everything() {
        let p = Layer::conv("p", (7, 7), 512, 512, 3, false);
        let c = Layer::global_avg_pool("gap", (7, 7), 512);
        let d = demand(&p, &c);
        assert!(d.needs_all);
    }

    #[test]
    fn fc_needs_everything() {
        let p = Layer::conv("p", (14, 14), 512, 512, 3, true);
        let c = Layer::fc("fc", 25088, 4096);
        let d = demand(&p, &c);
        assert!(d.needs_all);
        assert_eq!(d.emittable(195, 196, 8), 0);
        assert_eq!(d.emittable(196, 196, 8), 8);
    }

    #[test]
    fn emittable_inverts_required() {
        let d = InputDemand {
            head: 451,
            slope: 1,
            needs_all: false,
        };
        // With exactly required(p) pixels available we can emit p+1 outputs.
        for p in [0u64, 1, 100, 5000] {
            let avail = d.required(p, u64::MAX);
            assert_eq!(d.emittable(avail, u64::MAX, u64::MAX), p + 1);
            assert_eq!(d.emittable(avail - 1, u64::MAX, u64::MAX), p);
        }
        let d4 = InputDemand {
            head: 1132,
            slope: 4,
            needs_all: false,
        };
        for p in [0u64, 1, 77] {
            let avail = d4.required(p, u64::MAX);
            assert_eq!(d4.emittable(avail, u64::MAX, u64::MAX), p + 1);
        }
    }

    #[test]
    fn emittable_caps_at_out_total() {
        let d = InputDemand {
            head: 5,
            slope: 1,
            needs_all: false,
        };
        assert_eq!(d.emittable(1_000_000, 1_000_000, 42), 42);
    }
}
