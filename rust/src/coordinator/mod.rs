//! Serving coordinator (L3 request path): dynamic batcher, pipeline-slot
//! dispatcher, and the worker loop that executes the AOT-compiled quantized
//! CNN via PJRT. Python never runs here.

pub mod batcher;
pub mod dispatch;
pub mod request;
pub mod server;

pub use batcher::{BatchPolicy, FormedBatch};
pub use dispatch::{Dispatcher, PipelineShape};
pub use request::{Request, Response, ServeStats};
pub use server::Server;
