//! Serving coordinator (L3 request path): dynamic batcher, pipeline-slot
//! dispatcher, the mesh-ingress latency model (drained through the
//! [`crate::noc::NocBackend`] trait), startup replication planning driven
//! by the live [`BatchPolicy`] (see [`startup`]), and the worker loop that
//! executes the AOT-compiled quantized CNN via PJRT. Python never runs
//! here.

pub mod batcher;
pub mod clock;
pub mod dispatch;
pub mod ingress;
pub mod request;
pub mod server;
pub mod startup;

pub use batcher::{BatchPolicy, FormedBatch};
pub use clock::{Clock, VirtualClock, WallClock};
pub use dispatch::{Dispatcher, PipelineShape};
pub use ingress::{assess_ingress, IngressReport};
pub use request::{Request, Response, ServeStats};
pub use server::Server;
pub use startup::{policy_batch_depth, startup_plan, StartupPlan};
