//! Dynamic batching policy (pure logic, decoupled from threads so it is
//! property-testable): prefer the largest executable batch the queue can
//! fill; after `max_wait`, serve what is there — padding a nearly-full
//! large batch when the padding overhead beats running singles.
//!
//! Time is integer [`Clock`](super::clock::Clock) ticks, never
//! `std::time::Instant`: the same `form` logic runs under the server's
//! [`WallClock`](super::clock::WallClock) (ticks = µs) and the cluster
//! simulator's [`VirtualClock`](super::clock::VirtualClock) (ticks =
//! cycles), and unit tests just pass integers — no sleeps.

use std::collections::VecDeque;

use super::request::Request;

/// A formed batch: the requests to serve together and how many padding
/// images to append (padding outputs are discarded).
#[derive(Debug)]
pub struct FormedBatch {
    /// The real requests in the batch.
    pub requests: Vec<Request>,
    /// Padding images appended to reach an executable size.
    pub padding: usize,
}

impl FormedBatch {
    /// Executable batch size (requests + padding).
    pub fn size(&self) -> usize {
        self.requests.len() + self.padding
    }
}

/// Batching policy over the supported executable sizes.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Supported batch sizes, descending (e.g. [4, 1]).
    pub sizes: Vec<usize>,
    /// Maximum clock ticks the oldest request may wait before we stop
    /// hoarding (µs under the wall clock, cycles under a virtual one).
    pub max_wait: u64,
    /// Pad to a larger batch when at least this fraction of it is real
    /// work (e.g. 0.5: two reals may ride a 4-batch).
    pub min_fill: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            sizes: vec![4, 1],
            max_wait: 5_000, // 5 ms under the server's µs wall clock
            min_fill: 0.5,
        }
    }
}

impl BatchPolicy {
    /// Decide the next batch from `queue` at tick `now`. Returns `None` to
    /// keep waiting. Pops the consumed requests from the queue.
    pub fn form(&self, queue: &mut VecDeque<Request>, now: u64) -> Option<FormedBatch> {
        let oldest = queue.front()?;
        let biggest = *self.sizes.first()?;
        if queue.len() >= biggest {
            let requests: Vec<Request> = queue.drain(..biggest).collect();
            return Some(FormedBatch {
                requests,
                padding: 0,
            });
        }
        if now.saturating_sub(oldest.submitted) < self.max_wait {
            return None; // hoard a little longer
        }
        // Timeout: serve everything pending with the cheapest shape mix.
        let n = queue.len();
        // Find the smallest supported size >= n worth padding to.
        let padded = self
            .sizes
            .iter()
            .copied()
            .filter(|&s| s >= n && n as f64 >= s as f64 * self.min_fill)
            .min();
        let take = match padded {
            Some(_) => n,
            None => {
                // Serve as many exact batches as possible, then singles.
                let exact = self
                    .sizes
                    .iter()
                    .copied()
                    .filter(|&s| s <= n)
                    .max()
                    .unwrap_or(1);
                exact
            }
        };
        let requests: Vec<Request> = queue.drain(..take).collect();
        let target = padded.unwrap_or(take);
        Some(FormedBatch {
            padding: target - requests.len(),
            requests,
        })
    }

    /// The tick at which `form` stops hoarding a queue whose oldest
    /// request was submitted at `submitted`: its batch-timeout deadline.
    pub fn deadline(&self, submitted: u64) -> u64 {
        submitted + self.max_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, age: u64, now: u64) -> Request {
        Request {
            id,
            image: vec![0.0; 4],
            submitted: now.saturating_sub(age),
        }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy {
            sizes: vec![4, 1],
            max_wait: 5_000,
            min_fill: 0.5,
        }
    }

    #[test]
    fn full_batch_forms_immediately() {
        let now = 10_000;
        let mut q: VecDeque<Request> = (0..5).map(|i| req(i, 0, now)).collect();
        let b = policy().form(&mut q, now).unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.padding, 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn fresh_partial_waits() {
        let now = 10_000;
        let mut q: VecDeque<Request> = (0..2).map(|i| req(i, 1_000, now)).collect();
        assert!(policy().form(&mut q, now).is_none());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn stale_pair_pads_to_four() {
        let now = 20_000;
        let mut q: VecDeque<Request> = (0..2).map(|i| req(i, 10_000, now)).collect();
        let b = policy().form(&mut q, now).unwrap();
        assert_eq!(b.requests.len(), 2);
        assert_eq!(b.padding, 2);
        assert_eq!(b.size(), 4);
        assert!(q.is_empty());
    }

    #[test]
    fn stale_single_runs_alone() {
        let now = 20_000;
        let mut q: VecDeque<Request> = std::iter::once(req(0, 10_000, now)).collect();
        let b = policy().form(&mut q, now).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert_eq!(b.padding, 0); // 1 < 4 * 0.5: not worth padding
        assert_eq!(b.size(), 1);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut q = VecDeque::new();
        assert!(policy().form(&mut q, 0).is_none());
    }

    #[test]
    fn order_preserved_fifo() {
        let now = 10_000;
        let mut q: VecDeque<Request> = (0..6).map(|i| req(i, 0, now)).collect();
        let b = policy().form(&mut q, now).unwrap();
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn timeout_fires_exactly_at_deadline() {
        // Deterministic virtual-time check that needed sleeps before the
        // Clock refactor: one tick before the deadline hoards, at it serves.
        let p = policy();
        let mut q: VecDeque<Request> = std::iter::once(req(0, 0, 100)).collect();
        let deadline = p.deadline(100);
        assert!(p.form(&mut q, deadline - 1).is_none());
        let b = p.form(&mut q, deadline).unwrap();
        assert_eq!(b.requests.len(), 1);
    }
}
