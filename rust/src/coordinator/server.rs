//! The serving loop: a worker thread owns the PJRT runtime (PJRT handles
//! are not Send, so the worker constructs them) and drains a request
//! channel through the dynamic batcher. std threads + channels — the
//! vendored crate set has no tokio, and a single compute-bound worker
//! matches one PIM node anyway.

use std::collections::VecDeque;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::format_err;
use crate::util::error::Result;

use crate::runtime::vgg_tiny::{CLASSES, IMAGE_LEN};
use crate::runtime::{Runtime, VggTiny};

use super::batcher::BatchPolicy;
use super::clock::{Clock, WallClock};
use super::request::{Request, Response, ServeStats};

enum Msg {
    Infer(Request, Sender<Result<Response, String>>),
    Shutdown(Sender<ServeStats>),
}

/// Handle to a running serving coordinator.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: u64,
    /// Tick source shared with the worker (µs since server start); requests
    /// are stamped against it so the batcher sees pure integer time.
    clock: WallClock,
}

impl Server {
    /// Start the worker; fails fast (through the returned channel probe) if
    /// artifacts are missing.
    pub fn start(artifacts_dir: String, policy: BatchPolicy) -> Result<Self> {
        let clock = WallClock::new();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("smart-pim-serve".into())
            .spawn(move || worker_loop(artifacts_dir, policy, clock, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| format_err!("worker died during startup"))?
            .map_err(|e| format_err!("worker startup failed: {e}"))?;
        Ok(Self {
            tx,
            worker: Some(worker),
            next_id: 0,
            clock,
        })
    }

    /// Submit an image; returns a receiver for the response.
    pub fn submit(&mut self, image: Vec<f32>) -> Receiver<Result<Response, String>> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: self.next_id,
            image,
            submitted: self.clock.now(),
        };
        self.next_id += 1;
        // A send error means the worker is gone; the receiver will error.
        let _ = self.tx.send(Msg::Infer(req, rtx));
        rrx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&mut self, image: Vec<f32>) -> Result<Response> {
        self.submit(image)
            .recv()
            .map_err(|_| format_err!("worker dropped the request"))?
            .map_err(|e| format_err!("{e}"))
    }

    /// Stop the worker and collect statistics.
    pub fn shutdown(mut self) -> ServeStats {
        let (stx, srx) = mpsc::channel();
        let _ = self.tx.send(Msg::Shutdown(stx));
        let stats = srx.recv().unwrap_or_default();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        stats
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.worker.take() {
            let (stx, _srx) = mpsc::channel();
            let _ = self.tx.send(Msg::Shutdown(stx));
            let _ = h.join();
        }
    }
}

fn worker_loop(
    artifacts_dir: String,
    policy: BatchPolicy,
    clock: WallClock,
    rx: Receiver<Msg>,
    ready_tx: Sender<Result<(), String>>,
) {
    let model = match Runtime::new(artifacts_dir).and_then(|rt| VggTiny::load(&rt)) {
        Ok(m) => {
            let _ = ready_tx.send(Ok(()));
            m
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return;
        }
    };

    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut waiters: std::collections::HashMap<u64, Sender<Result<Response, String>>> =
        std::collections::HashMap::new();
    let mut stats = ServeStats::default();
    let mut shutdown_to: Option<Sender<ServeStats>> = None;

    'outer: loop {
        // Drain the channel (non-blocking if we already hold work).
        loop {
            let msg = if queue.is_empty() && shutdown_to.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break 'outer,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if queue.is_empty() {
                            break 'outer;
                        }
                        break;
                    }
                }
            };
            match msg {
                Msg::Infer(req, resp_tx) => {
                    if req.image.len() != IMAGE_LEN {
                        let _ = resp_tx.send(Err(format!(
                            "image must be {IMAGE_LEN} floats, got {}",
                            req.image.len()
                        )));
                        continue;
                    }
                    waiters.insert(req.id, resp_tx);
                    queue.push_back(req);
                }
                Msg::Shutdown(stx) => {
                    shutdown_to = Some(stx);
                }
            }
        }

        // Form and serve batches. At shutdown, flush regardless of age.
        let now = clock.now();
        let flushing = shutdown_to.is_some();
        let batch = if flushing && !queue.is_empty() {
            let n = queue.len().min(4);
            let take = if n >= 2 { n } else { 1 };
            Some(super::batcher::FormedBatch {
                padding: if take > 1 { 4 - take } else { 0 },
                requests: queue.drain(..take).collect(),
            })
        } else {
            policy.form(&mut queue, now)
        };

        if let Some(b) = batch {
            let size = b.size();
            stats.record_batch(size);
            let mut flat = Vec::with_capacity(size * IMAGE_LEN);
            for r in &b.requests {
                flat.extend_from_slice(&r.image);
            }
            flat.resize(size * IMAGE_LEN, 0.0);
            match model.infer(&flat) {
                Ok(logits) => {
                    for (i, r) in b.requests.iter().enumerate() {
                        let row = &logits[i * CLASSES..(i + 1) * CLASSES];
                        let class = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0;
                        let resp = Response {
                            id: r.id,
                            logits: row.to_vec(),
                            class,
                            // Queueing + batching + execution, µs ticks on
                            // the shared wall clock.
                            latency: Duration::from_micros(
                                clock.now().saturating_sub(r.submitted),
                            ),
                            batch: size,
                        };
                        stats.record(&resp, Instant::now());
                        if let Some(tx) = waiters.remove(&r.id) {
                            let _ = tx.send(Ok(resp));
                        }
                    }
                }
                Err(e) => {
                    for r in &b.requests {
                        if let Some(tx) = waiters.remove(&r.id) {
                            let _ = tx.send(Err(format!("{e:#}")));
                        }
                    }
                }
            }
        } else if shutdown_to.is_none() {
            // Partial queue still hoarding: nap briefly.
            std::thread::sleep(std::time::Duration::from_micros(200));
        }

        if queue.is_empty() {
            if let Some(stx) = shutdown_to.take() {
                let _ = stx.send(stats.clone());
                break;
            }
        }
    }
}
