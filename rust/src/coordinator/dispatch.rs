//! Pipeline-slot dispatcher: the coordinator-side view of the PIM node's
//! replicated pipelines (Sec. IV-C). Tracks which image occupies each
//! layer-stage at each logical beat and enforces the paper's two batch
//! rules: (a) structural hazard freedom — a layer serves at most one image
//! per beat; (b) per-image layer dependencies follow the same fixed offsets
//! for every image.

/// Static description: per-layer start offset (cycles after the image
/// enters layer 0) and per-layer occupancy (beats the image holds the
/// layer).
#[derive(Debug, Clone)]
pub struct PipelineShape {
    /// Per-layer start offset in beats after injection (critical path).
    pub offsets: Vec<u64>,
    /// Per-layer beats an image holds the layer.
    pub occupancy: Vec<u64>,
}

impl PipelineShape {
    /// Derive from stage plans over the layer DAG: a stage starts once the
    /// *latest* of its predecessors has covered its head-wait, so
    /// `offset_i = max over preds p of (offset_p + head-wait / rate_p +
    /// depth_p)` — the critical (longest) path through the graph. The
    /// pipeline fill time is `offsets[last] + occupancy[last]`. On a linear
    /// chain this reduces exactly to the seed's cumulative-sum recurrence.
    pub fn from_plans(plans: &[crate::pipeline::StagePlan]) -> Self {
        let mut offsets = vec![0u64; plans.len()];
        let mut occupancy = Vec::with_capacity(plans.len());
        for (i, p) in plans.iter().enumerate() {
            let mut off = 0u64;
            for (k, &pi) in p.preds.iter().enumerate() {
                let prev = &plans[pi];
                let head = if p.demands[k].needs_all {
                    prev.p_total
                } else {
                    p.demands[k].head.min(prev.p_total)
                };
                off = off.max(offsets[pi] + head.div_ceil(prev.rate) + prev.depth);
            }
            offsets[i] = off;
            occupancy.push(p.p_total.div_ceil(p.rate));
        }
        Self { offsets, occupancy }
    }

    /// Number of layers in the shape.
    pub fn n_layers(&self) -> usize {
        self.offsets.len()
    }

    /// Minimum injection interval with no structural hazard: the widest
    /// occupancy (each layer must free an image before the next arrives).
    pub fn min_interval(&self) -> u64 {
        self.occupancy.iter().copied().max().unwrap_or(1)
    }

    /// Beat window [start, end) during which image `img` (injected at beat
    /// `inject`) occupies layer `l`.
    pub fn window(&self, inject: u64, l: usize) -> (u64, u64) {
        let s = inject + self.offsets[l];
        (s, s + self.occupancy[l])
    }
}

/// Dispatcher state: injection schedule honoring the hazard rule.
#[derive(Debug)]
pub struct Dispatcher {
    shape: PipelineShape,
    interval: u64,
    /// Injection beats of all admitted images (empty when untracked).
    injections: Vec<u64>,
    /// Whether `admit` logs each injection beat for the verifiers.
    tracked: bool,
    next_free: u64,
}

impl Dispatcher {
    /// A dispatcher enforcing `shape.min_interval()` between injections,
    /// logging every injection beat so the hazard verifiers can audit the
    /// whole schedule.
    pub fn new(shape: PipelineShape) -> Self {
        let interval = shape.min_interval();
        Self {
            shape,
            interval,
            injections: Vec::new(),
            tracked: true,
            next_free: 0,
        }
    }

    /// A dispatcher that skips the per-injection history log — O(1) memory
    /// for long-horizon simulations (the cluster loop admits one image per
    /// request and only needs `next_free`/`completion`). The verifiers see
    /// an empty history and pass vacuously: audit with a tracked
    /// dispatcher in tests.
    pub fn untracked(shape: PipelineShape) -> Self {
        Self {
            tracked: false,
            ..Self::new(shape)
        }
    }

    /// The static pipeline shape being dispatched against.
    pub fn shape(&self) -> &PipelineShape {
        &self.shape
    }

    /// Admit an image arriving at beat `now`; returns its injection beat.
    pub fn admit(&mut self, now: u64) -> u64 {
        let t = now.max(self.next_free);
        if self.tracked {
            self.injections.push(t);
        }
        self.next_free = t + self.interval;
        t
    }

    /// Injection beats of every admitted image, in admission order.
    pub fn injections(&self) -> &[u64] {
        &self.injections
    }

    /// First beat at which a new injection would not violate the hazard
    /// interval — the pipeline's backlog horizon. An image admitted at
    /// `now` injects at `now.max(next_free())`, so `next_free() - now`
    /// is the pending pipeline wait (0 when the pipeline is caught up).
    pub fn next_free(&self) -> u64 {
        self.next_free
    }

    /// The enforced injection interval (`shape.min_interval()`).
    pub fn interval(&self) -> u64 {
        self.interval
    }

    /// Completion beat of the image injected at `inject`.
    pub fn completion(&self, inject: u64) -> u64 {
        let l = self.shape.n_layers() - 1;
        self.shape.window(inject, l).1
    }

    /// Verify the structural-hazard invariant over all admitted images:
    /// no layer hosts two images in the same beat.
    pub fn verify_no_hazard(&self) -> Result<(), String> {
        for l in 0..self.shape.n_layers() {
            let mut windows: Vec<(u64, u64)> = self
                .injections
                .iter()
                .map(|&inj| self.shape.window(inj, l))
                .collect();
            windows.sort_unstable();
            for w in windows.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!(
                        "layer {l}: windows {:?} and {:?} overlap",
                        w[0], w[1]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Verify rule (b): every image sees identical layer offsets.
    pub fn verify_fixed_offsets(&self) -> Result<(), String> {
        // Offsets are applied uniformly by construction; check windows are
        // consistent translations of image 0's.
        let Some(&first) = self.injections.first() else {
            return Ok(());
        };
        for &inj in &self.injections {
            for l in 0..self.shape.n_layers() {
                let base = self.shape.window(first, l);
                let w = self.shape.window(inj, l);
                if w.0 - inj != base.0 - first || w.1 - w.0 != base.1 - base.0 {
                    return Err(format!("layer {l}: inconsistent offsets"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::{NetworkMapping, ReplicationPlan};
    use crate::pipeline::build_plans;

    fn shape() -> PipelineShape {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        PipelineShape::from_plans(&build_plans(&net, &m, &arch))
    }

    #[test]
    fn min_interval_is_busiest_stage() {
        let s = shape();
        assert_eq!(s.min_interval(), 3136);
    }

    #[test]
    fn offsets_strictly_increase() {
        let s = shape();
        for w in s.offsets.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn admissions_respect_interval() {
        let mut d = Dispatcher::new(shape());
        for i in 0..20 {
            d.admit(i); // arrivals faster than the pipeline
        }
        d.verify_no_hazard().unwrap();
        d.verify_fixed_offsets().unwrap();
        let inj = d.injections();
        for w in inj.windows(2) {
            assert!(w[1] - w[0] >= 3136);
        }
    }

    #[test]
    fn untracked_dispatcher_matches_but_keeps_no_history() {
        let s = shape();
        let mut a = Dispatcher::new(s.clone());
        let mut b = Dispatcher::untracked(s);
        for i in 0..10u64 {
            assert_eq!(a.admit(i * 100), b.admit(i * 100));
        }
        assert_eq!(a.injections().len(), 10);
        assert!(b.injections().is_empty(), "untracked keeps no log");
        assert_eq!(a.next_free(), b.next_free());
        assert_eq!(a.interval(), b.interval());
    }

    #[test]
    fn sparse_arrivals_admit_immediately() {
        let mut d = Dispatcher::new(shape());
        let t1 = d.admit(0);
        let t2 = d.admit(100_000);
        assert_eq!(t1, 0);
        assert_eq!(t2, 100_000);
        d.verify_no_hazard().unwrap();
    }

    #[test]
    fn completion_after_injection() {
        // completion() is the dispatcher's ETA from the offset skeleton:
        // after the last stage's start offset plus its occupancy. (The
        // cycle-accurate engine, not this skeleton, models input-limited
        // stretching; admission control only needs min_interval.)
        let d0 = Dispatcher::new(shape());
        let s = d0.shape().clone();
        let mut d = Dispatcher::new(s.clone());
        let t = d.admit(0);
        let last = s.n_layers() - 1;
        assert_eq!(d.completion(t), t + s.offsets[last] + s.occupancy[last]);
        assert!(d.completion(t) > t);
    }
}
