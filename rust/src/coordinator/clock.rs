//! Time sources for the serving stack.
//!
//! The batcher's hoarding decision ("has the oldest request waited longer
//! than `max_wait`?") used to be written against `std::time::Instant`,
//! which made [`super::BatchPolicy::form`] untestable without sleeps and
//! unusable from the virtual-time cluster simulator. A [`Clock`] produces
//! monotone integer *ticks* instead; what a tick means is the clock's
//! business:
//!
//! - [`WallClock`] — microseconds since the clock was created. The real
//!   [`super::Server`] uses one; a 5 ms `max_wait` is `5_000` ticks.
//! - [`VirtualClock`] — simulated cycles, advanced explicitly by a
//!   discrete-event loop. The cluster simulator
//!   ([`crate::cluster`]) runs the *same* `BatchPolicy` logic in
//!   virtual time, so batching behavior is identical in both worlds.

use std::time::Instant;

/// A monotone source of integer ticks. Implementations define the tick
/// unit (µs for [`WallClock`], simulated cycles for [`VirtualClock`]).
pub trait Clock {
    /// Current time in ticks. Must never decrease.
    fn now(&self) -> u64;
}

/// Wall-clock ticks: microseconds elapsed since construction.
///
/// Copyable so the server handle and its worker thread can share one
/// epoch — both sides then agree on what tick `N` means.
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    /// A clock whose tick 0 is "now".
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// Virtual ticks: a counter advanced explicitly by a simulator's event
/// loop. One tick is one simulated cycle.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: u64,
}

impl VirtualClock {
    /// A clock at cycle 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Jump to `cycle`; panics on time running backwards (an event-loop
    /// ordering bug, worth failing loudly on).
    pub fn advance_to(&mut self, cycle: u64) {
        assert!(
            cycle >= self.now,
            "virtual clock moved backwards: {} -> {cycle}",
            self.now
        );
        self.now = cycle;
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> u64 {
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advances() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(100);
        assert_eq!(c.now(), 100);
        c.advance_to(100); // same cycle is fine
        assert_eq!(c.now(), 100);
    }

    #[test]
    #[should_panic(expected = "moved backwards")]
    fn virtual_clock_rejects_time_travel() {
        let mut c = VirtualClock::new();
        c.advance_to(10);
        c.advance_to(9);
    }
}
