//! Request/response types and serving statistics.

use std::time::{Duration, Instant};

/// An inference request: one image, flattened `32 x 32 x 3` in [0, 1].
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id (assigned by the server, unique per run).
    pub id: u64,
    /// Flattened input image.
    pub image: Vec<f32>,
    /// Submission time in [`Clock`](super::clock::Clock) ticks (µs for the
    /// real server, simulated cycles in the cluster simulator).
    pub submitted: u64,
}

/// The served result.
#[derive(Debug, Clone)]
pub struct Response {
    /// Id of the request this response answers.
    pub id: u64,
    /// Raw classifier outputs.
    pub logits: Vec<f32>,
    /// Argmax class index.
    pub class: usize,
    /// Queueing + batching + execution time.
    pub latency: Duration,
    /// Batch size this request was served in.
    pub batch: usize,
}

/// Online serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Batch-size histogram indexed by size (0 unused).
    pub batch_hist: [u64; 5],
    latencies_us: Vec<u64>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl ServeStats {
    /// Record one served response.
    pub fn record(&mut self, resp: &Response, now: Instant) {
        if self.started.is_none() {
            self.started = Some(resp.submitted_proxy(now));
        }
        self.finished = Some(now);
        self.served += 1;
        self.latencies_us.push(resp.latency.as_micros() as u64);
    }

    /// Record one executed batch of the given size.
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        if size < self.batch_hist.len() {
            self.batch_hist[size] += 1;
        }
    }

    /// Requests per second over the serving span.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) if f > s => self.served as f64 / (f - s).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Latency percentile (`p` in [0, 100]) in milliseconds.
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = (p / 100.0 * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)] as f64 / 1000.0
    }

    /// Mean serving latency in milliseconds.
    pub fn mean_latency_ms(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64 / 1000.0
    }
}

impl Response {
    fn submitted_proxy(&self, now: Instant) -> Instant {
        now.checked_sub(self.latency).unwrap_or(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, ms: u64) -> Response {
        Response {
            id,
            logits: vec![0.0; 10],
            class: 0,
            latency: Duration::from_millis(ms),
            batch: 1,
        }
    }

    #[test]
    fn stats_accumulate() {
        let mut s = ServeStats::default();
        let t = Instant::now();
        for i in 0..10 {
            s.record(&resp(i, 10 + i), t + Duration::from_millis(i as u64 * 5));
            s.record_batch(1);
        }
        assert_eq!(s.served, 10);
        assert_eq!(s.batches, 10);
        assert!(s.mean_latency_ms() >= 10.0);
        assert!(s.latency_percentile_ms(50.0) >= 10.0);
        assert!(s.latency_percentile_ms(99.0) <= 19.1);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = ServeStats::default();
        assert_eq!(s.throughput(), 0.0);
        assert_eq!(s.mean_latency_ms(), 0.0);
    }
}
