//! Startup planning: pick the replication plan the PIM node should carry
//! *before* serving starts, from the live batching configuration.
//!
//! The batcher's executable sizes determine the batch depth the pipeline
//! will actually see (a policy of `[4, 1]` steadily forms 4-deep batches
//! under load), and the searched planner is batch-depth aware: deep
//! batches favor the lowest steady-state interval, shallow ones favor
//! pipeline fill. This module closes that loop — `smart-pim serve` calls
//! [`startup_plan`] at boot and runs the dispatcher on the resulting
//! shape, so the served plan is derived, not hard-coded from Fig. 7.

use crate::cnn::{vgg, VggVariant};
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;
use crate::pipeline::build_plans;
use crate::planner::{evaluate_candidates, PlanCandidate, Planner, PlannerConfig};
use crate::power::WriteCost;
use crate::sweep::SweepRunner;

use super::batcher::BatchPolicy;
use super::dispatch::PipelineShape;

/// The coordinator's startup decision.
#[derive(Debug, Clone)]
pub struct StartupPlan {
    /// Workload the node was planned for.
    pub variant: VggVariant,
    /// Batch depth the plan was optimized for (largest executable size).
    pub batch_depth: u64,
    /// Tile budget the search ran under.
    pub tile_budget: usize,
    /// The chosen plan, engine-confirmed (`measured_interval` is set).
    pub candidate: PlanCandidate,
    /// Stage offsets/occupancy for the dispatcher.
    pub shape: PipelineShape,
    /// Cost of programming the chosen plan's full weight footprint onto
    /// the node before the first request can inject — the serving
    /// cold-start the ReRAM write model prices
    /// ([`WriteCost::of_mapping`]). The multi-tenant cluster pays this
    /// same cost per model swap.
    pub programming: WriteCost,
}

impl StartupPlan {
    /// Minimum injection interval the dispatcher must enforce.
    pub fn min_interval(&self) -> u64 {
        self.shape.min_interval()
    }

    /// Cold-start weight-programming time in wall seconds.
    pub fn cold_start_s(&self, logical_cycle_ns: f64) -> f64 {
        self.programming.latency_s(logical_cycle_ns)
    }
}

/// Batch depth implied by a policy: its largest executable batch size.
pub fn policy_batch_depth(policy: &BatchPolicy) -> u64 {
    policy.sizes.iter().copied().max().unwrap_or(1) as u64
}

/// Search a plan for `variant` on `arch` sized to the policy's batching,
/// confirm it through the engine, and derive the dispatcher shape.
pub fn startup_plan(
    variant: VggVariant,
    arch: &ArchConfig,
    policy: &BatchPolicy,
    tile_budget: usize,
) -> Result<StartupPlan, String> {
    let net = vgg::build(variant);
    let batch_depth = policy_batch_depth(policy);
    let planner = Planner::new(
        &net,
        arch,
        PlannerConfig {
            tile_budget,
            batch_depth,
            ..PlannerConfig::default()
        },
    );
    let result = planner.search()?;
    let mut chosen = vec![result.best];
    // Confirm through the engine with the policy's own batch depth.
    evaluate_candidates(
        &net,
        arch,
        &SweepRunner::new(),
        &mut chosen,
        batch_depth.max(4),
    );
    let candidate = chosen.pop().expect("one candidate in, one out");
    // The dispatcher shape must reflect the candidate's own mapping
    // selection (all-im2col under the default planner config).
    let mapping = NetworkMapping::build_with(&net, arch, &candidate.plan, &candidate.mapping)?;
    let shape = PipelineShape::from_plans(&build_plans(&net, &mapping, arch));
    let programming = WriteCost::of_mapping(&net, &mapping, arch);
    Ok(StartupPlan {
        variant,
        batch_depth,
        tile_budget: result.tile_budget,
        candidate,
        shape,
        programming,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::ReplicationPlan;
    use crate::planner::CostModel;

    #[test]
    fn startup_plan_beats_fig7_under_default_policy() {
        let arch = ArchConfig::paper_node();
        let sp = startup_plan(VggVariant::E, &arch, &BatchPolicy::default(), 320).unwrap();
        assert_eq!(sp.batch_depth, 4, "default policy sizes are [4, 1]");
        let net = vgg::build(VggVariant::E);
        let fig7 = CostModel::new(&net, &arch)
            .assess(&ReplicationPlan::fig7(VggVariant::E))
            .unwrap();
        assert!(
            sp.candidate.assessment.interval <= fig7.interval,
            "startup plan interval {} > fig7 {}",
            sp.candidate.assessment.interval,
            fig7.interval
        );
        assert!(sp.candidate.measured_interval.is_some(), "engine confirmed");
        assert!(sp.min_interval() >= 1);
        assert_eq!(sp.shape.n_layers(), net.len());
    }

    #[test]
    fn startup_prices_the_programming_cold_start() {
        // Any VGG plan programs real rows; the cold start is sub-second
        // but far from free (~0.18 s at the trip row-write latency).
        let arch = ArchConfig::paper_node();
        let sp = startup_plan(VggVariant::A, &arch, &BatchPolicy::default(), 320).unwrap();
        assert!(sp.programming.rows > 0);
        assert!(sp.programming.latency_cycles > 0);
        assert!(sp.programming.energy_j > 0.0);
        let s = sp.cold_start_s(arch.logical_cycle_ns);
        assert!((0.01..10.0).contains(&s), "cold start {s} s");
    }

    #[test]
    fn policy_depth_defaults_to_one_when_empty() {
        let p = BatchPolicy {
            sizes: vec![],
            ..BatchPolicy::default()
        };
        assert_eq!(policy_batch_depth(&p), 1);
    }
}
