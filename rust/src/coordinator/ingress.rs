//! Ingress/egress latency assessment for the serving coordinator.
//!
//! Requests entering a PIM node cross the mesh from the I/O tile to the
//! mapped pipeline's entry tile (and responses cross back). This model
//! injects that traffic into *any* interconnect through the
//! [`NocBackend`] trait object and drains it via the trait — the
//! coordinator never names a concrete NoC type, so serving-latency
//! estimates stay honest when the backend changes (wormhole vs SMART vs
//! ideal, or future fabrics).

use crate::noc::NocBackend;
use crate::util::stats::Accumulator;

/// Outcome of one ingress assessment.
#[derive(Debug, Clone)]
pub struct IngressReport {
    /// Packets offered (one per modeled request).
    pub offered: u64,
    /// Packets that completed before the drain budget expired.
    pub delivered: u64,
    /// Mean request latency in NoC cycles (generation -> tail ejection),
    /// over delivered packets.
    pub mean_latency_cycles: f64,
    /// Worst delivered-request latency in NoC cycles.
    pub max_latency_cycles: f64,
    /// Cycles the post-injection drain ran.
    pub drain_cycles: u64,
}

impl IngressReport {
    /// All offered requests arrived.
    pub fn complete(&self) -> bool {
        self.delivered == self.offered
    }
}

/// Inject `requests` packets of `packet_len` flits from `host` to `entry`,
/// one every `gap` cycles (gap 0 = a same-cycle burst: everything enqueues
/// before the clock moves, so source-queue serialization dominates), then
/// drain the backend and report delivery latency. `host` and `entry` must
/// differ.
pub fn assess_ingress(
    net: &mut dyn NocBackend,
    host: usize,
    entry: usize,
    requests: u64,
    packet_len: u16,
    gap: u64,
) -> IngressReport {
    assert_ne!(host, entry, "ingress needs distinct host and entry tiles");
    let mut ids = Vec::with_capacity(requests as usize);
    for _ in 0..requests {
        ids.push(net.enqueue(host, entry, packet_len));
        for _ in 0..gap {
            net.step();
        }
    }
    let drain_cycles = net.drain(1_000_000);
    let mut lat = Accumulator::new();
    let mut delivered = 0u64;
    for id in ids {
        let p = net.table().get(id);
        if p.is_done() {
            delivered += 1;
            lat.add(p.total_latency() as f64);
        }
    }
    IngressReport {
        offered: requests,
        delivered,
        mean_latency_cycles: lat.mean(),
        max_latency_cycles: if delivered > 0 { lat.max() } else { 0.0 },
        drain_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NocKind;
    use crate::noc::{build_backend, Mesh};

    fn assess(kind: NocKind) -> IngressReport {
        let mesh = Mesh::new(4, 4);
        let mut net = build_backend(kind, mesh, 6, 1, 4);
        assess_ingress(net.as_mut(), 0, mesh.nodes() - 1, 32, 4, 2)
    }

    #[test]
    fn every_backend_delivers_ingress_traffic() {
        for kind in NocKind::ALL {
            let r = assess(kind);
            assert!(r.complete(), "{kind:?}: {r:?}");
            assert!(r.mean_latency_cycles > 0.0, "{kind:?}");
            assert!(r.max_latency_cycles >= r.mean_latency_cycles, "{kind:?}");
        }
    }

    #[test]
    fn ideal_ingress_is_fastest() {
        let w = assess(NocKind::Wormhole);
        let s = assess(NocKind::Smart);
        let i = assess(NocKind::Ideal);
        assert!(
            i.mean_latency_cycles <= s.mean_latency_cycles,
            "ideal {} > smart {}",
            i.mean_latency_cycles,
            s.mean_latency_cycles
        );
        assert!(
            s.mean_latency_cycles <= w.mean_latency_cycles,
            "smart {} > wormhole {}",
            s.mean_latency_cycles,
            w.mean_latency_cycles
        );
    }

    #[test]
    #[should_panic(expected = "distinct host and entry")]
    fn self_ingress_rejected() {
        let mut net = build_backend(NocKind::Ideal, Mesh::new(4, 4), 6, 1, 4);
        assess_ingress(net.as_mut(), 3, 3, 1, 1, 1);
    }
}
