//! # smart-pim
//!
//! A production-quality reproduction of *"SMART Paths for Latency Reduction
//! in ReRAM Processing-In-Memory Architecture for CNN Inference"*
//! (Ko & Yu, 2020): an analog-ReRAM PIM accelerator for CNN inference with
//! intra-layer / inter-layer / batch pipelining, weight replication, and a
//! SMART-flow-control NoC, implemented as a three-layer Rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! - **Layer 3 (this crate)** — cycle-accurate processing-side simulator
//!   over validated layer DAGs ([`cnn::Network`]: linear VGGs and branching
//!   ResNets alike), event-driven flit-level NoC simulator behind the
//!   [`noc::NocBackend`] trait (wormhole / SMART / ideal), a searched
//!   replication/batch planner ([`planner`]), a unified parallel
//!   scenario-sweep engine ([`sweep`]), power/energy model, a serving
//!   coordinator that executes real quantized CNN inference through
//!   AOT-compiled XLA artifacts (PJRT, feature-gated), and a cluster-scale
//!   serving simulator ([`cluster`]): trace-driven multi-node inference
//!   with SLO metrics and capacity planning.
//! - **Layer 2 (python/compile/model.py)** — the quantized CNN forward
//!   graph in JAX, lowered once to HLO text at build time.
//! - **Layer 1 (python/compile/kernels/crossbar.py)** — the bit-serial
//!   2-bit-MLC crossbar GEMM as a Pallas kernel.
//!
//! See the repository `README.md` for the CLI quickstart and the
//! figure-to-command table, and `DESIGN.md` for the decision record.

#![warn(missing_docs)]

pub mod cluster;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod mapping;
pub mod metrics;
pub mod noc;
pub mod obs;
pub mod pipeline;
pub mod planner;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;
