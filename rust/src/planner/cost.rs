//! The planner's cost model.
//!
//! A candidate replication plan is priced with the *same* arithmetic the
//! simulator uses — `mapping::NetworkMapping` for tile packing and
//! `pipeline::build_plans` / `pipeline::max_occupancy` for the steady-state
//! injection interval — so a plan that models well is a plan the
//! cycle-accurate engine will confirm (the golden test pins this). On top
//! of the interval the model adds:
//!
//! - **fill cycles** — the first-image latency skeleton (stage start
//!   offsets + the last stage's occupancy, via
//!   [`crate::coordinator::PipelineShape`]), which is what a shallow batch
//!   actually pays;
//! - **batch-aware cost per image** — `(fill + (B-1) * interval) / B` for a
//!   batch depth `B`: at `B = 1` the planner optimizes single-image
//!   latency, at large `B` it optimizes the steady-state interval;
//! - **padding waste** — the fraction of allocated subarrays that hold no
//!   weights (whole-tile allocation rounds up), the third Pareto axis.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::coordinator::PipelineShape;
use crate::mapping::{
    plan_tiles, plan_tiles_with, MappingSelection, NetworkMapping, ReplicationPlan,
};
use crate::pipeline::{build_plans, max_occupancy};

/// Everything the search needs to know about one candidate plan.
#[derive(Debug, Clone)]
pub struct PlanAssessment {
    /// Tiles the plan occupies (whole-tile packing).
    pub tiles: usize,
    /// Modeled steady-state injection interval (logical cycles): the
    /// busiest stage's occupancy, exactly `pipeline::max_occupancy`.
    pub interval: u64,
    /// First-image latency skeleton (logical cycles).
    pub fill_cycles: u64,
    /// Fraction of allocated subarrays that hold no weights.
    pub padding_waste: f64,
    /// Per-stage occupancy `ceil(p_total / rate)` (the search lifts the
    /// argmax entries).
    pub occupancy: Vec<u64>,
}

impl PlanAssessment {
    /// Modeled cycles per image at batch depth `b` (>= 1): amortizes the
    /// pipeline fill over the batch.
    pub fn batch_cost(&self, b: u64) -> f64 {
        let b = b.max(1);
        (self.fill_cycles + (b - 1) * self.interval) as f64 / b as f64
    }
}

/// Cost model bound to one network + architecture.
pub struct CostModel<'a> {
    /// The network being planned.
    pub net: &'a Network,
    /// The node it must map onto.
    pub arch: &'a ArchConfig,
}

impl<'a> CostModel<'a> {
    /// A cost model bound to one network + architecture.
    pub fn new(net: &'a Network, arch: &'a ArchConfig) -> Self {
        Self { net, arch }
    }

    /// Price a plan. Fails when the plan does not map (arity mismatch or
    /// over the architecture's physical tile count) — the search only calls
    /// this for plans it already knows fit its budget.
    pub fn assess(&self, plan: &ReplicationPlan) -> Result<PlanAssessment, String> {
        self.assess_with(plan, &MappingSelection::im2col(self.net.len()))
    }

    /// [`CostModel::assess`] under a per-layer mapping selection (the joint
    /// mapping x replication search's pricing path; all-im2col is
    /// bit-identical to `assess`).
    pub fn assess_with(
        &self,
        plan: &ReplicationPlan,
        selection: &MappingSelection,
    ) -> Result<PlanAssessment, String> {
        let mapping = NetworkMapping::build_with(self.net, self.arch, plan, selection)?;
        let plans = build_plans(self.net, &mapping, self.arch);
        let occupancy: Vec<u64> = plans
            .iter()
            .map(|p| p.p_total.div_ceil(p.rate))
            .collect();
        let interval = max_occupancy(&plans);
        let shape = PipelineShape::from_plans(&plans);
        let last = shape.n_layers() - 1;
        let fill_cycles = shape.offsets[last] + shape.occupancy[last];
        Ok(PlanAssessment {
            tiles: mapping.total_tiles,
            interval,
            fill_cycles,
            padding_waste: self.padding_waste(&mapping),
            occupancy,
        })
    }

    /// Tiles a plan needs, without building the full mapping (the search's
    /// cheap budget pre-check).
    pub fn tiles_of(&self, factors: &[usize]) -> usize {
        plan_tiles(self.net, self.arch, factors)
    }

    /// [`CostModel::tiles_of`] under a per-layer mapping selection.
    pub fn tiles_of_with(&self, factors: &[usize], selection: &MappingSelection) -> usize {
        plan_tiles_with(self.net, self.arch, factors, selection)
    }

    /// Allocated-but-empty subarray fraction. Derived from the resolved
    /// mapping so the FC reload-rounds charging rule stays in one place
    /// (`mapping::layout` sets `reload_rounds`; conv layers carry 1):
    /// a layer keeps `subarrays / reload_rounds` resident at a time.
    fn padding_waste(&self, mapping: &NetworkMapping) -> f64 {
        let allocated = (mapping.total_tiles * self.arch.subarrays_per_tile()) as f64;
        let used: usize = mapping
            .layers
            .iter()
            .map(|lm| {
                lm.demand
                    .subarrays_replicated(lm.replication)
                    .div_ceil(lm.reload_rounds as usize)
            })
            .sum();
        (1.0 - used as f64 / allocated).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};

    #[test]
    fn fig7_assessment_matches_calibration_anchor() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let cm = CostModel::new(&net, &arch);
        let a = cm.assess(&ReplicationPlan::fig7(VggVariant::E)).unwrap();
        assert_eq!(a.interval, 3136, "Fig. 7 VGG-E beat");
        assert!(a.tiles <= 320);
        // Fill spans every stage: at least the summed pipeline depths (19
        // stages x >= 24 cycles), and batch cost at B=1 *is* the fill.
        assert!(a.fill_cycles >= 19 * 24, "fill {}", a.fill_cycles);
        assert_eq!(a.batch_cost(1), a.fill_cycles as f64);
        assert!((0.0..1.0).contains(&a.padding_waste));
    }

    #[test]
    fn batch_cost_interpolates_fill_and_interval() {
        let a = PlanAssessment {
            tiles: 1,
            interval: 100,
            fill_cycles: 1000,
            padding_waste: 0.0,
            occupancy: vec![100],
        };
        assert_eq!(a.batch_cost(1), 1000.0);
        let big = a.batch_cost(1000);
        assert!((100.0..110.0).contains(&big), "b->inf tends to interval, got {big}");
        assert!(a.batch_cost(4) < a.batch_cost(2));
    }

    #[test]
    fn none_plan_interval_is_conv1_stream() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let cm = CostModel::new(&net, &arch);
        let a = cm.assess(&ReplicationPlan::none(&net)).unwrap();
        assert_eq!(a.interval, 50176);
        assert_eq!(a.occupancy[0], 50176);
    }

    #[test]
    fn assess_with_im2col_is_assess() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let cm = CostModel::new(&net, &arch);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let a = cm.assess(&plan).unwrap();
        let b = cm
            .assess_with(&plan, &MappingSelection::im2col(net.len()))
            .unwrap();
        assert_eq!(a.tiles, b.tiles);
        assert_eq!(a.interval, b.interval);
        assert_eq!(a.fill_cycles, b.fill_cycles);
        assert_eq!(a.occupancy, b.occupancy);
    }

    #[test]
    fn assess_with_vwsdk_cuts_unreplicated_interval() {
        use crate::mapping::MappingKind;
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let cm = CostModel::new(&net, &arch);
        let sel = MappingSelection::uniform(MappingKind::VwSdk, net.len());
        let a = cm.assess_with(&ReplicationPlan::none(&net), &sel).unwrap();
        // The (2,8) stem window emits 16 pixels/cycle: conv2 now binds.
        assert_eq!(a.interval, 12544);
    }

    #[test]
    fn bad_arity_rejected() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let cm = CostModel::new(&net, &arch);
        let bad = ReplicationPlan { factors: vec![1; 2] };
        assert!(cm.assess(&bad).is_err());
    }
}
