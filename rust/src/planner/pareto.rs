//! Pareto frontier over candidate plans, and candidate evaluation through
//! the cycle-accurate engine.
//!
//! The search scores plans with the occupancy model; before a plan is
//! trusted (golden tests, the `plan` CLI, the coordinator's startup
//! choice) the frontier is replayed through [`crate::sim::Engine`] — each
//! candidate is an independent point, so the replay fans out across cores
//! via [`crate::sweep::SweepRunner`] exactly like every other sweep in the
//! repository.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::NetworkMapping;
use crate::pipeline::build_plans;
use crate::sim::{Engine, NocAdjust};
use crate::sweep::SweepRunner;

use super::search::PlanCandidate;

/// Keep the non-dominated candidates over (modeled interval, tiles, padding
/// waste) — all minimized — sorted by interval ascending, tiles ascending.
pub fn pareto_frontier(mut cands: Vec<PlanCandidate>) -> Vec<PlanCandidate> {
    cands.sort_by(|a, b| {
        a.assessment
            .interval
            .cmp(&b.assessment.interval)
            .then(a.assessment.tiles.cmp(&b.assessment.tiles))
            .then(a.assessment.padding_waste.total_cmp(&b.assessment.padding_waste))
    });
    let mut out: Vec<PlanCandidate> = Vec::new();
    for c in cands {
        let dominated = out.iter().any(|o| {
            o.assessment.interval <= c.assessment.interval
                && o.assessment.tiles <= c.assessment.tiles
                && o.assessment.padding_waste <= c.assessment.padding_waste
                && (o.assessment.interval < c.assessment.interval
                    || o.assessment.tiles < c.assessment.tiles
                    || o.assessment.padding_waste < c.assessment.padding_waste)
        });
        let duplicate = out
            .iter()
            .any(|o| o.plan == c.plan && o.mapping == c.mapping);
        if !dominated && !duplicate {
            out.push(c);
        }
    }
    out
}

/// Replay candidates through the event-driven pipeline engine (ideal NoC,
/// batch pipelining on, `images` per run), filling
/// [`PlanCandidate::measured_interval`]. Candidates whose mapping fails
/// keep `None`. Runs in parallel over the sweep runner.
pub fn evaluate_candidates(
    net: &Network,
    arch: &ArchConfig,
    runner: &SweepRunner,
    cands: &mut [PlanCandidate],
    images: u64,
) {
    let images = images.max(2); // one image has no steady interval
    let plans: Vec<&PlanCandidate> = cands.iter().collect();
    let measured: Vec<Option<f64>> = runner.run(&plans, |_, c| {
        // Replay under the candidate's own mapping selection — a VW-SDK
        // plan measured through the im2col mapping would be a lie.
        let mapping = NetworkMapping::build_with(net, arch, &c.plan, &c.mapping).ok()?;
        let stage_plans = build_plans(net, &mapping, arch);
        let adj = NocAdjust::identity(stage_plans.len());
        let sim = Engine::new(&stage_plans, &adj, true, images).run();
        Some(sim.interval_or_makespan())
    });
    for (c, m) in cands.iter_mut().zip(measured) {
        c.measured_interval = m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;
    use crate::planner::cost::CostModel;

    fn candidate(net: &Network, arch: &ArchConfig, plan: ReplicationPlan) -> PlanCandidate {
        let assessment = CostModel::new(net, arch).assess(&plan).unwrap();
        PlanCandidate {
            plan,
            mapping: crate::mapping::MappingSelection::im2col(net.len()),
            assessment,
            measured_interval: None,
        }
    }

    fn synthetic(tag: usize, interval: u64, tiles: usize, waste: f64) -> PlanCandidate {
        PlanCandidate {
            plan: ReplicationPlan {
                factors: vec![tag; 3],
            },
            mapping: crate::mapping::MappingSelection::im2col(3),
            assessment: crate::planner::cost::PlanAssessment {
                tiles,
                interval,
                fill_cycles: interval * 2,
                padding_waste: waste,
                occupancy: vec![interval; 3],
            },
            measured_interval: None,
        }
    }

    #[test]
    fn frontier_drops_dominated_plans_and_duplicates() {
        let a = synthetic(1, 100, 10, 0.10); // best interval
        let b = synthetic(2, 100, 12, 0.20); // dominated by a on all axes
        let c = synthetic(3, 500, 4, 0.30); // survives: fewest tiles
        let d = synthetic(4, 500, 5, 0.05); // survives: least waste
        let dup = synthetic(1, 100, 10, 0.10); // duplicate of a
        let f = pareto_frontier(vec![c.clone(), b.clone(), a.clone(), d.clone(), dup]);
        let plans: Vec<_> = f.iter().map(|x| x.plan.factors[0]).collect();
        assert!(plans.contains(&1), "best-interval plan survives: {plans:?}");
        assert!(plans.contains(&3), "fewest-tiles plan survives: {plans:?}");
        assert!(plans.contains(&4), "least-waste plan survives: {plans:?}");
        assert!(!plans.contains(&2), "dominated plan dropped: {plans:?}");
        assert_eq!(f.len(), 3, "duplicate dropped: {plans:?}");
        for w in f.windows(2) {
            assert!(w[0].assessment.interval <= w[1].assessment.interval);
        }
    }

    #[test]
    fn frontier_of_real_search_is_sane() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let result = crate::planner::plan_for(&net, &arch, 320).unwrap();
        assert!(!result.frontier.is_empty());
        // The head of the frontier carries the smallest interval visited,
        // so it can be no worse than the chosen best plan's.
        assert!(
            result.frontier[0].assessment.interval <= result.best.assessment.interval,
            "frontier head {} vs best {}",
            result.frontier[0].assessment.interval,
            result.best.assessment.interval
        );
        // No frontier member dominates another (pairwise check).
        for (i, x) in result.frontier.iter().enumerate() {
            for (j, y) in result.frontier.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominates = x.assessment.interval <= y.assessment.interval
                    && x.assessment.tiles <= y.assessment.tiles
                    && x.assessment.padding_waste <= y.assessment.padding_waste
                    && (x.assessment.interval < y.assessment.interval
                        || x.assessment.tiles < y.assessment.tiles
                        || x.assessment.padding_waste < y.assessment.padding_waste);
                assert!(!dominates, "frontier member {i} dominates {j}");
            }
        }
    }

    #[test]
    fn engine_confirms_modeled_interval() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let mut cands = vec![candidate(
            &net,
            &arch,
            ReplicationPlan::fig7(VggVariant::E),
        )];
        evaluate_candidates(&net, &arch, &SweepRunner::with_threads(1), &mut cands, 8);
        let measured = cands[0].measured_interval.expect("engine ran");
        let modeled = cands[0].assessment.interval as f64;
        assert!(
            (measured - modeled).abs() <= modeled * 0.05 + 32.0,
            "measured {measured} vs modeled {modeled}"
        );
    }
}
