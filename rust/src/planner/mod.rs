//! Automatic replication/batch planning — the searched replacement for the
//! paper's hand-tuned Fig. 7 table.
//!
//! The paper derives its per-VGG replication factors by hand for exactly
//! one node (320 tiles). This module derives them: a greedy
//! bottleneck-lifting search with a small beam ([`search::Planner`]) walks
//! power-of-two replication lifts — and, under
//! [`MappingMode::Auto`](crate::mapping::MappingMode), per-layer
//! im2col → VW-SDK backend switches, making the search joint over mapping x
//! replication — priced by the same occupancy math the simulator uses
//! ([`cost::CostModel`], batch-depth aware), and returns both a single best
//! plan and the Pareto frontier over throughput vs tiles vs padding waste
//! ([`pareto::pareto_frontier`]; candidates are deduplicated over
//! factors *and* mapping selection). Candidates are confirmed against the
//! cycle-accurate engine via the parallel sweep runner
//! ([`pareto::evaluate_candidates`], which replays each candidate under its
//! own mapping selection).
//!
//! Entry points:
//! - [`ReplicationPlan::searched`](crate::mapping::ReplicationPlan::searched)
//!   — drop-in next to `fig7` / `none` / `auto`;
//! - [`plan_for`] — full search result (best + frontier) for a network and
//!   tile budget;
//! - `smart-pim plan` — the CLI view (factors, modeled vs measured
//!   interval, frontier, comparison against Fig. 7);
//! - [`crate::coordinator::startup_plan`] — the serving coordinator's
//!   startup choice, driven by the live `BatchPolicy` sizes.
//!
//! # Example
//!
//! Search a plan for any workload — linear or branching — under the
//! paper's 320-tile budget:
//!
//! ```
//! use smart_pim::cnn::workload;
//! use smart_pim::config::ArchConfig;
//! use smart_pim::planner::plan_for;
//!
//! let arch = ArchConfig::paper_node();
//! let net = workload("vggA").unwrap();
//! let result = plan_for(&net, &arch, 320).unwrap();
//! assert!(result.best.assessment.tiles <= 320);
//! // Meets or beats the paper's hand-tuned 3136-cycle beat.
//! assert!(result.best.assessment.interval <= 3136);
//! ```

pub mod cost;
pub mod pareto;
pub mod search;

pub use cost::{CostModel, PlanAssessment};
pub use pareto::{evaluate_candidates, pareto_frontier};
pub use search::{
    plan_for, plan_for_mapped, PlanCandidate, Planner, PlannerConfig, PlanSearchResult,
};
