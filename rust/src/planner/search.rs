//! The replication-plan search: greedy bottleneck-lifting generalized to a
//! small beam, optionally joint over the mapping-backend axis.
//!
//! State = a vector of per-layer replication factors (powers of two, the
//! paper's replication granularity) plus, under [`MappingMode::Auto`], a
//! vector of per-layer mapping backends. From the all-ones plan, each step
//! either doubles the factor of a conv layer or (auto mode) switches a conv
//! layer from im2col to the VW-SDK packing, subject to the tile budget and
//! the per-layer factor cap. At batch depth >= 2 only layers whose
//! occupancy *is* the current bottleneck are expanded — changing any other
//! layer cannot reduce the modeled interval, which dominates the cost; at
//! batch depth 1 the objective is the pipeline fill, which any conv move
//! can reduce, so every conv layer is a candidate. When several candidates
//! tie the order of expansion matters once the budget gets tight, so
//! instead of committing to one order (the pure greedy) the search keeps
//! the `beam_width` best states per generation, scored by batch-aware
//! modeled cost then tiles. Every state ever visited feeds the Pareto
//! frontier (throughput vs tiles vs padding waste).
//!
//! Because auto mode expands a strict superset of the im2col moves from the
//! same base state, its best candidate pool always contains the pure-im2col
//! search's trajectory prefix; on the paper node the column-conservation
//! law (`mapping::backend` module docs) makes the two converge to the same
//! interval at the 320-tile budget — pinned by
//! `rust/tests/golden_mapping.rs`.

use std::collections::HashSet;

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::{MappingKind, MappingMode, MappingSelection, ReplicationPlan};

use super::cost::{CostModel, PlanAssessment};

/// Search knobs.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Tile budget (0 = the architecture's full tile count). Clamped to the
    /// physical tile count — a budget beyond the node needs a bigger node
    /// (`--config` with a larger mesh), not a plan.
    pub tile_budget: usize,
    /// Batch depth the plan is optimized for: 1 = single-image latency,
    /// large = steady-state interval. The coordinator passes its largest
    /// executable batch size here.
    pub batch_depth: u64,
    /// Per-layer replication cap (power-of-two lifts stop here). The
    /// paper's hand plans stop at 16; the default gives the search room to
    /// do better when the budget allows.
    pub max_factor: usize,
    /// States kept per search generation (1 = pure greedy).
    pub beam_width: usize,
    /// Mapping-backend axis: fixed im2col (the default, bit-identical to
    /// the pre-backend search), fixed VW-SDK, or joint per-layer search.
    pub mapping: MappingMode,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            tile_budget: 0,
            batch_depth: 8,
            max_factor: 1024,
            beam_width: 4,
            mapping: MappingMode::Im2col,
        }
    }
}

/// One fully-assessed candidate plan.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    /// The per-layer replication factors.
    pub plan: ReplicationPlan,
    /// The per-layer mapping backends the plan was priced under.
    pub mapping: MappingSelection,
    /// Modeled price of the plan (tiles, interval, fill, waste).
    pub assessment: PlanAssessment,
    /// Steady-state interval measured by the event-driven engine
    /// (`None` until [`super::evaluate_candidates`] runs).
    pub measured_interval: Option<f64>,
}

impl PlanCandidate {
    /// Modeled cycles per image at the configured batch depth.
    pub fn cost(&self, batch_depth: u64) -> f64 {
        self.assessment.batch_cost(batch_depth)
    }
}

/// Search outcome: the best plan plus the Pareto frontier of everything
/// visited.
#[derive(Debug, Clone)]
pub struct PlanSearchResult {
    /// Lowest batch-aware modeled cost (ties: fewer tiles, less waste).
    pub best: PlanCandidate,
    /// Non-dominated candidates over (interval, tiles, padding waste),
    /// sorted by interval ascending.
    pub frontier: Vec<PlanCandidate>,
    /// States assessed during the search.
    pub explored: usize,
    /// The budget actually used (input clamped to the node's tile count).
    pub tile_budget: usize,
}

/// The searched replication/batch planner.
pub struct Planner<'a> {
    net: &'a Network,
    arch: &'a ArchConfig,
    cfg: PlannerConfig,
}

impl<'a> Planner<'a> {
    /// A planner over one network + architecture with explicit knobs.
    pub fn new(net: &'a Network, arch: &'a ArchConfig, cfg: PlannerConfig) -> Self {
        Self { net, arch, cfg }
    }

    /// Effective tile budget after clamping to the node.
    pub fn budget(&self) -> usize {
        let phys = self.arch.total_tiles();
        if self.cfg.tile_budget == 0 {
            phys
        } else {
            self.cfg.tile_budget.min(phys)
        }
    }

    /// Run the search. Errors when even the all-ones plan exceeds the
    /// budget (the network simply does not fit that many tiles).
    pub fn search(&self) -> Result<PlanSearchResult, String> {
        let _prof = crate::obs::profile::scope("planner.search");
        let cm = CostModel::new(self.net, self.arch);
        let budget = self.budget();
        let b = self.cfg.batch_depth.max(1);

        // Base mapping per mode: fixed modes pin every conv layer to that
        // backend (non-conv layers are backend-blind and stay im2col, the
        // same normalization `mapping::layout` applies); auto starts from
        // the seed im2col everywhere and lets switch moves diverge.
        let base_kind = match self.cfg.mapping {
            MappingMode::VwSdk => MappingKind::VwSdk,
            MappingMode::Im2col | MappingMode::Auto => MappingKind::Im2col,
        };
        let base_kinds: Vec<MappingKind> = self
            .net
            .layers()
            .iter()
            .map(|l| if l.is_conv() { base_kind } else { MappingKind::Im2col })
            .collect();

        let base_factors = vec![1usize; self.net.len()];
        let base_tiles = cm.tiles_of_with(
            &base_factors,
            &MappingSelection {
                kinds: base_kinds.clone(),
            },
        );
        if base_tiles > budget {
            return Err(format!(
                "{}: needs {base_tiles} tiles unreplicated > budget {budget}",
                self.net.name
            ));
        }
        let assess = |factors: &[usize], kinds: &[MappingKind]| -> Result<PlanCandidate, String> {
            let plan = ReplicationPlan {
                factors: factors.to_vec(),
            };
            let mapping = MappingSelection {
                kinds: kinds.to_vec(),
            };
            let assessment = cm.assess_with(&plan, &mapping)?;
            Ok(PlanCandidate {
                plan,
                mapping,
                assessment,
                measured_interval: None,
            })
        };

        let mut seen: HashSet<(Vec<usize>, Vec<MappingKind>)> = HashSet::new();
        seen.insert((base_factors.clone(), base_kinds.clone()));
        let base = assess(&base_factors, &base_kinds)?;
        let mut all: Vec<PlanCandidate> = vec![base.clone()];
        let mut beam: Vec<PlanCandidate> = vec![base];

        // At batch depth 1 the objective is the fill (first-image latency),
        // which *any* conv lift can reduce (it shortens that stage's
        // head-wait contribution), so the expansion must consider every
        // conv layer. At depth >= 2 the interval term dominates and only
        // bottleneck lifts can lower it — restricting expansion to them
        // keeps the search small without giving up the optimum.
        let lift_all = b == 1;

        loop {
            let _round = crate::obs::profile::scope("planner.round");
            let mut children: Vec<PlanCandidate> = Vec::new();
            for state in &beam {
                let bottleneck = state.assessment.interval;
                for (i, layer) in self.net.layers().iter().enumerate() {
                    let r = state.plan.factors[i];
                    // FC stages emit at a fixed rate (reload rounds):
                    // replicating them buys nothing, only tiles.
                    if !layer.is_conv()
                        || (!lift_all && state.assessment.occupancy[i] != bottleneck)
                    {
                        continue;
                    }
                    let mut moves: Vec<(Vec<usize>, Vec<MappingKind>)> = Vec::new();
                    if r * 2 <= self.cfg.max_factor {
                        let mut factors = state.plan.factors.clone();
                        factors[i] = r * 2;
                        moves.push((factors, state.mapping.kinds.clone()));
                    }
                    // Auto: switching a conv to the VW-SDK packing is a
                    // move on the mapping axis (rate x= parallel windows at
                    // unchanged replication).
                    if self.cfg.mapping == MappingMode::Auto
                        && state.mapping.kind(i) == MappingKind::Im2col
                    {
                        let mut kinds = state.mapping.kinds.clone();
                        kinds[i] = MappingKind::VwSdk;
                        moves.push((state.plan.factors.clone(), kinds));
                    }
                    for (factors, kinds) in moves {
                        let key = (factors, kinds);
                        if seen.contains(&key)
                            || cm.tiles_of_with(
                                &key.0,
                                &MappingSelection {
                                    kinds: key.1.clone(),
                                },
                            ) > budget
                        {
                            continue;
                        }
                        children.push(assess(&key.0, &key.1)?);
                        seen.insert(key);
                    }
                }
            }
            if children.is_empty() {
                break;
            }
            children.sort_by(|x, y| {
                x.cost(b)
                    .total_cmp(&y.cost(b))
                    .then(x.assessment.tiles.cmp(&y.assessment.tiles))
            });
            all.extend(children.iter().cloned());
            children.truncate(self.cfg.beam_width.max(1));
            beam = children;
        }

        let best = all
            .iter()
            .min_by(|x, y| {
                x.cost(b)
                    .total_cmp(&y.cost(b))
                    .then(x.assessment.tiles.cmp(&y.assessment.tiles))
                    .then(x.assessment.padding_waste.total_cmp(&y.assessment.padding_waste))
            })
            .expect("at least the base plan exists")
            .clone();
        let explored = all.len();
        let frontier = super::pareto::pareto_frontier(all);
        Ok(PlanSearchResult {
            best,
            frontier,
            explored,
            tile_budget: budget,
        })
    }
}

/// One-call convenience: the best searched plan for `net` under a tile
/// budget, with default search knobs.
pub fn plan_for(
    net: &Network,
    arch: &ArchConfig,
    tile_budget: usize,
) -> Result<PlanSearchResult, String> {
    Planner::new(
        net,
        arch,
        PlannerConfig {
            tile_budget,
            ..PlannerConfig::default()
        },
    )
    .search()
}

/// [`plan_for`] under an explicit mapping mode (`Im2col` reproduces
/// `plan_for` exactly; `Auto` runs the joint mapping x replication search).
pub fn plan_for_mapped(
    net: &Network,
    arch: &ArchConfig,
    tile_budget: usize,
    mapping: MappingMode,
) -> Result<PlanSearchResult, String> {
    Planner::new(
        net,
        arch,
        PlannerConfig {
            tile_budget,
            mapping,
            ..PlannerConfig::default()
        },
    )
    .search()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::validate_plan;

    #[test]
    fn searched_dominates_fig7_interval_unit_smoke() {
        // One variant here; the all-VGG sweep lives in
        // rust/tests/golden_planner.rs (don't pay the full search 2x per
        // `cargo test`).
        let v = VggVariant::B;
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let cm = CostModel::new(&net, &arch);
        let fig7 = cm.assess(&ReplicationPlan::fig7(v)).unwrap();
        let got = plan_for(&net, &arch, 320).unwrap();
        assert!(
            got.best.assessment.interval <= fig7.interval,
            "{}: searched {} > fig7 {}",
            v.name(),
            got.best.assessment.interval,
            fig7.interval
        );
        validate_plan(&net, &arch, &got.best.plan).unwrap();
    }

    #[test]
    fn budget_respected_and_clamped() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        for budget in [200, 320, 5000] {
            let r = plan_for(&net, &arch, budget).unwrap();
            assert!(r.tile_budget <= arch.total_tiles());
            assert!(
                r.best.assessment.tiles <= r.tile_budget,
                "budget {budget}: {} tiles",
                r.best.assessment.tiles
            );
        }
    }

    #[test]
    fn impossible_budget_is_a_clean_error() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        // VGG-E needs 185 tiles unreplicated.
        let err = plan_for(&net, &arch, 50).unwrap_err();
        assert!(err.contains("budget"), "{err}");
    }

    #[test]
    fn batch_depth_one_prefers_lower_fill() {
        // At B=1 the cost is the fill; at large B it is the interval. The
        // two optima need not coincide, but cost must be consistent.
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let cm = CostModel::new(&net, &arch);
        let latency = Planner::new(
            &net,
            &arch,
            PlannerConfig {
                batch_depth: 1,
                ..PlannerConfig::default()
            },
        )
        .search()
        .unwrap();
        let base = cm.assess(&ReplicationPlan::none(&net)).unwrap();
        // The B=1 search minimizes fill over everything it visited, and the
        // all-ones plan is always visited: it can never lose to it.
        assert!(
            latency.best.assessment.fill_cycles <= base.fill_cycles,
            "latency plan fill {} > unreplicated fill {}",
            latency.best.assessment.fill_cycles,
            base.fill_cycles
        );
        let throughput = plan_for(&net, &arch, 0).unwrap();
        assert!(
            throughput.best.assessment.interval <= latency.best.assessment.interval,
            "throughput plan must win (or tie) on interval"
        );
    }

    #[test]
    fn joint_search_never_loses_to_im2col_search() {
        // Auto expands a superset of the im2col moves; on the paper node
        // the conservation law makes them converge (golden_mapping.rs pins
        // equality across all workloads — this is the cheap one-variant
        // smoke).
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let im2col = plan_for(&net, &arch, 320).unwrap();
        let joint = plan_for_mapped(&net, &arch, 320, MappingMode::Auto).unwrap();
        assert!(
            joint.best.assessment.interval <= im2col.best.assessment.interval,
            "joint {} > im2col {}",
            joint.best.assessment.interval,
            im2col.best.assessment.interval
        );
        assert_eq!(im2col.best.mapping.summary(), "im2col");
    }

    #[test]
    fn vwsdk_search_validates_under_its_own_selection() {
        use crate::mapping::validate_plan_with;
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let r = plan_for_mapped(&net, &arch, 320, MappingMode::VwSdk).unwrap();
        validate_plan_with(&net, &arch, &r.best.plan, &r.best.mapping).unwrap();
        // Every conv entry is VW-SDK in fixed-vwsdk mode.
        for (i, l) in net.layers().iter().enumerate() {
            if l.is_conv() {
                assert_eq!(r.best.mapping.kind(i), MappingKind::VwSdk);
            }
        }
    }

    // Determinism is covered by golden_planner.rs::prop_search_is_deterministic.

    #[test]
    fn greedy_beam_one_also_dominates() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let greedy = Planner::new(
            &net,
            &arch,
            PlannerConfig {
                beam_width: 1,
                ..PlannerConfig::default()
            },
        )
        .search()
        .unwrap();
        assert!(greedy.best.assessment.interval <= 3136);
    }
}
