//! Schedule traces: turn an engine run into per-stage occupancy windows and
//! render them as an ASCII Gantt chart or CSV — the debugging view of the
//! paper's pipelining diagrams (Sec. IV).

use crate::pipeline::StagePlan;

use super::engine::SimResult;

/// One stage's activity window for one image (logical cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    /// Stage (layer) index.
    pub stage: usize,
    /// Image index.
    pub image: u64,
    /// First cycle the image occupies the stage.
    pub start: u64,
    /// One past the last occupied cycle.
    pub end: u64,
}

/// Reconstruct per-stage windows from a schedule using the static plan
/// offsets (the engine records injections/completions; stage windows follow
/// the dispatcher shape — exact for steady state, approximate during
/// fill/drain).
pub fn windows(plans: &[StagePlan], sim: &SimResult) -> Vec<Window> {
    let shape = crate::coordinator::PipelineShape::from_plans(plans);
    let mut out = Vec::new();
    for (img, &inj) in sim.injections.iter().enumerate() {
        if inj == u64::MAX {
            continue;
        }
        for stage in 0..plans.len() {
            let (s, e) = shape.window(inj, stage);
            out.push(Window {
                stage,
                image: img as u64,
                start: s,
                end: e,
            });
        }
    }
    out
}

/// ASCII Gantt chart: one row per stage, one column per `scale` cycles;
/// cells show the image index (mod 10) active in that bucket.
pub fn gantt(plans: &[StagePlan], sim: &SimResult, width: usize) -> String {
    let ws = windows(plans, sim);
    let horizon = sim
        .completions
        .iter()
        .filter(|&&c| c != u64::MAX)
        .max()
        .copied()
        .unwrap_or(0)
        .max(1);
    let scale = horizon.div_ceil(width as u64).max(1);
    let mut rows: Vec<Vec<u8>> = vec![vec![b'.'; width]; plans.len()];
    for w in &ws {
        let lo = (w.start / scale) as usize;
        let hi = ((w.end.saturating_sub(1)) / scale) as usize;
        for col in lo..=hi.min(width - 1) {
            rows[w.stage][col] = b'0' + (w.image % 10) as u8;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "gantt: {} cycles, {} cycles/char\n",
        horizon, scale
    ));
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!(
            "{:>10} |{}|\n",
            plans[i].name,
            String::from_utf8_lossy(row)
        ));
    }
    out
}

/// CSV export (stage,image,start,end) for external plotting.
pub fn to_csv(plans: &[StagePlan], sim: &SimResult) -> String {
    let mut out = String::from("stage,name,image,start,end\n");
    for w in windows(plans, sim) {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            w.stage, plans[w.stage].name, w.image, w.start, w.end
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::{NetworkMapping, ReplicationPlan};
    use crate::pipeline::build_plans;
    use crate::sim::engine::{Engine, NocAdjust};

    fn run() -> (Vec<StagePlan>, SimResult) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::fig7(VggVariant::A);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let sim = Engine::new(&plans, &adj, true, 3).run();
        (plans, sim)
    }

    #[test]
    fn windows_cover_all_stage_image_pairs() {
        let (plans, sim) = run();
        let ws = windows(&plans, &sim);
        assert_eq!(ws.len(), plans.len() * 3);
        for w in &ws {
            assert!(w.start < w.end, "{w:?}");
        }
    }

    #[test]
    fn windows_ordered_along_the_pipeline() {
        let (plans, sim) = run();
        let ws = windows(&plans, &sim);
        // For each image, stage starts strictly increase with depth.
        for img in 0..3u64 {
            let mut starts: Vec<u64> = ws
                .iter()
                .filter(|w| w.image == img)
                .map(|w| w.start)
                .collect();
            let sorted = {
                let mut s = starts.clone();
                s.sort_unstable();
                s
            };
            assert_eq!(starts.len(), plans.len());
            starts.sort_unstable();
            assert_eq!(starts, sorted);
        }
    }

    #[test]
    fn gantt_renders_all_stages() {
        let (plans, sim) = run();
        let g = gantt(&plans, &sim, 72);
        assert_eq!(g.lines().count(), plans.len() + 1);
        assert!(g.contains("conv1"));
        assert!(g.contains("fc3"));
        // Image ids 0..2 appear somewhere.
        assert!(g.contains('0') && g.contains('1') && g.contains('2'), "{g}");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let (plans, sim) = run();
        let csv = to_csv(&plans, &sim);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "stage,image,start,end".replace("stage,", "stage,name,"));
        assert_eq!(csv.lines().count(), 1 + plans.len() * 3);
    }
}
