//! Schedule traces: turn an engine run into per-stage occupancy windows and
//! render them as an ASCII Gantt chart or CSV — the debugging view of the
//! paper's pipelining diagrams (Sec. IV).
//!
//! Two window sources exist. [`windows`] reconstructs them from the
//! static [`crate::coordinator::PipelineShape`] — no instrumentation
//! needed, but approximate: exact in steady state only when the shape's
//! producer-depth offsets agree with the engine's consumer-depth
//! visibility rule (they differ by a constant per-stage shift when
//! intra-layer depths vary, and the first image's fill windows start
//! early while upstream rings are saturated — both pinned by the tests
//! below against the executable mirror). [`windows_from_trace`] reads
//! the exact emission windows the engine records through a
//! [`crate::obs::TraceSink`] and is exact everywhere, fill and drain
//! included.

use crate::obs::trace::{TraceEvent, TracePhase};
use crate::pipeline::StagePlan;

use super::engine::SimResult;

/// One stage's activity window for one image (logical cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    /// Stage (layer) index.
    pub stage: usize,
    /// Image index.
    pub image: u64,
    /// First cycle the image occupies the stage.
    pub start: u64,
    /// One past the last occupied cycle.
    pub end: u64,
}

/// Reconstruct per-stage windows from a schedule using the static plan
/// offsets (the engine records injections/completions; stage windows
/// follow the dispatcher shape). This is the sink-free fallback:
/// steady-state-exact on equal-occupancy pipelines, but the first
/// image's fill windows and any stage whose depth differs from its
/// producer's are shifted by a small constant against the engine's real
/// emission windows — use [`windows_from_trace`] when exactness matters
/// (the module doc has the full story).
pub fn windows(plans: &[StagePlan], sim: &SimResult) -> Vec<Window> {
    let shape = crate::coordinator::PipelineShape::from_plans(plans);
    let mut out = Vec::new();
    for (img, &inj) in sim.injections.iter().enumerate() {
        if inj == u64::MAX {
            continue;
        }
        for stage in 0..plans.len() {
            let (s, e) = shape.window(inj, stage);
            out.push(Window {
                stage,
                image: img as u64,
                start: s,
                end: e,
            });
        }
    }
    out
}

/// Exact per-stage windows from recorded trace events: every `"stage"`
/// span the pipeline engine emitted through its sink (subsystem
/// `"pipeline"`, track = stage index, `image` argument) becomes one
/// [`Window`] covering precisely the cycles the image occupied the
/// stage. Unlike [`windows`], fill and drain transients are exact.
/// Windows are sorted by `(image, stage)`.
pub fn windows_from_trace(events: &[TraceEvent]) -> Vec<Window> {
    let mut out = Vec::new();
    for ev in events {
        if ev.subsystem != "pipeline" || ev.name != "stage" {
            continue;
        }
        let TracePhase::Span { dur } = ev.phase else {
            continue;
        };
        let image = ev
            .args
            .iter()
            .find(|(k, _)| *k == "image")
            .map(|&(_, v)| v)
            .unwrap_or(u64::MAX);
        out.push(Window {
            stage: ev.track as usize,
            image,
            start: ev.ts,
            end: ev.ts + dur,
        });
    }
    out.sort_by_key(|w| (w.image, w.stage));
    out
}

/// ASCII Gantt chart: one row per stage, one column per `scale` cycles;
/// cells show the image index (mod 10) active in that bucket.
pub fn gantt(plans: &[StagePlan], sim: &SimResult, width: usize) -> String {
    let ws = windows(plans, sim);
    let horizon = sim
        .completions
        .iter()
        .filter(|&&c| c != u64::MAX)
        .max()
        .copied()
        .unwrap_or(0)
        .max(1);
    let scale = horizon.div_ceil(width as u64).max(1);
    let mut rows: Vec<Vec<u8>> = vec![vec![b'.'; width]; plans.len()];
    for w in &ws {
        let lo = (w.start / scale) as usize;
        let hi = ((w.end.saturating_sub(1)) / scale) as usize;
        for col in lo..=hi.min(width - 1) {
            rows[w.stage][col] = b'0' + (w.image % 10) as u8;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "gantt: {} cycles, {} cycles/char\n",
        horizon, scale
    ));
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!(
            "{:>10} |{}|\n",
            plans[i].name,
            String::from_utf8_lossy(row)
        ));
    }
    out
}

/// CSV export (stage,image,start,end) for external plotting.
pub fn to_csv(plans: &[StagePlan], sim: &SimResult) -> String {
    let mut out = String::from("stage,name,image,start,end\n");
    for w in windows(plans, sim) {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            w.stage, plans[w.stage].name, w.image, w.start, w.end
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::{NetworkMapping, ReplicationPlan};
    use crate::obs::trace::RecordingSink;
    use crate::pipeline::{build_plans, InputDemand};
    use crate::sim::engine::{Engine, NocAdjust};

    fn run() -> (Vec<StagePlan>, SimResult) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::fig7(VggVariant::A);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let plans = build_plans(&net, &m, &arch);
        let adj = NocAdjust::identity(plans.len());
        let sim = Engine::new(&plans, &adj, true, 3).run();
        (plans, sim)
    }

    #[test]
    fn windows_cover_all_stage_image_pairs() {
        let (plans, sim) = run();
        let ws = windows(&plans, &sim);
        assert_eq!(ws.len(), plans.len() * 3);
        for w in &ws {
            assert!(w.start < w.end, "{w:?}");
        }
    }

    #[test]
    fn windows_ordered_along_the_pipeline() {
        let (plans, sim) = run();
        let ws = windows(&plans, &sim);
        // For each image, stage starts strictly increase with depth.
        for img in 0..3u64 {
            let mut starts: Vec<u64> = ws
                .iter()
                .filter(|w| w.image == img)
                .map(|w| w.start)
                .collect();
            let sorted = {
                let mut s = starts.clone();
                s.sort_unstable();
                s
            };
            assert_eq!(starts.len(), plans.len());
            starts.sort_unstable();
            assert_eq!(starts, sorted);
        }
    }

    #[test]
    fn gantt_renders_all_stages() {
        let (plans, sim) = run();
        let g = gantt(&plans, &sim, 72);
        assert_eq!(g.lines().count(), plans.len() + 1);
        assert!(g.contains("conv1"));
        assert!(g.contains("fc3"));
        // Image ids 0..2 appear somewhere.
        assert!(g.contains('0') && g.contains('1') && g.contains('2'), "{g}");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let (plans, sim) = run();
        let csv = to_csv(&plans, &sim);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "stage,image,start,end".replace("stage,", "stage,name,"));
        assert_eq!(csv.lines().count(), 1 + plans.len() * 3);
    }

    /// Uniform-depth three-stage chain (depth 5, p_total 100, rate 10,
    /// head 10 / slope 1), batch pipelining, 6 images. Constants below
    /// were pinned against the executable Python mirror of the engine.
    fn uniform_chain() -> Vec<StagePlan> {
        let stage = |i: usize| StagePlan {
            name: format!("s{i}"),
            p_total: 100,
            rate: 10,
            depth: 5,
            preds: if i == 0 { vec![] } else { vec![i - 1] },
            demands: if i == 0 {
                vec![]
            } else {
                vec![InputDemand {
                    head: 10,
                    slope: 1,
                    needs_all: false,
                }]
            },
        };
        (0..3).map(stage).collect()
    }

    #[test]
    fn trace_windows_match_static_windows_in_steady_state() {
        let plans = uniform_chain();
        let adj = NocAdjust::identity(plans.len());
        let mut sink = RecordingSink::new();
        let sim = Engine::new(&plans, &adj, true, 6).run_with_sink(&mut sink);

        // Mirror-pinned schedule.
        assert_eq!(sim.injections, vec![0, 10, 20, 30, 40, 50]);
        assert_eq!(sim.completions, vec![26, 36, 46, 56, 66, 76]);

        let exact = windows_from_trace(sink.events());
        let mut stat = windows(&plans, &sim);
        stat.sort_by_key(|w| (w.image, w.stage));
        assert_eq!(exact.len(), stat.len());

        for (e, s) in exact.iter().zip(&stat) {
            assert_eq!((e.stage, e.image), (s.stage, s.image));
            if e.image >= 1 {
                // Steady state: the static reconstruction is exact.
                assert_eq!(e, s, "image {} stage {}", e.image, e.stage);
            }
        }

        // Fill transient: while upstream rings are still empty the real
        // engine lets downstream stages start as soon as visibility
        // allows, earlier than the static offsets claim (the documented
        // inaccuracy this function fixes). Mirror-pinned windows:
        let img0 = |stage: usize, ws: &[Window]| {
            *ws.iter()
                .find(|w| w.image == 0 && w.stage == stage)
                .unwrap()
        };
        assert_eq!((img0(0, &exact).start, img0(0, &exact).end), (0, 10));
        assert_eq!((img0(1, &exact).start, img0(1, &exact).end), (0, 16));
        assert_eq!((img0(2, &exact).start, img0(2, &exact).end), (11, 22));
        assert_eq!((img0(1, &stat).start, img0(1, &stat).end), (6, 16));
        assert_eq!((img0(2, &stat).start, img0(2, &stat).end), (12, 22));
    }

    #[test]
    fn trace_windows_cover_vgg_and_pin_completion_identity() {
        let (plans, _) = run();
        let adj = NocAdjust::identity(plans.len());
        let mut sink = RecordingSink::new();
        let sim = Engine::new(&plans, &adj, true, 3).run_with_sink(&mut sink);

        let exact = windows_from_trace(sink.events());
        assert_eq!(exact.len(), plans.len() * 3);

        // Same (stage, image) coverage as the static reconstruction.
        let mut stat = windows(&plans, &sim);
        stat.sort_by_key(|w| (w.image, w.stage));
        let keys = |ws: &[Window]| -> Vec<(usize, u64)> {
            ws.iter().map(|w| (w.stage, w.image)).collect()
        };
        assert_eq!(keys(&exact), keys(&stat));

        // Stage 0 has no producer, so static and exact always agree.
        for (e, s) in exact.iter().zip(&stat) {
            assert!(e.start < e.end, "{e:?}");
            if e.stage == 0 {
                assert_eq!(e, s);
            }
        }

        // Completion = last emission cycle + intra-layer drain depth.
        let last = plans.len() - 1;
        for (img, &comp) in sim.completions.iter().enumerate() {
            let w = exact
                .iter()
                .find(|w| w.stage == last && w.image == img as u64)
                .unwrap();
            assert_eq!(comp, w.end - 1 + plans[last].depth);
        }
    }
}
