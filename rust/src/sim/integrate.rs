//! Processing ↔ interconnect co-simulation (Sec. VI): run the mapped CNN's
//! flow set through the flit-level NoC, convert measured latency and
//! acceptance into per-stage adjustments, and evaluate the full benchmark
//! grid (VGG x scenario x NoC) — the machinery behind Figs. 5, 6, 8, 9.

use crate::cnn::{vgg, Network, VggVariant};
use crate::config::{ArchConfig, NocKind, Scenario};
use crate::mapping::{MappingSelection, NetworkMapping, Placement, ReplicationPlan};
use crate::noc::sim::run_flows_detailed_traced;
use crate::noc::AnyTopology;
use crate::obs::trace::SharedSink;
use crate::pipeline::{build_plans, StagePlan};
use crate::power::{EnergyBreakdown, EnergyModel};

use super::engine::{Engine, NocAdjust, SimResult};
use super::traffic::{extract_flows, flatten, LayerFlows};

/// Router parameters used for the CNN mesh. The paper ran two separate
/// garnet experiments with their own configs: the synthetic study (Sec. VII,
/// 8x8 mesh — see `SyntheticConfig`) and this full-system co-simulation
/// (Sec. VI, 16x20). Here the wormhole baseline keeps the node's multi-stage
/// router with standard 4-flit buffers (per-port service ~ depth/(latency+2)
/// ≈ 0.66 flits/cycle — putting the replicated conv1/conv2 hotspot a few
/// percent past stability, which is what places wormhole behind SMART in
/// Figs. 6/8); SMART routers are single-cycle with bypass.
pub fn router_params(kind: NocKind) -> (u64, usize) {
    match kind {
        NocKind::Smart => (1, 4),
        _ => (4, 4),
    }
}

/// NoC measurement window (NoC cycles).
const NOC_WARMUP: u64 = 3_000;
const NOC_MEASURE: u64 = 12_000;
const NOC_DRAIN: u64 = 30_000;

/// Assess the NoC's impact on a mapped pipeline.
pub fn assess_noc(
    kind: NocKind,
    net: &Network,
    mapping: &NetworkMapping,
    placement: &Placement,
    plans: &[StagePlan],
    arch: &ArchConfig,
) -> (NocAdjust, Vec<LayerFlows>) {
    assess_noc_traced(kind, net, mapping, placement, plans, arch, None)
}

/// [`assess_noc`] with an optional trace sink attached to the NoC backend
/// (subsystem `"noc"` events from the CNN flow run). Observational only.
#[allow(clippy::too_many_arguments)]
pub fn assess_noc_traced(
    kind: NocKind,
    net: &Network,
    mapping: &NetworkMapping,
    placement: &Placement,
    plans: &[StagePlan],
    arch: &ArchConfig,
    trace: Option<SharedSink>,
) -> (NocAdjust, Vec<LayerFlows>) {
    let layer_flows = extract_flows(net, mapping, placement, plans, arch);
    let n = plans.len();
    let mut adjust = NocAdjust::identity(n);
    if matches!(kind, NocKind::Ideal) {
        // One-cycle fabric: a logical cycle always covers the hop.
        return (adjust, layer_flows);
    }
    let (flows, owner) = flatten(&layer_flows);
    if flows.is_empty() {
        return (adjust, layer_flows);
    }
    let (rl, depth) = router_params(kind);
    let topo = AnyTopology::for_node(arch);
    let stats = run_flows_detailed_traced(
        kind,
        topo,
        &flows,
        NOC_WARMUP,
        NOC_MEASURE,
        NOC_DRAIN,
        arch.hpc_max,
        rl,
        depth,
        trace,
    );
    let phi = arch.noc_cycles_per_logical();
    // Aggregate per layer, weighted by offered packets: the stage's
    // effective acceptance is total completed / total offered across its
    // flows (a min over flows would amplify sampling noise on the many
    // near-zero-rate flows), and its transfer latency is the
    // offered-weighted mean.
    let mut lat_sum = vec![0.0f64; n];
    let mut lat_w = vec![0.0f64; n];
    let mut offered = vec![0u64; n];
    let mut completed = vec![0u64; n];
    for (fi, s) in stats.iter().enumerate() {
        let li = owner[fi];
        if s.completed > 0 {
            lat_sum[li] += s.avg_latency * s.offered_window as f64;
            lat_w[li] += s.offered_window as f64;
        }
        offered[li] += s.offered_window;
        completed[li] += s.completed_window;
    }
    for li in 0..n {
        if lat_w[li] > 0.0 {
            let mean_lat = lat_sum[li] / lat_w[li];
            // Transfer latency delays when the *next* stage sees the data.
            let extra = (mean_lat / phi).ceil() as u64;
            if li + 1 < n {
                adjust.extra_depth[li + 1] += extra;
            }
        }
        // A saturated mesh throttles the producer's streaming rate.
        adjust.rate_scale[li] = if offered[li] == 0 {
            1.0
        } else {
            (completed[li] as f64 / offered[li] as f64).clamp(0.05, 1.0)
        };
    }
    (adjust, layer_flows)
}

/// One benchmark point's results (a cell of Fig. 8 / a bar of Figs. 5-6).
#[derive(Debug, Clone)]
pub struct PerfReport {
    /// VGG variant evaluated.
    pub variant: VggVariant,
    /// Pipelining scenario (Sec. VI-B's (1)-(4)).
    pub scenario: Scenario,
    /// Interconnect model.
    pub noc: NocKind,
    /// Steady-state injection interval (logical cycles).
    pub interval_cycles: f64,
    /// Per-image latency (logical cycles, steady state).
    pub latency_cycles: f64,
    /// Frames per second at the calibrated logical clock.
    pub fps: f64,
    /// Tera-operations per second (1 MAC = 2 ops).
    pub tops: f64,
    /// Per-image energy breakdown.
    pub energy: EnergyBreakdown,
    /// Energy efficiency.
    pub tops_per_watt: f64,
    /// Raw schedule (completions/injections) for deeper analysis.
    pub sim: SimResult,
}

/// Results of evaluating an arbitrary network (DAG or chain) under an
/// explicit replication plan — the workload-agnostic core behind
/// [`evaluate`] and the `--network` CLI paths.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    /// Workload name (`Network::name`).
    pub network: String,
    /// Steady-state injection interval (logical cycles).
    pub interval_cycles: f64,
    /// Per-image latency (logical cycles, steady state).
    pub latency_cycles: f64,
    /// Frames per second at the calibrated logical clock.
    pub fps: f64,
    /// Tera-operations per second (1 MAC = 2 ops).
    pub tops: f64,
    /// Per-image energy breakdown.
    pub energy: EnergyBreakdown,
    /// Energy efficiency.
    pub tops_per_watt: f64,
    /// Raw schedule (completions/injections) for deeper analysis.
    pub sim: SimResult,
}

/// Evaluate any mapped network: pipeline + NoC co-simulation with `images`
/// streamed (batch-pipelined or not), energy model included. Errors when
/// the plan does not map under `arch`.
pub fn evaluate_network(
    net: &Network,
    plan: &ReplicationPlan,
    batch: bool,
    noc: NocKind,
    arch: &ArchConfig,
    images: u64,
) -> Result<NetworkReport, String> {
    evaluate_network_mapped(
        net,
        plan,
        &MappingSelection::im2col(net.len()),
        batch,
        noc,
        arch,
        images,
    )
}

/// [`evaluate_network`] under a per-layer mapping selection: the whole
/// mapping -> placement -> NoC -> engine -> energy chain is driven by the
/// selected packing (`--mapping` on the CLI).
pub fn evaluate_network_mapped(
    net: &Network,
    plan: &ReplicationPlan,
    selection: &MappingSelection,
    batch: bool,
    noc: NocKind,
    arch: &ArchConfig,
    images: u64,
) -> Result<NetworkReport, String> {
    evaluate_network_mapped_traced(net, plan, selection, batch, noc, arch, images, None)
}

/// [`evaluate_network_mapped`] with an optional trace sink threaded
/// through both halves of the co-simulation: the NoC flow run (subsystem
/// `"noc"`) and the pipeline engine (subsystem `"pipeline"`). With `None`
/// this *is* [`evaluate_network_mapped`]; with a sink, every reported
/// number is still bit-identical (`tests/obs_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_network_mapped_traced(
    net: &Network,
    plan: &ReplicationPlan,
    selection: &MappingSelection,
    batch: bool,
    noc: NocKind,
    arch: &ArchConfig,
    images: u64,
    trace: Option<SharedSink>,
) -> Result<NetworkReport, String> {
    let mapping = NetworkMapping::build_with(net, arch, plan, selection)?;
    let placement = Placement::for_topology(arch);
    let plans = build_plans(net, &mapping, arch);
    let (adjust, layer_flows) =
        assess_noc_traced(noc, net, &mapping, &placement, &plans, arch, trace.clone());
    let engine = Engine::new(&plans, &adjust, batch, images);
    let sim = match &trace {
        Some(sink) => engine.run_with_sink(&mut *sink.borrow_mut()),
        None => engine.run(),
    };

    let interval = sim.interval_or_makespan();
    let lats = sim.latencies();
    let latency = lats[lats.len() / 2..]
        .iter()
        .map(|&l| l as f64)
        .sum::<f64>()
        / (lats.len() - lats.len() / 2) as f64;
    let t_log_s = arch.logical_cycle_ns * 1e-9;
    let fps = 1.0 / (interval * t_log_s);
    let tops = fps * net.ops() as f64 / 1e12;

    let em = EnergyModel::new(arch);
    // Fan-out-aware hop weights: one full OFM copy per DAG successor.
    let copy_hops: Vec<f64> = layer_flows.iter().map(|l| l.copy_hops).collect();
    let energy = em.image_energy(net, &mapping, &copy_hops);
    let tops_per_watt = em.tops_per_watt(net, &energy);

    Ok(NetworkReport {
        network: net.name.clone(),
        interval_cycles: interval,
        latency_cycles: latency,
        fps,
        tops,
        energy,
        tops_per_watt,
        sim,
    })
}

/// Number of images simulated per benchmark point (enough for a stable
/// steady-state interval; the pipeline is periodic after the first image).
pub fn default_images(scenario: Scenario) -> u64 {
    if scenario.batch() {
        10
    } else {
        4
    }
}

/// Evaluate one (VGG, scenario, NoC) benchmark — the paper's unit of
/// evaluation (60 in total). Thin wrapper over [`evaluate_network`] with
/// the scenario's canonical plan (Fig. 7 or none) and image count.
pub fn evaluate(
    variant: VggVariant,
    scenario: Scenario,
    noc: NocKind,
    arch: &ArchConfig,
) -> PerfReport {
    evaluate_traced(variant, scenario, noc, arch, None)
}

/// [`evaluate`] with an optional trace sink (see
/// [`evaluate_network_mapped_traced`]); backs `simulate --trace-out`.
pub fn evaluate_traced(
    variant: VggVariant,
    scenario: Scenario,
    noc: NocKind,
    arch: &ArchConfig,
    trace: Option<SharedSink>,
) -> PerfReport {
    let net = vgg::build(variant);
    let plan = if scenario.replication() {
        ReplicationPlan::fig7(variant)
    } else {
        ReplicationPlan::none(&net)
    };
    let r = evaluate_network_mapped_traced(
        &net,
        &plan,
        &MappingSelection::im2col(net.len()),
        scenario.batch(),
        noc,
        arch,
        default_images(scenario),
        trace,
    )
    .expect("mapping must fit");
    PerfReport {
        variant,
        scenario,
        noc,
        interval_cycles: r.interval_cycles,
        latency_cycles: r.latency_cycles,
        fps: r.fps,
        tops: r.tops,
        energy: r.energy,
        tops_per_watt: r.tops_per_watt,
        sim: r.sim,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> ArchConfig {
        ArchConfig::paper_node()
    }

    #[test]
    fn ideal_assess_is_identity() {
        let a = arch();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::fig7(VggVariant::A);
        let m = NetworkMapping::build(&net, &a, &plan).unwrap();
        let p = Placement::snake(&a);
        let plans = build_plans(&net, &m, &a);
        let (adj, _) = assess_noc(NocKind::Ideal, &net, &m, &p, &plans, &a);
        assert!(adj.extra_depth.iter().all(|&d| d == 0));
        assert!(adj.rate_scale.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn vgg_e_best_case_near_paper() {
        // Fig. 8 ideal scenario (4): 40.9131 TOPS / 1042 FPS. Our interval
        // is calibrated to 3136 cycles; fps = 1/(3136 * 306ns) = 1042.
        let r = evaluate(
            VggVariant::E,
            Scenario::ReplicationBatch,
            NocKind::Ideal,
            &arch(),
        );
        assert!((r.fps - 1042.0).abs() < 40.0, "fps {}", r.fps);
        assert!((r.tops - 40.9).abs() < 2.0, "tops {}", r.tops);
    }

    #[test]
    fn scenario_ordering_holds() {
        // (4) >= (3) >= (1) and (4) >= (2) >= (1) in throughput.
        let a = arch();
        let f = |s| {
            evaluate(VggVariant::A, s, NocKind::Ideal, &a).fps
        };
        let f1 = f(Scenario::Baseline);
        let f2 = f(Scenario::BatchOnly);
        let f3 = f(Scenario::ReplicationOnly);
        let f4 = f(Scenario::ReplicationBatch);
        assert!(f2 >= f1 * 0.999, "batch {f2} < baseline {f1}");
        assert!(f3 > 5.0 * f1, "repl {f3} vs baseline {f1}");
        assert!(f4 >= f3 * 0.999, "both {f4} < repl {f3}");
    }

    #[test]
    fn smart_between_wormhole_and_ideal() {
        // Fig. 6/8: wormhole <= smart <= ideal in throughput.
        let a = arch();
        let f = |k| evaluate(VggVariant::E, Scenario::ReplicationBatch, k, &a).fps;
        let w = f(NocKind::Wormhole);
        let s = f(NocKind::Smart);
        let i = f(NocKind::Ideal);
        assert!(w <= s * 1.001, "wormhole {w} > smart {s}");
        assert!(s <= i * 1.001, "smart {s} > ideal {i}");
    }

    #[test]
    fn resnet18_evaluates_end_to_end() {
        use crate::cnn::{resnet, ResNetVariant};
        let a = arch();
        let net = resnet::build(ResNetVariant::R18);
        let plan = ReplicationPlan::none(&net);
        let r = evaluate_network(&net, &plan, true, NocKind::Ideal, &a, 6).unwrap();
        assert_eq!(r.network, "resnet18");
        // Unreplicated bottleneck: the stem streams 112*112 = 12544 pixel
        // positions (56x56 stages emit 3136 < 12544).
        assert!(
            (r.interval_cycles - 12544.0).abs() <= 64.0,
            "interval {}",
            r.interval_cycles
        );
        assert!(r.fps > 0.0 && r.tops > 0.0 && r.tops_per_watt > 0.0);
    }

    #[test]
    fn energy_efficiency_in_band() {
        // Fig. 9 band: 2.5 - 3.6 TOPS/W across the VGGs.
        let a = arch();
        for v in VggVariant::ALL {
            let r = evaluate(v, Scenario::ReplicationBatch, NocKind::Ideal, &a);
            assert!(
                (1.5..6.0).contains(&r.tops_per_watt),
                "{}: {} TOPS/W",
                v.name(),
                r.tops_per_watt
            );
        }
    }
}
