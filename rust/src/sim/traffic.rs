//! Inter-layer NoC traffic extraction: turn a mapped, placed network plus a
//! pipeline schedule into the point-to-point flow set the mesh must carry
//! while the pipeline streams (Sec. VI's processing/interconnect co-model).

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::{NetworkMapping, Placement};
use crate::noc::Flow;
use crate::pipeline::StagePlan;

/// Flows of one producer layer (layer i -> layer i+1), with bookkeeping to
/// map NoC results back to stages.
#[derive(Debug, Clone)]
pub struct LayerFlows {
    pub layer_idx: usize,
    pub flows: Vec<Flow>,
    /// Mean XY hop count across the flow set (for Eq. (3)-style reporting
    /// and the energy model).
    pub mean_hops: f64,
}

/// Extract flows. `noc_cycles_per_logical` converts the pipeline's
/// per-logical-cycle emission rates into NoC-clock packet rates.
pub fn extract_flows(
    net: &Network,
    mapping: &NetworkMapping,
    placement: &Placement,
    plans: &[StagePlan],
    arch: &ArchConfig,
) -> Vec<LayerFlows> {
    let phi = arch.noc_cycles_per_logical();
    let layers = net.layers();
    let mut out = Vec::new();
    for i in 0..layers.len() {
        let producer = &layers[i];
        let src_tiles = &mapping.layers[i].tile_ids;
        // The last layer streams its logits off-chip through tile 0's
        // router; intermediate layers feed the next layer's tiles.
        let dst_tiles: Vec<usize> = if i + 1 < layers.len() {
            mapping.layers[i + 1].tile_ids.clone()
        } else {
            vec![0]
        };
        // Values leaving layer i per image: pooled OFM (the MP unit runs
        // before the OR/tile boundary).
        let (oh, ow) = producer.out_hw();
        let values = (oh * ow * producer.out_ch()) as f64;
        let flits_per_image = values / arch.values_per_flit() as f64;
        // The layer streams its image over `occupancy` logical cycles.
        let occupancy = plans[i].p_total.div_ceil(plans[i].rate).max(1) as f64;
        let flits_per_noc_cycle = flits_per_image / (occupancy * phi);
        // Packetize: one packet carries one destination-bound pixel group,
        // capped at 8 flits (64 values) to keep worms bounded.
        let packet_len = ((producer.out_ch() / arch.values_per_flit()).clamp(1, 8)) as u16;
        let n_flows = (src_tiles.len() * dst_tiles.len()) as f64;
        let pkts_per_cycle_per_flow =
            flits_per_noc_cycle / packet_len as f64 / n_flows;
        let mut flows = Vec::with_capacity(src_tiles.len() * dst_tiles.len());
        let mut hop_sum = 0.0;
        for &s in src_tiles {
            for &d in dst_tiles.iter() {
                let src = placement.node_of(s);
                let dst = placement.node_of(d);
                if src == dst {
                    continue; // same router: the tile bus handles it
                }
                hop_sum += placement.coord(s).hops(&placement.coord(d)) as f64;
                flows.push(Flow {
                    src,
                    dst,
                    packets_per_cycle: pkts_per_cycle_per_flow,
                    packet_len,
                });
            }
        }
        let mean_hops = if flows.is_empty() {
            0.0
        } else {
            hop_sum / flows.len() as f64
        };
        out.push(LayerFlows {
            layer_idx: i,
            flows,
            mean_hops,
        });
    }
    out
}

/// Flatten for the NoC driver, remembering which flow belongs to which
/// layer.
pub fn flatten(layer_flows: &[LayerFlows]) -> (Vec<Flow>, Vec<usize>) {
    let mut flows = Vec::new();
    let mut owner = Vec::new();
    for lf in layer_flows {
        for &f in &lf.flows {
            flows.push(f);
            owner.push(lf.layer_idx);
        }
    }
    (flows, owner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;
    use crate::pipeline::build_plans;

    fn setup() -> (Network, NetworkMapping, Placement, Vec<StagePlan>, ArchConfig) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let p = Placement::snake(&arch);
        let plans = build_plans(&net, &m, &arch);
        (net, m, p, plans, arch)
    }

    #[test]
    fn flows_cover_every_layer() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        assert_eq!(lf.len(), net.len());
        // Multi-tile adjacent layers must produce traffic.
        assert!(lf.iter().any(|l| !l.flows.is_empty()));
    }

    #[test]
    fn rates_are_positive_and_bounded() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        for l in &lf {
            for f in &l.flows {
                assert!(f.packets_per_cycle > 0.0, "layer {}", l.layer_idx);
                assert!(
                    f.packets_per_cycle < 1.0,
                    "layer {} flow rate {} (> 1 pkt/cycle/flow is unschedulable)",
                    l.layer_idx,
                    f.packets_per_cycle
                );
                assert!((1..=8).contains(&f.packet_len));
            }
        }
    }

    #[test]
    fn snake_placement_keeps_hops_low() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        // Exclude the final layer: its logits leave through tile 0's router,
        // which is legitimately far from the last FC tiles.
        let worst = lf[..lf.len() - 1]
            .iter()
            .filter(|l| !l.flows.is_empty())
            .map(|l| l.mean_hops)
            .fold(0.0f64, f64::max);
        // Adjacent layers sit in adjacent snake runs; mean hops should stay
        // far below the mesh diameter (34).
        assert!(worst < 12.0, "worst mean hops {worst}");
        let _ = net;
    }

    #[test]
    fn flatten_preserves_ownership() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        let (flows, owner) = flatten(&lf);
        assert_eq!(flows.len(), owner.len());
        let total: usize = lf.iter().map(|l| l.flows.len()).sum();
        assert_eq!(flows.len(), total);
        assert!(owner.windows(2).all(|w| w[0] <= w[1]));
        let _ = net;
    }
}
