//! Inter-layer NoC traffic extraction: turn a mapped, placed network plus a
//! pipeline schedule into the point-to-point flow set the fabric must carry
//! while the pipeline streams (Sec. VI's processing/interconnect co-model).
//! Hop counts come from the configured topology (`arch.topology`), so the
//! same extraction serves mesh, torus, and Parallel-Prism runs.

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::mapping::{NetworkMapping, Placement};
use crate::noc::{AnyTopology, Flow};
use crate::pipeline::StagePlan;

/// Flows of one producer layer (layer i -> each of its DAG successors),
/// with bookkeeping to map NoC results back to stages.
#[derive(Debug, Clone)]
pub struct LayerFlows {
    /// Producer layer index into `Network::layers()`.
    pub layer_idx: usize,
    /// Point-to-point flows this layer injects into the mesh.
    pub flows: Vec<Flow>,
    /// Mean topology hop count across the whole flow set (Eq. (3)-style
    /// reporting; minimal-route hops on the configured fabric — Manhattan
    /// distance on the mesh).
    pub mean_hops: f64,
    /// Sum over DAG successors of that successor's mean hop count — the
    /// per-image hop cost of moving one full OFM copy to *each* consumer
    /// (the energy model's weight; equals `mean_hops` on a chain).
    pub copy_hops: f64,
}

/// Extract flows. `noc_cycles_per_logical` converts the pipeline's
/// per-logical-cycle emission rates into NoC-clock packet rates.
pub fn extract_flows(
    net: &Network,
    mapping: &NetworkMapping,
    placement: &Placement,
    plans: &[StagePlan],
    arch: &ArchConfig,
) -> Vec<LayerFlows> {
    let phi = arch.noc_cycles_per_logical();
    let topo = AnyTopology::for_node(arch);
    let layers = net.layers();
    let mut out = Vec::new();
    for i in 0..layers.len() {
        let producer = &layers[i];
        let src_tiles = &mapping.layers[i].tile_ids;
        // The sink layer streams its logits off-chip through tile 0's
        // router; every other layer feeds each DAG successor's tiles. At a
        // branch point the OFM *fans out*: every successor receives a full
        // copy, so the injected load scales with the fan-out degree.
        let dst_sets: Vec<Vec<usize>> = if net.succs(i).is_empty() {
            vec![vec![0]]
        } else {
            net.succs(i)
                .iter()
                .map(|&s| mapping.layers[s].tile_ids.clone())
                .collect()
        };
        // Values leaving layer i per image: pooled OFM (the MP unit runs
        // before the OR/tile boundary).
        let (oh, ow) = producer.out_hw();
        let values = (oh * ow * producer.out_ch()) as f64;
        let flits_per_image = values / arch.values_per_flit() as f64;
        // The layer streams its image over `occupancy` logical cycles.
        let occupancy = plans[i].p_total.div_ceil(plans[i].rate).max(1) as f64;
        let flits_per_noc_cycle = flits_per_image / (occupancy * phi);
        // Packetize: one packet carries one destination-bound pixel group,
        // capped at 8 flits (64 values) to keep worms bounded.
        let packet_len = ((producer.out_ch() / arch.values_per_flit()).clamp(1, 8)) as u16;
        let mut flows = Vec::new();
        let mut hop_sum = 0.0;
        let mut copy_hops = 0.0;
        for dst_tiles in &dst_sets {
            // One full OFM copy per successor, spread over this successor's
            // src x dst flow pairs.
            let n_flows = (src_tiles.len() * dst_tiles.len()) as f64;
            let pkts_per_cycle_per_flow = flits_per_noc_cycle / packet_len as f64 / n_flows;
            let mut set_hops = 0.0;
            for &s in src_tiles {
                for &d in dst_tiles.iter() {
                    let src = placement.node_of(s);
                    let dst = placement.node_of(d);
                    if src == dst {
                        continue; // same router: the tile bus handles it
                    }
                    set_hops += topo.hops(src, dst) as f64;
                    flows.push(Flow {
                        src,
                        dst,
                        packets_per_cycle: pkts_per_cycle_per_flow,
                        packet_len,
                    });
                }
            }
            hop_sum += set_hops;
            // This copy's flits split evenly over all src x dst pairs
            // (same-router pairs ride the tile bus at zero hop cost), so
            // the copy's mean hop distance averages over every pair.
            copy_hops += set_hops / n_flows;
        }
        let mean_hops = if flows.is_empty() {
            0.0
        } else {
            hop_sum / flows.len() as f64
        };
        out.push(LayerFlows {
            layer_idx: i,
            flows,
            mean_hops,
            copy_hops,
        });
    }
    out
}

/// Flatten for the NoC driver, remembering which flow belongs to which
/// layer.
pub fn flatten(layer_flows: &[LayerFlows]) -> (Vec<Flow>, Vec<usize>) {
    let mut flows = Vec::new();
    let mut owner = Vec::new();
    for lf in layer_flows {
        for &f in &lf.flows {
            flows.push(f);
            owner.push(lf.layer_idx);
        }
    }
    (flows, owner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::mapping::ReplicationPlan;
    use crate::pipeline::build_plans;

    fn setup() -> (Network, NetworkMapping, Placement, Vec<StagePlan>, ArchConfig) {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let p = Placement::snake(&arch);
        let plans = build_plans(&net, &m, &arch);
        (net, m, p, plans, arch)
    }

    #[test]
    fn flows_cover_every_layer() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        assert_eq!(lf.len(), net.len());
        // Multi-tile adjacent layers must produce traffic.
        assert!(lf.iter().any(|l| !l.flows.is_empty()));
    }

    #[test]
    fn rates_are_positive_and_bounded() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        for l in &lf {
            for f in &l.flows {
                assert!(f.packets_per_cycle > 0.0, "layer {}", l.layer_idx);
                assert!(
                    f.packets_per_cycle < 1.0,
                    "layer {} flow rate {} (> 1 pkt/cycle/flow is unschedulable)",
                    l.layer_idx,
                    f.packets_per_cycle
                );
                assert!((1..=8).contains(&f.packet_len));
            }
        }
    }

    #[test]
    fn snake_placement_keeps_hops_low() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        // Exclude the final layer: its logits leave through tile 0's router,
        // which is legitimately far from the last FC tiles.
        let worst = lf[..lf.len() - 1]
            .iter()
            .filter(|l| !l.flows.is_empty())
            .map(|l| l.mean_hops)
            .fold(0.0f64, f64::max);
        // Adjacent layers sit in adjacent snake runs; mean hops should stay
        // far below the mesh diameter (34).
        assert!(worst < 12.0, "worst mean hops {worst}");
        let _ = net;
    }

    #[test]
    fn chain_copy_hops_equal_mean_hops() {
        // On a linear network every layer has one successor, so the energy
        // model's per-copy hop weight is just the flow-set mean.
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        for l in &lf {
            assert!(
                (l.copy_hops - l.mean_hops).abs() < 1e-12,
                "layer {}: copy {} vs mean {}",
                l.layer_idx,
                l.copy_hops,
                l.mean_hops
            );
        }
        let _ = net;
    }

    #[test]
    fn branch_points_fan_out_full_copies() {
        use crate::cnn::{resnet, ResNetVariant};
        let arch = ArchConfig::paper_node();
        let net = resnet::build(ResNetVariant::R18);
        let plan = ReplicationPlan::none(&net);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        let p = Placement::snake(&arch);
        let plans = build_plans(&net, &m, &arch);
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        assert_eq!(lf.len(), net.len());
        let phi = arch.noc_cycles_per_logical();
        let mut checked = 0;
        for (i, l) in net.layers().iter().enumerate() {
            if net.succs(i).len() < 2 {
                continue;
            }
            checked += 1;
            // Injected flit rate across all flows equals fan-out x one full
            // OFM copy per streaming window (tile runs are disjoint, so no
            // same-router pair is skipped under the none plan).
            let (oh, ow) = l.out_hw();
            let values = (oh * ow * l.out_ch()) as f64;
            let occupancy = plans[i].p_total.div_ceil(plans[i].rate).max(1) as f64;
            let one_copy = values / arch.values_per_flit() as f64 / (occupancy * phi);
            let total: f64 = lf[i]
                .flows
                .iter()
                .map(|f| f.packets_per_cycle * f.packet_len as f64)
                .sum();
            let want = net.succs(i).len() as f64 * one_copy;
            assert!(
                (total - want).abs() < want * 1e-9,
                "layer {} ({}): {total} vs {want}",
                i,
                l.name
            );
        }
        assert!(checked >= 8, "ResNet-18 has a branch before every block");
    }

    #[test]
    fn flatten_preserves_ownership() {
        let (net, m, p, plans, arch) = setup();
        let lf = extract_flows(&net, &m, &p, &plans, &arch);
        let (flows, owner) = flatten(&lf);
        assert_eq!(flows.len(), owner.len());
        let total: usize = lf.iter().map(|l| l.flows.len()).sum();
        assert_eq!(flows.len(), total);
        assert!(owner.windows(2).all(|w| w[0] <= w[1]));
        let _ = net;
    }
}
