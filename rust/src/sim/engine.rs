//! Cycle-accurate processing-side simulator — the Rust counterpart of the
//! paper's from-scratch C++ simulator (Sec. VI-A).
//!
//! Each stage (layer) processes at most one image per logical cycle (the
//! paper's structural-hazard rule, Sec. IV-C) and emits up to `rate` output
//! units per cycle once its input demand (Sec. IV-B) is met **on every
//! incoming DAG edge** — a residual/concat merge therefore waits on its
//! slowest predecessor. Emissions become visible to consumer stages `depth`
//! cycles later (the intra-layer pipeline, Sec. IV-A). Batch pipelining is
//! the injection policy: with it, image k+1 enters stage 0 as soon as
//! stage 0 finished emitting image k; without it, image k+1 waits for
//! image k to leave the whole network.

use std::collections::VecDeque;

use crate::obs::trace::{NullSink, TraceEvent, TracePhase, TraceSink};
use crate::pipeline::StagePlan;

/// Ring size for delayed-visibility snapshots; must exceed every stage
/// depth (max 31 + NoC extension).
const RING: usize = 256;

/// Per-layer knobs from the NoC coupling (identity when the NoC is ideal).
#[derive(Debug, Clone)]
pub struct NocAdjust {
    /// Extra logical cycles added to each stage's visibility delay
    /// (inter-layer transfer latency over the mesh).
    pub extra_depth: Vec<u64>,
    /// Emission-rate multiplier in (0, 1]: a saturated mesh throttles the
    /// producer's effective streaming rate.
    pub rate_scale: Vec<f64>,
}

impl NocAdjust {
    /// No-op adjustment for `n` stages (ideal NoC).
    pub fn identity(n: usize) -> Self {
        Self {
            extra_depth: vec![0; n],
            rate_scale: vec![1.0; n],
        }
    }
}

/// Simulation outcome over a stream of images.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion cycle of each image (tail of last stage, incl. depth).
    pub completions: Vec<u64>,
    /// Injection cycle of each image.
    pub injections: Vec<u64>,
    /// Total cycles simulated.
    pub cycles: u64,
}

impl SimResult {
    /// Per-image latency (injection -> completion).
    pub fn latencies(&self) -> Vec<u64> {
        self.completions
            .iter()
            .zip(&self.injections)
            .map(|(c, i)| c - i)
            .collect()
    }

    /// Steady-state injection interval: mean gap between the completions of
    /// the last half of the image stream (cycle-exact for a periodic
    /// pipeline). `None` when fewer than two images completed — a
    /// single-image run has no interval to measure (callers used to panic
    /// here; they now choose their own fallback).
    pub fn steady_interval(&self) -> Option<f64> {
        let n = self.completions.len();
        if n < 2 {
            return None;
        }
        let half = n / 2;
        let span = self.completions[n - 1] - self.completions[half - 1];
        Some(span as f64 / (n - half) as f64)
    }

    /// Steady-state interval, falling back to the whole-run makespan when
    /// fewer than two images completed (a sub-2-image run effectively
    /// serves one image per full pass). This is the panic-free form every
    /// caller that cannot guarantee its image count should use.
    pub fn interval_or_makespan(&self) -> f64 {
        self.steady_interval().unwrap_or(self.cycles as f64)
    }
}

struct Stage {
    plan: StagePlan,
    /// Visibility delay: plan.depth + NoC extra.
    depth: u64,
    /// Fractional-rate credit (NoC-throttled rates).
    rate: f64,
    /// `Some(rate)` when the rate is an unthrottled integer — the common
    /// (ideal-NoC) case takes a credit-free fast path.
    rate_int: Option<u64>,
    credit: f64,
    /// Images waiting / in progress (front = active).
    queue: VecDeque<u64>,
    /// Emitted units of the active image.
    emitted: u64,
    /// finish_emit[img] = cycle the stage emitted the last unit (u64::MAX
    /// while unfinished).
    finish_emit: Vec<u64>,
    /// start_emit[img] = cycle the stage emitted its first unit (u64::MAX
    /// while unstarted) — the exact trace-window left edge.
    start_emit: Vec<u64>,
    /// Ring of (image, emitted) snapshots, indexed by cycle % RING.
    ring: Vec<(u64, u64)>,
}

impl Stage {
    fn new(plan: StagePlan, extra_depth: u64, rate_scale: f64, images: usize) -> Self {
        let rate = (plan.rate as f64 * rate_scale).max(1e-9);
        let rate_int = (rate.fract() == 0.0 && rate >= 1.0).then_some(rate as u64);
        Self {
            depth: plan.depth + extra_depth,
            rate,
            rate_int,
            credit: 0.0,
            plan,
            queue: VecDeque::new(),
            emitted: 0,
            finish_emit: vec![u64::MAX; images],
            start_emit: vec![u64::MAX; images],
            ring: vec![(u64::MAX, 0); RING],
        }
    }

    /// Emitted units of image `img` as of cycle `vt` (engine guarantees
    /// `now - vt < RING`).
    fn emitted_at(&self, img: u64, vt: u64) -> u64 {
        if self.finish_emit[img as usize] != u64::MAX && self.finish_emit[img as usize] <= vt {
            return self.plan.p_total;
        }
        let (ring_img, ring_emitted) = self.ring[(vt % RING as u64) as usize];
        if ring_img == img {
            ring_emitted
        } else if ring_img != u64::MAX && ring_img > img {
            // The stage had moved past `img` by vt (finished earlier).
            self.plan.p_total
        } else {
            0 // not started yet at vt
        }
    }
}

/// The engine.
pub struct Engine {
    stages: Vec<Stage>,
    batch: bool,
    images: u64,
    injected: u64,
    now: u64,
    injections: Vec<u64>,
    completions: Vec<u64>,
    /// Images complete in order; only this index needs checking per cycle.
    next_done: u64,
}

impl Engine {
    /// `plans` from [`crate::pipeline::build_plans`]; `adjust` from the NoC
    /// coupling; `batch` selects batch pipelining; `images` is the stream
    /// length to simulate.
    pub fn new(plans: &[StagePlan], adjust: &NocAdjust, batch: bool, images: u64) -> Self {
        assert_eq!(adjust.extra_depth.len(), plans.len());
        assert_eq!(adjust.rate_scale.len(), plans.len());
        let stages = plans
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let s = Stage::new(
                    p.clone(),
                    adjust.extra_depth[i],
                    adjust.rate_scale[i],
                    images as usize,
                );
                assert!(s.depth < RING as u64, "stage depth exceeds ring");
                s
            })
            .collect();
        Self {
            stages,
            batch,
            images,
            injected: 0,
            now: 0,
            injections: vec![u64::MAX; images as usize],
            completions: vec![u64::MAX; images as usize],
            next_done: 0,
        }
    }

    /// Run to completion of all images (or the safety cap) and return the
    /// schedule.
    pub fn run(self) -> SimResult {
        self.run_with_sink(&mut NullSink)
    }

    /// [`Engine::run`] reporting trace events to `sink`: one `"stage"`
    /// span per (stage, image) — the **exact** emission window, unlike
    /// the static reconstruction in [`crate::sim::windows`] — plus
    /// `"inject"` / `"complete"` instants. With [`NullSink`] this is
    /// exactly [`Engine::run`] (the schedule is bit-identical either
    /// way; pinned by `tests/obs_parity.rs`).
    pub fn run_with_sink(mut self, sink: &mut dyn TraceSink) -> SimResult {
        let _prof = crate::obs::profile::scope("engine.run");
        if sink.enabled() {
            for (i, s) in self.stages.iter().enumerate() {
                sink.name_track("pipeline", i as u64, &s.plan.name);
            }
            sink.name_track("pipeline", self.stages.len() as u64, "inject");
        }
        // Generous cap: serial execution of everything at the *effective*
        // (NoC-throttled) rates, times 4.
        let serial: u64 = self
            .stages
            .iter()
            .map(|s| (s.plan.p_total as f64 / s.rate).ceil() as u64 + s.depth)
            .sum::<u64>()
            .saturating_mul(self.images.max(1))
            .saturating_mul(4)
            .max(10_000);
        while self.done_count() < self.images {
            self.step(sink);
            assert!(
                self.now < serial,
                "engine exceeded safety cap {serial} (deadlock?)"
            );
        }
        SimResult {
            completions: self.completions,
            injections: self.injections,
            cycles: self.now,
        }
    }

    /// Debug run: print per-stage progress every `every` cycles.
    pub fn run_debug(mut self, max_cycles: u64, every: u64) -> SimResult {
        while self.done_count() < self.images && self.now < max_cycles {
            if self.now % every == 0 {
                let prog: Vec<String> = self
                    .stages
                    .iter()
                    .map(|s| {
                        format!(
                            "{}:{}/{}q{}",
                            s.plan.name,
                            s.emitted,
                            s.plan.p_total,
                            s.queue.len()
                        )
                    })
                    .collect();
                eprintln!("t={} {}", self.now, prog.join(" "));
            }
            self.step(&mut NullSink);
        }
        SimResult {
            completions: self.completions,
            injections: self.injections,
            cycles: self.now,
        }
    }

    fn done_count(&self) -> u64 {
        self.next_done
    }

    fn step(&mut self, sink: &mut dyn TraceSink) {
        let now = self.now;
        // Injection policy (evaluated at cycle start).
        if self.injected < self.images {
            let ready = if self.batch {
                // stage 0 has finished (or never had) its previous image.
                self.stages[0].queue.is_empty()
            } else {
                // whole network drained of the previous image.
                self.injected == 0
                    || self.completions[self.injected as usize - 1] != u64::MAX
            };
            if ready {
                let img = self.injected;
                for s in &mut self.stages {
                    s.queue.push_back(img);
                }
                self.injections[img as usize] = now;
                self.injected += 1;
                if sink.enabled() {
                    sink.record(TraceEvent {
                        subsystem: "pipeline",
                        track: self.stages.len() as u64,
                        name: "inject",
                        ts: now,
                        phase: TracePhase::Instant,
                        args: vec![("image", img)],
                    });
                }
            }
        }

        // Stage updates. Stage i reads its predecessors' rings at
        // (now - depth_i); predecessors precede i in topological order and
        // depth >= 1, so this cycle's writes never alias the read slots and
        // in-order iteration is race-free. A merge stage takes the min of
        // its per-edge emittable counts — it waits on the slowest input.
        for i in 0..self.stages.len() {
            let can = {
                let img = match self.stages[i].queue.front() {
                    Some(&img) => img,
                    None => {
                        self.write_ring(i);
                        continue;
                    }
                };
                let plan = &self.stages[i].plan;
                if plan.preds.is_empty() {
                    // Host-fed source: the whole image is present.
                    plan.p_total
                } else {
                    let vt = now.saturating_sub(self.stages[i].depth);
                    let mut can = u64::MAX;
                    for (k, &pi) in plan.preds.iter().enumerate() {
                        let prod = &self.stages[pi];
                        let avail = prod.emitted_at(img, vt);
                        can = can.min(plan.demands[k].emittable(
                            avail,
                            prod.plan.p_total,
                            plan.p_total,
                        ));
                    }
                    can
                }
            };
            let s = &mut self.stages[i];
            if let Some(&img) = s.queue.front() {
                if can > s.emitted {
                    let emit = if let Some(r) = s.rate_int {
                        // Fast path: unthrottled integer rate (no credit).
                        r.min(can - s.emitted)
                    } else {
                        s.credit += s.rate;
                        let burst = s.credit.floor() as u64;
                        let emit = burst.min(can - s.emitted);
                        s.credit -= emit as f64;
                        // Cap credit so idle periods don't bank an
                        // unbounded burst.
                        s.credit = s.credit.min(s.rate.max(1.0));
                        emit
                    };
                    if emit > 0 && s.emitted == 0 {
                        s.start_emit[img as usize] = now;
                    }
                    s.emitted += emit;
                }
                if s.emitted >= s.plan.p_total {
                    s.finish_emit[img as usize] = now;
                    s.queue.pop_front();
                    s.emitted = 0;
                    s.credit = 0.0;
                    if sink.enabled() {
                        // Zero-unit stages (none exist today) would pop
                        // without emitting; fall back to a 1-cycle span.
                        let start = match s.start_emit[img as usize] {
                            u64::MAX => now,
                            t => t,
                        };
                        sink.record(TraceEvent {
                            subsystem: "pipeline",
                            track: i as u64,
                            name: "stage",
                            ts: start,
                            phase: TracePhase::Span {
                                dur: now + 1 - start,
                            },
                            args: vec![("image", img), ("stage", i as u64)],
                        });
                    }
                }
            }
            self.write_ring(i);
        }
        // Image completes when the last stage's tail drains its pipe.
        // Stages process images in order, so completions fill in order.
        let last = self.stages.last().unwrap();
        let last_track = self.stages.len() as u64 - 1;
        while self.next_done < self.images {
            let f = last.finish_emit[self.next_done as usize];
            if f == u64::MAX || f + last.depth > now {
                break;
            }
            self.completions[self.next_done as usize] = f + last.depth;
            if sink.enabled() {
                sink.record(TraceEvent {
                    subsystem: "pipeline",
                    track: last_track,
                    name: "complete",
                    ts: f + last.depth,
                    phase: TracePhase::Instant,
                    args: vec![("image", self.next_done)],
                });
            }
            self.next_done += 1;
        }
        self.now += 1;
    }

    fn write_ring(&mut self, i: usize) {
        let s = &mut self.stages[i];
        let entry = match s.queue.front() {
            Some(&img) => (img, s.emitted),
            None => (u64::MAX, 0),
        };
        s.ring[(self.now % RING as u64) as usize] = entry;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::{NetworkMapping, ReplicationPlan};
    use crate::pipeline::build_plans;

    fn vgg_plans(v: VggVariant, repl: bool) -> Vec<StagePlan> {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(v);
        let plan = if repl {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        build_plans(&net, &m, &arch)
    }

    fn run(v: VggVariant, repl: bool, batch: bool, images: u64) -> SimResult {
        let plans = vgg_plans(v, repl);
        let adj = NocAdjust::identity(plans.len());
        Engine::new(&plans, &adj, batch, images).run()
    }

    #[test]
    fn single_image_latency_exceeds_conv1_stream() {
        let r = run(VggVariant::A, false, false, 1);
        let lat = r.latencies()[0];
        // conv1 streams 50176 cycles; the rest overlaps behind it.
        assert!(lat >= 50176, "latency {lat}");
        assert!(lat < 3 * 50176, "latency {lat} suspiciously large");
    }

    #[test]
    fn steady_interval_none_for_single_image() {
        let one = run(VggVariant::A, false, false, 1);
        assert!(one.steady_interval().is_none(), "1 image has no interval");
        let two = run(VggVariant::A, false, false, 2);
        assert!(two.steady_interval().is_some());
    }

    #[test]
    fn batch_interval_converges_to_max_occupancy() {
        let r = run(VggVariant::E, true, true, 10);
        let interval = r.steady_interval().expect("10 images");
        // Fig. 7 VGG-E: busiest stage 3136 cycles/image.
        assert!(
            (interval - 3136.0).abs() <= 64.0,
            "interval {interval} != ~3136"
        );
    }

    #[test]
    fn batch_pipelining_speedup_is_modest_without_replication() {
        // Fig. 5: geomean (2) vs (1) = 1.0309x.
        let no_batch = run(VggVariant::D, false, false, 8);
        let batch = run(VggVariant::D, false, true, 8);
        let s = no_batch.steady_interval().unwrap() / batch.steady_interval().unwrap();
        assert!((1.0..1.35).contains(&s), "speedup {s}");
    }

    #[test]
    fn replication_speedup_is_order_ten() {
        // Fig. 5: geomean (3) vs (1) = 10.1788x.
        let base = run(VggVariant::E, false, false, 4);
        let repl = run(VggVariant::E, true, false, 4);
        let s = base.steady_interval().unwrap() / repl.steady_interval().unwrap();
        assert!((5.0..20.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn full_pipelining_speedup_near_sixteen() {
        // Paper: "for the best pipelining setup in scenario (4), it achieves
        // a speedup close to 16x".
        let base = run(VggVariant::E, false, false, 4);
        let both = run(VggVariant::E, true, true, 10);
        let s = base.steady_interval().unwrap() / both.steady_interval().unwrap();
        assert!((10.0..20.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn completions_monotone_and_dependencies_hold() {
        let r = run(VggVariant::B, true, true, 6);
        for w in r.completions.windows(2) {
            assert!(w[0] < w[1], "completions not monotone: {:?}", w);
        }
        for (inj, comp) in r.injections.iter().zip(&r.completions) {
            assert!(inj < comp);
        }
    }

    #[test]
    fn rate_throttle_slows_pipeline() {
        let plans = vgg_plans(VggVariant::A, true);
        let n = plans.len();
        let id = NocAdjust::identity(n);
        let fast = Engine::new(&plans, &id, true, 6).run();
        let throttled = NocAdjust {
            extra_depth: vec![2; n],
            rate_scale: vec![0.5; n],
        };
        let slow = Engine::new(&plans, &throttled, true, 6).run();
        assert!(
            slow.steady_interval().unwrap() > 1.5 * fast.steady_interval().unwrap(),
            "throttle had no effect: {} vs {}",
            slow.steady_interval().unwrap(),
            fast.steady_interval().unwrap()
        );
    }
}
