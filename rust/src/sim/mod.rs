//! Cycle-accurate system simulation: the streaming pipeline engine, CNN
//! traffic extraction, and the processing/NoC co-evaluation that produces
//! the paper's benchmark grid.

pub mod engine;
pub mod integrate;
pub mod trace;
pub mod traffic;

pub use engine::{Engine, NocAdjust, SimResult};
pub use integrate::{
    assess_noc, assess_noc_traced, evaluate, evaluate_network, evaluate_network_mapped,
    evaluate_network_mapped_traced, evaluate_traced, NetworkReport, PerfReport,
};
pub use trace::{gantt, windows, windows_from_trace, Window};
pub use traffic::{extract_flows, LayerFlows};
