//! Deterministic seeded arrival processes in simulated cycles.
//!
//! Every generator is a pure function of `(rate, seed)` — wall-clock never
//! enters — so a cluster run replays bit-identically from its seed. The
//! Poisson generator is built on a *unit-rate* exponential stream scaled by
//! `1/rate`: the same seed at a higher offered rate produces the same
//! event stream compressed in time. That construction makes per-request
//! queueing waits monotone in the offered rate (Lindley's recurrence under
//! gap-wise compression), which `tests/prop_cluster.rs` pins.

use crate::util::{Json, Rng};

/// A request arrival process over simulated cycles.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at the offered rate.
    Poisson,
    /// MMPP on-off bursts: exponential sojourns in an ON state arriving at
    /// `(on_mean + off_mean) / on_mean` times the offered rate, and a
    /// silent OFF state — the long-run mean rate equals the offered rate.
    Bursty {
        /// Mean ON-state sojourn in cycles.
        on_mean: u64,
        /// Mean OFF-state sojourn in cycles.
        off_mean: u64,
    },
    /// Diurnal ramp: a non-homogeneous Poisson process whose instantaneous
    /// rate sweeps `offered * (1 + sin(2*pi*t/period))` — peak twice the
    /// offered rate, trough zero — via thinning.
    Diurnal {
        /// Cycles per full ramp period.
        period: u64,
    },
    /// Replay explicit arrival cycles (e.g. from a recorded trace file).
    Trace(Vec<u64>),
}

impl ArrivalProcess {
    /// Resolve a CLI pattern name with this module's default parameters.
    pub fn from_name(name: &str) -> Result<Self, String> {
        match name {
            "poisson" => Ok(Self::Poisson),
            "bursty" => Ok(Self::Bursty {
                on_mean: 20_000,
                off_mean: 20_000,
            }),
            "diurnal" => Ok(Self::Diurnal { period: 1_000_000 }),
            other => Err(format!(
                "unknown arrival pattern {other:?} \
                 (poisson | bursty | diurnal | trace via --trace FILE)"
            )),
        }
    }

    /// Load a trace: a JSON array of arrival cycles, or an object with an
    /// `arrivals_cycles` array. Cycles are sorted if needed.
    pub fn from_trace_json(doc: &Json) -> Result<Self, String> {
        let arr = doc
            .as_arr()
            .or_else(|| doc.get("arrivals_cycles").and_then(Json::as_arr))
            .ok_or_else(|| {
                "trace must be a JSON array of cycles or {\"arrivals_cycles\": [...]}"
                    .to_string()
            })?;
        let mut cycles = Vec::with_capacity(arr.len());
        for (i, v) in arr.iter().enumerate() {
            let x = v
                .as_f64()
                .ok_or_else(|| format!("trace entry {i} is not a number"))?;
            if !x.is_finite() || x < 0.0 {
                return Err(format!("trace entry {i} ({x}) is not a valid cycle"));
            }
            cycles.push(x as u64);
        }
        cycles.sort_unstable();
        Ok(Self::Trace(cycles))
    }

    /// Load a trace file from disk (see [`Self::from_trace_json`]).
    pub fn from_trace_file(path: &str) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading trace {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("parsing trace {path}: {e}"))?;
        Self::from_trace_json(&doc)
    }

    /// Pattern name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Bursty { .. } => "bursty",
            Self::Diurnal { .. } => "diurnal",
            Self::Trace(_) => "trace",
        }
    }

    /// Arrival cycles in `[0, horizon)` at `rate` requests/cycle, sorted
    /// non-decreasing. `rate` must be positive for the synthetic processes
    /// (a trace ignores it).
    ///
    /// This is the *materializing reference*: the event loop itself pulls
    /// from [`Self::stream_horizon`], and `tests/prop_cluster_perf.rs`
    /// pins that both produce identical per-seed streams.
    pub fn generate(&self, rate: f64, horizon: u64, seed: u64) -> Vec<u64> {
        match self {
            Self::Trace(cycles) => cycles.iter().copied().filter(|&c| c < horizon).collect(),
            _ => self.stream(rate, seed, Limit::Horizon(horizon)),
        }
    }

    /// The first `n` arrival cycles at `rate` requests/cycle (a trace
    /// yields its first `n` entries). Used by fixed-population experiments
    /// — the monotonicity properties compare equal request counts.
    pub fn generate_n(&self, rate: f64, n: usize, seed: u64) -> Vec<u64> {
        match self {
            Self::Trace(cycles) => cycles.iter().copied().take(n).collect(),
            _ => self.stream(rate, seed, Limit::Count(n)),
        }
    }

    /// Pull-based equivalent of [`Self::generate`]: an iterator yielding
    /// the *same per-seed arrival cycles* one event at a time, so a
    /// consumer (the cluster calendar) holds O(1) arrival state no matter
    /// how long the horizon is. Traces borrow their materialized `Vec`.
    pub fn stream_horizon(&self, rate: f64, horizon: u64, seed: u64) -> ArrivalStream<'_> {
        ArrivalStream::new(self, rate, seed, Limit::Horizon(horizon))
    }

    /// Pull-based equivalent of [`Self::generate_n`]: yields exactly the
    /// first `n` per-seed arrival cycles, one at a time.
    pub fn stream_n(&self, rate: f64, n: usize, seed: u64) -> ArrivalStream<'_> {
        ArrivalStream::new(self, rate, seed, Limit::Count(n))
    }

    fn stream(&self, rate: f64, seed: u64, limit: Limit) -> Vec<u64> {
        assert!(
            rate > 0.0 && rate.is_finite(),
            "synthetic arrivals need a positive rate, got {rate}"
        );
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        match *self {
            Self::Poisson => {
                // Unit-rate exponential stream, scaled: t_k = S_k / rate.
                let mut unit_t = 0.0f64;
                while limit.wants_more(&out, unit_t / rate) {
                    unit_t += exp1(&mut rng);
                    out.push((unit_t / rate) as u64);
                }
                limit.trim(&mut out);
            }
            Self::Bursty { on_mean, off_mean } => {
                let duty = on_mean as f64 / (on_mean + off_mean) as f64;
                let on_rate = rate / duty;
                let mut t = 0.0f64; // current cycle (f64 for sub-cycle gaps)
                let mut on = true; // start bursting: tests see arrivals early
                let mut window_end = exp_mean(&mut rng, on_mean as f64);
                while limit.wants_more(&out, t) {
                    if on {
                        let gap = exp1(&mut rng) / on_rate;
                        if t + gap < window_end {
                            t += gap;
                            out.push(t as u64);
                            continue;
                        }
                    }
                    // Sojourn exhausted (or OFF): hop to the next window.
                    t = window_end;
                    on = !on;
                    let mean = (if on { on_mean } else { off_mean }) as f64;
                    window_end = t + exp_mean(&mut rng, mean);
                }
                limit.trim(&mut out);
            }
            Self::Diurnal { period } => {
                // Thinning against the peak rate 2*rate.
                let peak = 2.0 * rate;
                let w = std::f64::consts::TAU / period as f64;
                let mut t = 0.0f64;
                while limit.wants_more(&out, t) {
                    t += exp1(&mut rng) / peak;
                    let accept = 0.5 * (1.0 + (w * t).sin()); // rate(t)/peak
                    if rng.chance(accept) {
                        out.push(t as u64);
                    }
                }
                limit.trim(&mut out);
            }
            Self::Trace(_) => unreachable!("traces do not stream"),
        }
        out
    }
}

/// Stop condition for streaming generators.
#[derive(Debug, Clone, Copy)]
enum Limit {
    Horizon(u64),
    Count(usize),
}

impl Limit {
    /// Should the generator keep producing, given the events so far and the
    /// current (pre-push) simulated time?
    fn wants_more(&self, out: &[u64], t: f64) -> bool {
        match *self {
            Limit::Horizon(h) => t < h as f64,
            Limit::Count(n) => out.len() < n,
        }
    }

    /// Drop any overshoot past the stop condition (the last pushed event
    /// may land beyond a horizon).
    fn trim(&self, out: &mut Vec<u64>) {
        if let Limit::Horizon(h) = *self {
            while out.last().is_some_and(|&c| c >= h) {
                out.pop();
            }
        }
    }
}

/// A pull-based arrival generator: yields the same per-seed arrival cycles
/// as [`ArrivalProcess::generate`] / [`ArrivalProcess::generate_n`], one
/// event at a time. The cluster event loop holds exactly one of these plus
/// one pending `Arrival` calendar entry, so arrival memory is O(1) in the
/// horizon and request count (a [`ArrivalProcess::Trace`] borrows its
/// already-materialized cycles instead of copying them).
///
/// Equivalence to the materializing reference is pinned per pattern by the
/// `stream_matches_generate_*` tests below and re-checked at the stats
/// level by `tests/prop_cluster_perf.rs`.
#[derive(Debug)]
pub struct ArrivalStream<'a> {
    inner: StreamInner<'a>,
    limit: Limit,
    yielded: usize,
}

#[derive(Debug)]
enum StreamInner<'a> {
    /// Unit-rate exponential stream scaled by `1/rate`; `unit_t` is the
    /// running unit-time sum S_k.
    Poisson { rng: Rng, rate: f64, unit_t: f64 },
    /// MMPP on-off windows, mid-sojourn state carried across pulls.
    Bursty {
        rng: Rng,
        on_rate: f64,
        on_mean: f64,
        off_mean: f64,
        t: f64,
        on: bool,
        window_end: f64,
    },
    /// Thinned non-homogeneous Poisson against the peak rate.
    Diurnal { rng: Rng, peak: f64, w: f64, t: f64 },
    /// Borrowed trace replay.
    Trace { cycles: &'a [u64], pos: usize },
}

impl<'a> ArrivalStream<'a> {
    fn new(process: &'a ArrivalProcess, rate: f64, seed: u64, limit: Limit) -> Self {
        if !matches!(process, ArrivalProcess::Trace(_)) {
            assert!(
                rate > 0.0 && rate.is_finite(),
                "synthetic arrivals need a positive rate, got {rate}"
            );
        }
        let mut rng = Rng::new(seed);
        let inner = match *process {
            ArrivalProcess::Poisson => StreamInner::Poisson {
                rng,
                rate,
                unit_t: 0.0,
            },
            ArrivalProcess::Bursty { on_mean, off_mean } => {
                let duty = on_mean as f64 / (on_mean + off_mean) as f64;
                let window_end = exp_mean(&mut rng, on_mean as f64);
                StreamInner::Bursty {
                    rng,
                    on_rate: rate / duty,
                    on_mean: on_mean as f64,
                    off_mean: off_mean as f64,
                    t: 0.0,
                    on: true, // start bursting, matching `generate`
                    window_end,
                }
            }
            ArrivalProcess::Diurnal { period } => StreamInner::Diurnal {
                rng,
                peak: 2.0 * rate,
                w: std::f64::consts::TAU / period as f64,
                t: 0.0,
            },
            ArrivalProcess::Trace(ref cycles) => StreamInner::Trace { cycles, pos: 0 },
        };
        Self {
            inner,
            limit,
            yielded: 0,
        }
    }
}

impl Iterator for ArrivalStream<'_> {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if let Limit::Count(n) = self.limit {
            if self.yielded >= n {
                return None;
            }
        }
        // Real-valued cutoff for the synthetic processes. `generate` keeps
        // exactly the events whose real-valued time t satisfies
        // `t < horizon as f64` (the one possible overshoot it pushes is
        // popped again by `Limit::trim`), so stopping at the first t past
        // the cutoff reproduces its output bit for bit.
        let cut = match self.limit {
            Limit::Horizon(h) => h as f64,
            Limit::Count(_) => f64::INFINITY,
        };
        let cycle = match &mut self.inner {
            StreamInner::Poisson { rng, rate, unit_t } => {
                *unit_t += exp1(rng);
                let t = *unit_t / *rate;
                if t >= cut {
                    return None;
                }
                t as u64
            }
            StreamInner::Bursty {
                rng,
                on_rate,
                on_mean,
                off_mean,
                t,
                on,
                window_end,
            } => loop {
                if *t >= cut {
                    return None;
                }
                if *on {
                    let gap = exp1(rng) / *on_rate;
                    if *t + gap < *window_end {
                        *t += gap;
                        if *t >= cut {
                            return None;
                        }
                        break *t as u64;
                    }
                }
                // Sojourn exhausted (or OFF): hop to the next window.
                *t = *window_end;
                *on = !*on;
                let mean = if *on { *on_mean } else { *off_mean };
                *window_end = *t + exp_mean(rng, mean);
            },
            StreamInner::Diurnal { rng, peak, w, t } => loop {
                *t += exp1(rng) / *peak;
                if *t >= cut {
                    return None;
                }
                let accept = 0.5 * (1.0 + (*w * *t).sin());
                if rng.chance(accept) {
                    break *t as u64;
                }
            },
            StreamInner::Trace { cycles, pos } => loop {
                let &c = cycles.get(*pos)?;
                *pos += 1;
                // Filter (not take_while): `generate` filters, and raw
                // traces are only sorted by contract, not by construction.
                match self.limit {
                    Limit::Horizon(h) if c >= h => continue,
                    _ => break c,
                }
            },
        };
        self.yielded += 1;
        Some(cycle)
    }
}

/// Salt folded into the run seed for the tenant-label RNG: labels draw
/// from their own generator, so adding or removing tenants never perturbs
/// the arrival *timing* stream — the same seed keeps the same cycles.
pub const LABEL_SALT: u64 = 0x7E4A_B1E5_5EED_0001;

/// How arrivals are labeled with tenants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MixMode {
    /// Time-invariant categorical draw over the tenant weights.
    Static,
    /// Phase-shifted diurnal weights: tenant `i` of `T` sees its base
    /// weight scaled by `1 + sin(2*pi*(cycle mod period)/period -
    /// 2*pi*i/T)` — tenants peak at staggered phases (anti-phase for
    /// two), which is what makes reprogram-on-miss swap storms
    /// reproducible on demand.
    Diurnal {
        /// Cycles per full mix period.
        period: u64,
    },
    /// Deterministic round-robin over tenants in arrival order (no RNG
    /// draw) — the two-tenant worst case for residency, and the exactly
    /// checkable golden-trace labeling.
    Alternate,
}

impl MixMode {
    /// Resolve a CLI mix name; `period` parameterizes the diurnal mode.
    pub fn from_name(name: &str, period: u64) -> Result<Self, String> {
        match name {
            "static" => Ok(Self::Static),
            "diurnal" => Ok(Self::Diurnal { period }),
            "alternate" => Ok(Self::Alternate),
            other => Err(format!(
                "unknown tenant mix {other:?} (static | diurnal | alternate)"
            )),
        }
    }

    /// Mix name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Diurnal { .. } => "diurnal",
            Self::Alternate => "alternate",
        }
    }
}

/// Seeded tenant labeler: one label per arrival, in arrival order. A pure
/// function of `(weights, mode, seed)` — labeling replays bit-identically
/// with the run, independently of the timing draws (see [`LABEL_SALT`]).
#[derive(Debug)]
pub struct TenantMix {
    weights: Vec<f64>,
    mode: MixMode,
    rng: Rng,
    count: u64,
    /// Per-sample modulated weights (reused across draws).
    scratch: Vec<f64>,
}

impl TenantMix {
    /// Build a labeler over positive tenant `weights` from the *run* seed
    /// (salted internally).
    pub fn new(weights: Vec<f64>, mode: MixMode, seed: u64) -> Self {
        assert!(
            !weights.is_empty() && weights.iter().all(|&w| w > 0.0 && w.is_finite()),
            "tenant weights must be positive and finite: {weights:?}"
        );
        if let MixMode::Diurnal { period } = mode {
            assert!(period > 0, "diurnal mix needs a positive period");
        }
        Self {
            weights,
            mode,
            rng: Rng::new(seed ^ LABEL_SALT),
            count: 0,
            scratch: Vec::new(),
        }
    }

    /// Tenants in the mix.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    /// True for an empty mix (never constructible; for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// Label one arrival at `cycle`. Consumes exactly one uniform draw in
    /// the categorical modes and none under [`MixMode::Alternate`].
    pub fn sample(&mut self, cycle: u64) -> usize {
        let t = self.weights.len();
        let n = self.count;
        self.count += 1;
        if matches!(self.mode, MixMode::Alternate) {
            return (n % t as u64) as usize;
        }
        self.scratch.clear();
        match self.mode {
            MixMode::Diurnal { period } => {
                let frac = (cycle % period) as f64 / period as f64;
                for (i, &w) in self.weights.iter().enumerate() {
                    let phase = std::f64::consts::TAU * frac
                        - std::f64::consts::TAU * i as f64 / t as f64;
                    self.scratch.push(w * (1.0 + phase.sin()));
                }
                // A trough can zero every modulated weight (two tenants in
                // exact anti-phase at sin = -1); fall back to base weights
                // rather than divide by zero.
                if self.scratch.iter().sum::<f64>() <= 0.0 {
                    self.scratch.clear();
                    self.scratch.extend_from_slice(&self.weights);
                }
            }
            _ => self.scratch.extend_from_slice(&self.weights),
        }
        let u = self.rng.next_f64();
        let mut x = u * self.scratch.iter().sum::<f64>();
        for (i, &w) in self.scratch.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        t - 1
    }
}

/// An [`ArrivalStream`] with a tenant label attached to every event:
/// yields `(cycle, tenant)` in arrival order. This is what the
/// multi-tenant event loop ([`crate::cluster::tenant`]) pulls from.
#[derive(Debug)]
pub struct LabeledArrivals<'a> {
    stream: ArrivalStream<'a>,
    mix: TenantMix,
}

impl<'a> LabeledArrivals<'a> {
    /// Attach a labeler to a timing stream.
    pub fn new(stream: ArrivalStream<'a>, mix: TenantMix) -> Self {
        Self { stream, mix }
    }
}

impl Iterator for LabeledArrivals<'_> {
    type Item = (u64, usize);

    fn next(&mut self) -> Option<(u64, usize)> {
        let cycle = self.stream.next()?;
        let tenant = self.mix.sample(cycle);
        Some((cycle, tenant))
    }
}

/// Exponential(1) variate (inverse CDF on a (0, 1] uniform).
fn exp1(rng: &mut Rng) -> f64 {
    -(1.0 - rng.next_f64()).ln()
}

/// Exponential variate with the given mean.
fn exp_mean(rng: &mut Rng, mean: f64) -> f64 {
    mean * exp1(rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let a = ArrivalProcess::Poisson.generate(0.01, 1_000_000, 42);
        // Expect ~10000 arrivals; allow generous 5% slack.
        assert!((9_500..10_500).contains(&a.len()), "{}", a.len());
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "not sorted");
        assert!(a.iter().all(|&c| c < 1_000_000));
    }

    #[test]
    fn poisson_same_seed_same_stream() {
        let a = ArrivalProcess::Poisson.generate(0.001, 500_000, 7);
        let b = ArrivalProcess::Poisson.generate(0.001, 500_000, 7);
        assert_eq!(a, b);
        let c = ArrivalProcess::Poisson.generate(0.001, 500_000, 8);
        assert_ne!(a, c, "different seed must differ");
    }

    #[test]
    fn poisson_higher_rate_compresses_the_same_stream() {
        // The monotonicity keystone: t_k(rate) = S_k / rate with the SAME
        // unit stream S, so doubling the rate exactly halves every time.
        let lo = ArrivalProcess::Poisson.generate_n(0.001, 500, 3);
        let hi = ArrivalProcess::Poisson.generate_n(0.002, 500, 3);
        assert_eq!(lo.len(), hi.len());
        for (&l, &h) in lo.iter().zip(&hi) {
            assert!(h <= l, "compression violated: {h} > {l}");
            // Integer truncation of an exact halving.
            assert!(h >= l / 2, "{h} < {l}/2");
        }
    }

    #[test]
    fn generate_n_yields_exactly_n() {
        for p in [
            ArrivalProcess::Poisson,
            ArrivalProcess::from_name("bursty").unwrap(),
            ArrivalProcess::from_name("diurnal").unwrap(),
        ] {
            let a = p.generate_n(0.01, 137, 11);
            assert_eq!(a.len(), 137, "{}", p.name());
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{}", p.name());
        }
    }

    #[test]
    fn bursty_long_run_rate_matches_offered() {
        let p = ArrivalProcess::Bursty {
            on_mean: 10_000,
            off_mean: 10_000,
        };
        let a = p.generate(0.01, 4_000_000, 5);
        let measured = a.len() as f64 / 4_000_000.0;
        assert!(
            (measured - 0.01).abs() < 0.002,
            "long-run rate {measured} != 0.01"
        );
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Index of dispersion of counts in fixed windows: MMPP > Poisson.
        let windows = |a: &[u64]| -> f64 {
            let mut counts = vec![0f64; 100];
            for &c in a {
                counts[(c / 10_000).min(99) as usize] += 1.0;
            }
            let m = crate::util::stats::mean(&counts);
            let v = crate::util::stats::stddev(&counts).powi(2);
            v / m
        };
        let pois = ArrivalProcess::Poisson.generate(0.01, 1_000_000, 9);
        let burst = ArrivalProcess::from_name("bursty").unwrap().generate(0.01, 1_000_000, 9);
        assert!(
            windows(&burst) > 2.0 * windows(&pois),
            "bursty dispersion {} vs poisson {}",
            windows(&burst),
            windows(&pois)
        );
    }

    #[test]
    fn diurnal_peaks_and_troughs() {
        let p = ArrivalProcess::Diurnal { period: 1_000_000 };
        let a = p.generate(0.01, 1_000_000, 13);
        // First half-period carries the sin>0 crest, second the trough.
        let first = a.iter().filter(|&&c| c < 500_000).count();
        let second = a.len() - first;
        assert!(
            first > 2 * second,
            "ramp not visible: {first} vs {second}"
        );
    }

    #[test]
    fn trace_replay_filters_and_sorts() {
        let doc = Json::parse("[30, 10, 20, 99]").unwrap();
        let p = ArrivalProcess::from_trace_json(&doc).unwrap();
        assert_eq!(p.generate(1.0, 50, 0), vec![10, 20, 30]);
        assert_eq!(p.generate_n(1.0, 2, 0), vec![10, 20]);
        assert_eq!(p.name(), "trace");
    }

    #[test]
    fn trace_object_form_and_errors() {
        let doc = Json::parse(r#"{"arrivals_cycles": [5, 6]}"#).unwrap();
        assert_eq!(
            ArrivalProcess::from_trace_json(&doc).unwrap(),
            ArrivalProcess::Trace(vec![5, 6])
        );
        for bad in ["{\"x\": 1}", "[1, \"two\"]", "[-4]", "3"] {
            let doc = Json::parse(bad).unwrap();
            assert!(
                ArrivalProcess::from_trace_json(&doc).is_err(),
                "{bad} should be rejected"
            );
        }
    }

    #[test]
    fn stream_matches_generate_for_every_pattern() {
        // The event loop pulls from the stream; the materializing
        // reference defines the contract. Pin equality across patterns,
        // rates, horizons and seeds.
        let patterns = [
            ArrivalProcess::Poisson,
            ArrivalProcess::from_name("bursty").unwrap(),
            ArrivalProcess::from_name("diurnal").unwrap(),
            ArrivalProcess::Trace(vec![3, 3, 40, 41, 500, 70_000, 900_000]),
        ];
        for p in &patterns {
            for seed in [0u64, 7, 0xDEAD_BEEF] {
                for (rate, horizon) in
                    [(0.01, 0u64), (0.01, 1), (0.003, 250_000), (1.7, 4_096)]
                {
                    let vec = p.generate(rate, horizon, seed);
                    let streamed: Vec<u64> =
                        p.stream_horizon(rate, horizon, seed).collect();
                    assert_eq!(
                        streamed,
                        vec,
                        "{} rate={rate} horizon={horizon} seed={seed}",
                        p.name()
                    );
                }
            }
        }
    }

    #[test]
    fn stream_n_matches_generate_n_for_every_pattern() {
        let patterns = [
            ArrivalProcess::Poisson,
            ArrivalProcess::from_name("bursty").unwrap(),
            ArrivalProcess::from_name("diurnal").unwrap(),
            ArrivalProcess::Trace((0..300).map(|i| i * 17).collect()),
        ];
        for p in &patterns {
            for seed in [1u64, 99] {
                for n in [0usize, 1, 137, 1_000] {
                    let vec = p.generate_n(0.02, n, seed);
                    let streamed: Vec<u64> = p.stream_n(0.02, n, seed).collect();
                    assert_eq!(streamed, vec, "{} n={n} seed={seed}", p.name());
                }
            }
        }
    }

    #[test]
    fn stream_state_is_a_few_machine_words() {
        // The point of streaming: arrival state held by the event loop is
        // constant in the horizon — the enum fits in a cacheline or two,
        // versus 8 bytes *per arrival* for the materialized Vec.
        assert!(
            std::mem::size_of::<ArrivalStream<'static>>() <= 128,
            "stream state grew to {} bytes",
            std::mem::size_of::<ArrivalStream<'static>>()
        );
    }

    #[test]
    fn alternate_mix_round_robins_without_rng() {
        let mut m = TenantMix::new(vec![1.0, 5.0, 2.0], MixMode::Alternate, 9);
        let labels: Vec<usize> = (0..7).map(|c| m.sample(c * 100)).collect();
        assert_eq!(labels, vec![0, 1, 2, 0, 1, 2, 0], "weights are ignored");
    }

    #[test]
    fn static_mix_respects_weights() {
        let mut m = TenantMix::new(vec![3.0, 1.0], MixMode::Static, 4);
        let n = 10_000;
        let zeros = (0..n).filter(|&c| m.sample(c) == 0).count();
        // Expect ~75%; generous 3-sigma slack.
        assert!((7_200..7_800).contains(&zeros), "{zeros}");
    }

    #[test]
    fn mix_is_deterministic_per_seed_and_salted() {
        let labels = |seed: u64| -> Vec<usize> {
            let mut m = TenantMix::new(vec![1.0, 1.0, 1.0], MixMode::Static, seed);
            (0..200).map(|c| m.sample(c)).collect()
        };
        assert_eq!(labels(7), labels(7));
        assert_ne!(labels(7), labels(8));
        // The salt decorrelates labels from timing: an unsalted Rng at the
        // same seed draws a different uniform stream.
        let mut raw = Rng::new(7);
        let mut salted = Rng::new(7 ^ LABEL_SALT);
        assert_ne!(raw.next_f64(), salted.next_f64());
    }

    #[test]
    fn diurnal_mix_peaks_in_anti_phase() {
        // Two tenants: at a quarter period tenant 0's modulated weight is
        // 2w and tenant 1's is exactly 0 (sin = ±1), and vice versa at
        // three quarters.
        let period = 1_000_000u64;
        let mut m = TenantMix::new(vec![1.0, 1.0], MixMode::Diurnal { period }, 3);
        for _ in 0..50 {
            assert_eq!(m.sample(period / 4), 0);
        }
        for _ in 0..50 {
            assert_eq!(m.sample(3 * period / 4), 1);
        }
    }

    #[test]
    fn labeled_arrivals_ride_the_timing_stream() {
        // Labels attach 1:1 to the unlabeled stream's cycles; alternate
        // labeling is exactly checkable.
        let p = ArrivalProcess::Trace(vec![5, 6, 40]);
        let plain: Vec<u64> = p.stream_horizon(1.0, 100, 2).collect();
        let labeled: Vec<(u64, usize)> = LabeledArrivals::new(
            p.stream_horizon(1.0, 100, 2),
            TenantMix::new(vec![1.0, 1.0], MixMode::Alternate, 2),
        )
        .collect();
        assert_eq!(
            labeled,
            plain
                .iter()
                .enumerate()
                .map(|(i, &c)| (c, i % 2))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn from_name_resolves() {
        assert_eq!(
            ArrivalProcess::from_name("poisson").unwrap(),
            ArrivalProcess::Poisson
        );
        assert!(ArrivalProcess::from_name("storm").is_err());
    }
}
