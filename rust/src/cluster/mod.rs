//! Cluster-scale serving simulator: trace-driven multi-node inference
//! with SLO metrics and capacity planning.
//!
//! The single-node stack models what happens *after* a request reaches a
//! PIM pipeline — replication plans, batch pipelining, the 3136-cycle
//! VGG-E beat. This layer models everything between request arrival and
//! pipeline injection across a fleet of node replicas, in virtual time:
//!
//! - [`arrival`] — deterministic seeded arrival processes (Poisson,
//!   bursty MMPP, diurnal ramp, JSON trace replay) in simulated cycles,
//!   consumed through the pull-based [`ArrivalStream`] so arrival memory
//!   is O(1) in the horizon;
//! - [`node`] — one replica: queue + the real [`BatchPolicy`]
//!   (virtual ticks) + the pipeline-slot [`Dispatcher`] from the node's
//!   replication plan, so per-request latency = queueing + backlog + fill;
//! - [`sim`] — the binary-heap event loop over N nodes with pluggable
//!   routing (round-robin / join-shortest-queue / least-work) and
//!   admission control (max outstanding per node, rejections counted
//!   against the SLO). Routing runs on incremental indexes by default
//!   ([`RouteImpl`]; the O(N) scan survives as the bit-identical
//!   reference) and deadline suppression keeps the calendar at
//!   O(fleet + in-flight batches), so 10k-node fleets stream millions of
//!   requests in seconds — see DESIGN.md §4a and
//!   `benches/cluster_scale.rs`;
//! - [`stats`] — exact p50/p95/p99/p999 latency, throughput, per-node
//!   utilization, rejection rate;
//! - [`capacity`] — "minimum nodes such that p99 <= target at this QPS",
//!   by parallel section search over fleet size on [`SweepRunner`],
//!   optionally gated by an average-fleet-power budget;
//! - [`tenant`] — multi-tenant serving over the same fleet: per-node
//!   resident-model state, residency policies (reprogram-on-miss vs
//!   dedicated-partition), tenant-labeled arrivals
//!   ([`arrival::TenantMix`]), and ReRAM weight-programming costs
//!   ([`crate::power::WriteCost`]) charged per model swap into
//!   [`FleetEnergy::weight_writes_j`].
//!
//! Fleet energy rides along (DESIGN.md §5): every [`NodeModel`] built
//! from a workload carries an [`EnergyProfile`] (one injection = one
//! image's dynamic energy; an allocated replica burns the node idle
//! floor while its bottleneck is not streaming), and every run reports
//! [`FleetEnergy`] — joules per image, average watts, fleet TOPS/W,
//! padding waste — in [`ClusterStats`] and its JSON form.
//!
//! Everything is deterministic from the seed; `smart-pim cluster` is the
//! CLI surface and `benches/cluster_scale.rs` writes `BENCH_cluster.json`.
//!
//! [`BatchPolicy`]: crate::coordinator::BatchPolicy
//! [`Dispatcher`]: crate::coordinator::Dispatcher
//! [`SweepRunner`]: crate::sweep::SweepRunner

pub mod arrival;
pub mod capacity;
pub mod node;
pub mod sim;
pub mod stats;
pub mod tenant;

pub use arrival::{ArrivalProcess, ArrivalStream, LabeledArrivals, MixMode, TenantMix};
pub use capacity::{
    plan_capacity, tenant_capacity_ladder, CapacityPoint, CapacityReport, TenantCapacityPoint,
};
pub use node::{EnergyProfile, Node, NodeModel, Served, TenantNode};
pub use sim::{
    cycle_policy, rate_from_qps, simulate, simulate_with_sink, ClusterConfig, RouteImpl,
    RoutePolicy,
};
pub use stats::{ClusterStats, FleetEnergy, LatencySummary, EXACT_SAMPLE_CAP};
pub use tenant::{
    partition_counts, simulate_tenants, simulate_tenants_with_sink, Residency,
    TenantClusterStats, TenantConfig, TenantRoute, TenantStats, TenantWorkload,
};
