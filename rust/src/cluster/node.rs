//! One node replica of the serving fleet: a request queue, the *same*
//! [`BatchPolicy`] the real server runs (virtual ticks = cycles after the
//! Clock refactor), and the pipeline-slot [`Dispatcher`] built from the
//! node's replication plan — so per-request latency decomposes into
//! queueing (arrival -> batch formation), pipeline backlog (formation ->
//! injection) and the batch-pipelined fill (injection -> completion), all
//! in the validated single-node cycle model.

use std::collections::VecDeque;

use crate::cnn::Network;
use crate::config::ArchConfig;
use crate::coordinator::{BatchPolicy, Dispatcher, PipelineShape, Request};
use crate::mapping::{MappingSelection, NetworkMapping, Placement, ReplicationPlan};
use crate::pipeline::build_plans;
use crate::power::{components::aggregates, EnergyModel};
use crate::sim::extract_flows;

/// The static energy parameters of one fleet replica, derived from the
/// same mapping/placement/traffic chain the single-node energy model uses
/// (DESIGN.md §5): an allocated replica burns the always-on node idle
/// floor (eDRAM buffers + routers never power-gate) over its whole
/// lifetime, and every pipeline injection — real or padding — adds one
/// image's dynamic energy on top.
#[derive(Debug, Clone, Copy)]
pub struct EnergyProfile {
    /// Dynamic energy of one pipeline injection in millijoules
    /// ([`EnergyModel::image_energy`] over the replica's mapping, with
    /// fan-out-aware `copy_hops` weights).
    pub image_mj: f64,
    /// Incremental power above the idle floor while the bottleneck stage
    /// streams (W): `image_mj / (interval x logical cycle)`. By
    /// construction, utilization x active power x span == injections x
    /// image energy.
    pub active_power_w: f64,
    /// Always-on idle floor (W) — [`aggregates::NODE_IDLE_POWER_MW`] —
    /// burned over the full span regardless of traffic.
    pub idle_power_w: f64,
    /// Crossbar operations one completed image represents (`Network::ops`).
    pub ops_per_image: u64,
    /// Logical cycle duration in ns (converts spans to wall seconds).
    pub logical_cycle_ns: f64,
}

/// The static per-replica pipeline model every node of a (homogeneous)
/// fleet shares: the dispatcher shape plus its two defining constants.
#[derive(Debug, Clone)]
pub struct NodeModel {
    /// Stage offsets/occupancy for the dispatcher.
    pub shape: PipelineShape,
    /// Hazard-free injection interval in cycles (`shape.min_interval()`).
    pub interval: u64,
    /// Injection-to-completion cycles for one image (pipeline fill).
    pub fill: u64,
    /// Energy parameters of one replica; present when the model was built
    /// from a real workload ([`Self::from_workload`]), absent for a bare
    /// shape ([`Self::new`]) which has no network to price.
    pub energy: Option<EnergyProfile>,
}

impl NodeModel {
    /// Wrap a dispatcher shape (no workload attached, so no energy model).
    pub fn new(shape: PipelineShape) -> Self {
        let interval = shape.min_interval();
        let last = shape.n_layers() - 1;
        let fill = shape.offsets[last] + shape.occupancy[last];
        Self {
            shape,
            interval,
            fill,
            energy: None,
        }
    }

    /// Build from a workload + replication plan on `arch` (the same
    /// mapping -> stage-plan -> shape chain `smart-pim serve` uses),
    /// including the replica's [`EnergyProfile`].
    pub fn from_workload(
        net: &Network,
        arch: &ArchConfig,
        plan: &ReplicationPlan,
    ) -> Result<Self, String> {
        Self::from_workload_mapped(net, arch, plan, &MappingSelection::im2col(net.len()))
    }

    /// [`Self::from_workload`] under a per-layer mapping selection — the
    /// whole replica model (shape, interval, fill, energy profile) is
    /// derived from the selected packing, so a VW-SDK fleet is priced end
    /// to end under VW-SDK.
    pub fn from_workload_mapped(
        net: &Network,
        arch: &ArchConfig,
        plan: &ReplicationPlan,
        selection: &MappingSelection,
    ) -> Result<Self, String> {
        let mapping = NetworkMapping::build_with(net, arch, plan, selection)?;
        let plans = build_plans(net, &mapping, arch);
        let shape = PipelineShape::from_plans(&plans);
        let mut model = Self::new(shape);
        // Price one injection through the single-node energy model: snake
        // placement, fan-out-aware copy_hops, DAG-aware per-layer energy.
        let placement = Placement::snake(arch);
        let flows = extract_flows(net, &mapping, &placement, &plans, arch);
        let hops: Vec<f64> = flows.iter().map(|l| l.copy_hops).collect();
        let em = EnergyModel::new(arch);
        let image_mj = em.image_energy(net, &mapping, &hops).total_mj();
        let interval_s = model.interval as f64 * arch.logical_cycle_ns * 1e-9;
        model.energy = Some(EnergyProfile {
            image_mj,
            active_power_w: image_mj * 1e-3 / interval_s,
            idle_power_w: aggregates::NODE_IDLE_POWER_MW / 1000.0,
            ops_per_image: net.ops(),
            logical_cycle_ns: arch.logical_cycle_ns,
        });
        Ok(model)
    }

    /// Steady-state capacity in requests per cycle (one image per
    /// `interval`), before batching fill effects.
    pub fn capacity_per_cycle(&self) -> f64 {
        1.0 / self.interval as f64
    }
}

/// One request served to completion (the node's answer to the event loop).
#[derive(Debug, Clone, Copy)]
pub struct Served {
    /// Request id.
    pub id: u64,
    /// Arrival cycle at the cluster.
    pub arrived: u64,
    /// Pipeline injection cycle (>= formation cycle; the gap is backlog).
    pub injected: u64,
    /// Pipeline completion cycle (`injected + fill`).
    pub completed: u64,
}

/// Mutable per-node simulation state.
#[derive(Debug)]
pub struct Node {
    interval: u64,
    policy: BatchPolicy,
    dispatcher: Dispatcher,
    queue: VecDeque<Request>,
    /// Outstanding requests: queued + admitted-but-not-completed.
    in_flight: u64,
    /// Real requests completed.
    pub completed: u64,
    /// Requests this node's admission control rejected.
    pub rejected: u64,
    /// Total pipeline injections (real + padding) for utilization.
    pub injected: u64,
}

impl Node {
    /// A fresh node running `policy` over `model`'s pipeline. The
    /// dispatcher runs untracked (O(1) memory per node regardless of
    /// horizon); use [`Self::with_hazard_log`] to audit the schedule.
    pub fn new(model: &NodeModel, policy: BatchPolicy) -> Self {
        Self::build(model, policy, false)
    }

    /// A node whose dispatcher logs every injection beat so
    /// [`Self::verify_no_hazard`] can audit the full schedule (tests).
    pub fn with_hazard_log(model: &NodeModel, policy: BatchPolicy) -> Self {
        Self::build(model, policy, true)
    }

    fn build(model: &NodeModel, policy: BatchPolicy, log: bool) -> Self {
        let shape = model.shape.clone();
        Self {
            interval: model.interval,
            policy,
            dispatcher: if log {
                Dispatcher::new(shape)
            } else {
                Dispatcher::untracked(shape)
            },
            queue: VecDeque::new(),
            in_flight: 0,
            completed: 0,
            rejected: 0,
            injected: 0,
        }
    }

    /// Outstanding requests (queued + in the pipeline) — the
    /// join-shortest-queue routing signal and the admission-control gauge.
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Pending work in cycles at `now`: the pipeline's backlog horizon
    /// plus the unformed queue priced at one interval each — the
    /// least-work routing signal.
    pub fn backlog(&self, now: u64) -> u64 {
        self.dispatcher.next_free().saturating_sub(now)
            + self.queue.len() as u64 * self.interval
    }

    /// Offer a request; `false` means admission control rejected it
    /// (`in_flight` already at `max_queue`).
    pub fn offer(&mut self, id: u64, now: u64, max_queue: u64) -> bool {
        if self.in_flight >= max_queue {
            self.rejected += 1;
            return false;
        }
        self.in_flight += 1;
        self.queue.push_back(Request {
            id,
            image: Vec::new(), // virtual requests carry no pixels
            submitted: now,
        });
        true
    }

    /// Form every batch the policy will release at `now` and admit it to
    /// the pipeline; returns the served requests (their completion events).
    pub fn form_batches(&mut self, now: u64) -> Vec<Served> {
        let mut served = Vec::new();
        self.form_batches_into(now, &mut served);
        served
    }

    /// [`Self::form_batches`] into a caller-owned buffer (appended, not
    /// cleared): the event loop reuses one scratch `Vec` across all events
    /// instead of allocating per service call.
    pub fn form_batches_into(&mut self, now: u64, served: &mut Vec<Served>) {
        while let Some(batch) = self.policy.form(&mut self.queue, now) {
            for r in &batch.requests {
                let injected = self.dispatcher.admit(now);
                self.injected += 1;
                served.push(Served {
                    id: r.id,
                    arrived: r.submitted,
                    injected,
                    completed: self.dispatcher.completion(injected),
                });
            }
            // Padding images occupy real pipeline slots (their outputs are
            // discarded) — charge them or utilization and backlog lie.
            for _ in 0..batch.padding {
                self.dispatcher.admit(now);
                self.injected += 1;
            }
        }
    }

    /// Unformed requests still waiting in the batch queue (a component of
    /// [`Self::backlog`]; the indexed least-work router tracks it
    /// incrementally).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The batch-timeout deadline of the current queue head, if any: by
    /// this cycle `form_batches` is guaranteed to release something.
    pub fn next_deadline(&self) -> Option<u64> {
        self.queue
            .front()
            .map(|oldest| self.policy.deadline(oldest.submitted))
    }

    /// Record a completion (the event loop calls this when a [`Served`]
    /// event fires).
    pub fn complete_one(&mut self) {
        debug_assert!(self.in_flight > 0, "completion without admission");
        self.in_flight -= 1;
        self.completed += 1;
    }

    /// Bottleneck-stage busy cycles so far (injections x interval).
    pub fn busy_cycles(&self) -> u64 {
        self.injected * self.interval
    }

    /// Cycle at which the pipeline's bottleneck stage frees its last
    /// reserved slot (`Dispatcher::next_free`). The utilization span must
    /// cover this: when the offset-skeleton fill is shorter than the
    /// interval (e.g. ResNet-18's 1956 vs 12544), the last completion
    /// lands *before* the bottleneck finishes its window, and dividing
    /// busy cycles by the completion span alone would exceed 100%.
    pub fn busy_until(&self) -> u64 {
        self.dispatcher.next_free()
    }

    /// The node's hazard verifier (delegates to the dispatcher; vacuous
    /// unless the node was built with [`Self::with_hazard_log`]).
    pub fn verify_no_hazard(&self) -> Result<(), String> {
        self.dispatcher.verify_no_hazard()
    }
}

/// Mutable per-node state of the multi-tenant event loop
/// ([`crate::cluster::tenant`]). Tenant nodes run the eager-scheduling
/// singles model: every accepted request is injected at admission time
/// and the node's completions are FIFO by construction (a tenant switch
/// waits for the full drain; same-tenant completions are monotone under a
/// constant fill), so the node reduces to a handful of cycle counters
/// instead of a queue + dispatcher.
#[derive(Debug, Clone)]
pub struct TenantNode {
    /// Tenant whose weights currently occupy the node's crossbars.
    pub resident: usize,
    /// Earliest hazard-free injection cycle for the next request.
    pub next_inject: u64,
    /// Completion cycle of the last injected request — the FIFO drain
    /// point a model swap must wait for before reprogramming.
    pub drain_at: u64,
    /// Outstanding requests (admission-control gauge and jsq signal).
    pub in_flight: u64,
    /// Bottleneck streaming cycles (injections x the tenant's interval).
    pub busy_cycles: u64,
    /// Cycles spent reprogramming weights (counted into utilization: a
    /// node mid-swap is busy, just not serving).
    pub swap_cycles: u64,
    /// Model swaps performed on this node.
    pub swaps: u64,
    /// Requests injected (every accepted request; singles, no padding).
    pub injected: u64,
}

impl TenantNode {
    /// A fresh node with `resident`'s weights pre-programmed (initial
    /// programming happens before the measured span, like the single-model
    /// fleet's).
    pub fn new(resident: usize) -> Self {
        Self {
            resident,
            next_inject: 0,
            drain_at: 0,
            in_flight: 0,
            busy_cycles: 0,
            swap_cycles: 0,
            swaps: 0,
            injected: 0,
        }
    }

    /// Utilization numerator: streaming plus reprogramming cycles.
    pub fn active_cycles(&self) -> u64 {
        self.busy_cycles + self.swap_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};

    fn model() -> NodeModel {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        NodeModel::from_workload(&net, &arch, &plan).unwrap()
    }

    fn singles_policy() -> BatchPolicy {
        BatchPolicy {
            sizes: vec![1],
            max_wait: 0,
            min_fill: 1.0,
        }
    }

    #[test]
    fn node_model_carries_the_validated_constants() {
        let m = model();
        assert_eq!(m.interval, 3136, "VGG-E Fig. 7 interval");
        assert_eq!(m.fill, m.shape.offsets[m.shape.n_layers() - 1]
            + m.shape.occupancy[m.shape.n_layers() - 1]);
        assert!((m.capacity_per_cycle() - 1.0 / 3136.0).abs() < 1e-15);
    }

    #[test]
    fn workload_model_carries_an_energy_profile() {
        // Mirror-derived anchors (VGG-E Fig. 7 on the paper node): one
        // image costs ~11.23 mJ, so streaming at the 3136-cycle beat draws
        // ~11.7 W on top of the ~11.96 W idle floor.
        let m = model();
        let e = m.energy.expect("from_workload must attach energy");
        assert!((10.5..12.0).contains(&e.image_mj), "image {} mJ", e.image_mj);
        assert!((10.9..12.5).contains(&e.active_power_w), "active {} W", e.active_power_w);
        assert!((e.idle_power_w - 11.9584).abs() < 0.01, "idle {} W", e.idle_power_w);
        assert!((38.0e9..41.0e9).contains(&(e.ops_per_image as f64)), "{}", e.ops_per_image);
        // The defining identity: active power x interval time == image energy.
        let interval_s = m.interval as f64 * e.logical_cycle_ns * 1e-9;
        assert!((e.active_power_w * interval_s - e.image_mj * 1e-3).abs() < 1e-12);
        // A bare shape has no workload to price.
        assert!(NodeModel::new(m.shape.clone()).energy.is_none());
    }

    #[test]
    fn sparse_singles_complete_in_exactly_fill_cycles() {
        let m = model();
        let mut n = Node::with_hazard_log(&m, singles_policy());
        for (i, at) in [(0u64, 0u64), (1, 100_000), (2, 200_000)] {
            assert!(n.offer(i, at, u64::MAX));
            let s = n.form_batches(at);
            assert_eq!(s.len(), 1);
            assert_eq!(s[0].injected, at, "idle pipeline injects immediately");
            assert_eq!(s[0].completed - s[0].arrived, m.fill);
            n.complete_one();
        }
        n.verify_no_hazard().unwrap();
    }

    #[test]
    fn burst_of_singles_spaces_by_interval() {
        let m = model();
        let mut n = Node::with_hazard_log(&m, singles_policy());
        let k = 5;
        for i in 0..k {
            assert!(n.offer(i, 0, u64::MAX));
        }
        let s = n.form_batches(0);
        assert_eq!(s.len() as u64, k);
        for (j, srv) in s.iter().enumerate() {
            assert_eq!(srv.injected, j as u64 * m.interval);
            assert_eq!(srv.completed, srv.injected + m.fill);
        }
        n.verify_no_hazard().unwrap();
        assert_eq!(n.busy_cycles(), k * m.interval);
    }

    #[test]
    fn admission_control_bounds_in_flight() {
        let m = model();
        let mut n = Node::new(&m, singles_policy());
        assert!(n.offer(0, 0, 2));
        assert!(n.offer(1, 0, 2));
        assert!(!n.offer(2, 0, 2), "third must be rejected at depth 2");
        assert_eq!(n.rejected, 1);
        assert_eq!(n.in_flight(), 2);
        let s = n.form_batches(0);
        assert_eq!(s.len(), 2);
        n.complete_one();
        assert_eq!(n.in_flight(), 1);
        assert!(n.offer(3, 0, 2), "freed capacity readmits");
    }

    #[test]
    fn hoarding_policy_waits_for_deadline() {
        let m = model();
        let policy = BatchPolicy {
            sizes: vec![4, 1],
            max_wait: 1_000,
            min_fill: 0.5,
        };
        let mut n = Node::new(&m, policy);
        assert!(n.offer(0, 0, u64::MAX));
        assert!(n.offer(1, 0, u64::MAX));
        assert!(n.form_batches(0).is_empty(), "2 of 4: hoard");
        assert_eq!(n.next_deadline(), Some(1_000));
        let s = n.form_batches(1_000);
        assert_eq!(s.len(), 2, "deadline releases the pair (padded to 4)");
        // Padding rode along: 4 injections total.
        assert_eq!(n.injected, 4);
        assert!(n.next_deadline().is_none());
    }

    #[test]
    fn full_batch_forms_without_waiting() {
        let m = model();
        let policy = BatchPolicy {
            sizes: vec![4, 1],
            max_wait: 1_000_000,
            min_fill: 0.5,
        };
        let mut n = Node::new(&m, policy);
        for i in 0..4 {
            assert!(n.offer(i, 5, u64::MAX));
        }
        let s = n.form_batches(5);
        assert_eq!(s.len(), 4);
        assert_eq!(n.injected, 4);
    }

    #[test]
    fn tenant_node_counts_swap_time_as_active() {
        let mut n = TenantNode::new(1);
        assert_eq!(n.resident, 1);
        assert_eq!(n.active_cycles(), 0);
        n.busy_cycles = 300;
        n.swap_cycles = 50;
        assert_eq!(n.active_cycles(), 350, "a node mid-swap is busy");
    }

    #[test]
    fn backlog_tracks_queue_and_pipeline() {
        let m = model();
        let mut n = Node::new(&m, singles_policy());
        assert_eq!(n.backlog(0), 0);
        n.offer(0, 0, u64::MAX);
        assert_eq!(n.backlog(0), m.interval, "queued, unformed");
        n.form_batches(0);
        assert_eq!(n.backlog(0), m.interval, "now in the pipeline");
        assert_eq!(n.backlog(m.interval), 0, "caught up");
    }
}
