//! The discrete-event cluster loop: a binary-heap calendar (the same idiom
//! as the event-driven NoC's wakeup calendar) over N node replicas, fed by
//! a seeded [`ArrivalProcess`], with pluggable routing and per-node
//! admission control. Virtual time only — a fleet-year simulates in
//! seconds, and identical seeds give bit-identical stats.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::coordinator::{BatchPolicy, Clock, VirtualClock};

use super::arrival::ArrivalProcess;
use super::node::{Node, NodeModel};
use super::stats::{ClusterStats, FleetEnergy, LatencySummary};

/// How arriving requests pick a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through nodes in order, stateless per request.
    RoundRobin,
    /// Join the node with the fewest outstanding requests (ties to the
    /// lowest index).
    ShortestQueue,
    /// Join the node with the least pending work in cycles (pipeline
    /// backlog + unformed queue; ties to the lowest index).
    LeastWork,
}

impl RoutePolicy {
    /// All policies, CLI/report order.
    pub const ALL: [RoutePolicy; 3] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::ShortestQueue,
        RoutePolicy::LeastWork,
    ];

    /// Short name for tables and flags.
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::ShortestQueue => "jsq",
            RoutePolicy::LeastWork => "least-work",
        }
    }
}

impl std::str::FromStr for RoutePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "jsq" | "shortest-queue" => Ok(RoutePolicy::ShortestQueue),
            "least-work" | "lw" => Ok(RoutePolicy::LeastWork),
            other => Err(format!(
                "unknown route policy {other:?} (rr | jsq | least-work)"
            )),
        }
    }
}

/// One cluster scenario: fleet size, offered load, arrival shape, routing
/// and admission, all in simulated cycles.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Node replicas in the fleet.
    pub nodes: usize,
    /// Offered arrival rate in requests per cycle (see
    /// [`rate_from_qps`] for the wall-clock conversion).
    pub rate_per_cycle: f64,
    /// Arrival process shape.
    pub pattern: ArrivalProcess,
    /// Routing policy.
    pub route: RoutePolicy,
    /// Admission bound: max outstanding requests per node; arrivals routed
    /// to a full node are rejected (counted against the SLO).
    pub max_queue: u64,
    /// Arrival horizon in cycles (generation stops here; the loop then
    /// drains). Ignored when `fixed_requests` is set.
    pub horizon_cycles: u64,
    /// Fixed-population mode: exactly this many arrivals regardless of
    /// horizon (the monotonicity properties compare equal counts).
    pub fixed_requests: Option<usize>,
    /// Batching policy each node runs (ticks = cycles).
    pub policy: BatchPolicy,
    /// Seed for the arrival process.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            rate_per_cycle: 1e-4,
            pattern: ArrivalProcess::Poisson,
            route: RoutePolicy::RoundRobin,
            max_queue: 64,
            horizon_cycles: 5_000_000,
            fixed_requests: None,
            policy: cycle_policy(),
            seed: 0xC105_E12,
        }
    }
}

/// The default node batching policy in *cycles*: the server's [4, 1] shape
/// with a max_wait comparable to one VGG-E Fig. 7 interval, so hoarding
/// costs at most about one pipeline beat.
pub fn cycle_policy() -> BatchPolicy {
    BatchPolicy {
        sizes: vec![4, 1],
        max_wait: 4_000,
        min_fill: 0.5,
    }
}

/// Requests/cycle for an offered load in requests/second at
/// `logical_cycle_ns` per cycle.
pub fn rate_from_qps(qps: f64, logical_cycle_ns: f64) -> f64 {
    qps * logical_cycle_ns * 1e-9
}

#[derive(Debug, PartialEq, Eq)]
enum EventKind {
    /// The `idx`-th request of the arrival stream reaches the cluster.
    Arrival { idx: usize },
    /// A node's batch-timeout deadline may have ripened (lazy-deleted:
    /// stale deadlines are harmless re-checks).
    Deadline { node: usize },
    /// A request finishes its pipeline on `node`.
    Completion { node: usize, arrived: u64, injected: u64 },
}

/// Calendar entry. `(cycle, seq)` is the heap key; `seq` is a unique push
/// counter, so same-cycle events fire deterministically in push order.
#[derive(Debug, PartialEq, Eq)]
struct Event {
    cycle: u64,
    seq: u64,
    kind: EventKind,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.cycle, self.seq).cmp(&(other.cycle, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap wakeup calendar with the deterministic tie-break counter.
#[derive(Debug, Default)]
struct Calendar {
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl Calendar {
    fn push(&mut self, cycle: u64, kind: EventKind) {
        self.heap.push(Reverse(Event {
            cycle,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }
}

/// Run one cluster scenario to completion (arrivals exhausted, queues
/// drained, pipelines empty) and report.
pub fn simulate(model: &NodeModel, cfg: &ClusterConfig) -> ClusterStats {
    assert!(cfg.nodes > 0, "a cluster needs at least one node");
    assert!(
        !cfg.policy.sizes.is_empty() && cfg.policy.sizes.iter().all(|&s| s > 0),
        "batch policy sizes must be non-empty and positive (an empty list \
         never releases the queue; a zero size forms empty batches forever)"
    );
    let arrivals = match cfg.fixed_requests {
        Some(n) => cfg.pattern.generate_n(cfg.rate_per_cycle, n, cfg.seed),
        None => cfg
            .pattern
            .generate(cfg.rate_per_cycle, cfg.horizon_cycles, cfg.seed),
    };
    let mut nodes: Vec<Node> = (0..cfg.nodes)
        .map(|_| Node::new(model, cfg.policy.clone()))
        .collect();

    let mut cal = Calendar::default();
    if !arrivals.is_empty() {
        cal.push(arrivals[0], EventKind::Arrival { idx: 0 });
    }

    let mut rr_next = 0usize;
    let mut latencies: Vec<u64> = Vec::with_capacity(arrivals.len());
    let mut queueing: Vec<u64> = Vec::with_capacity(arrivals.len());
    let mut drained_at = 0u64;

    // The simulation's time source: nodes batch against the same integer
    // ticks the real server's WallClock provides, and `advance_to` panics
    // if the calendar ever pops out of order — a live check on the heap's
    // (cycle, seq) contract.
    let mut clock = VirtualClock::new();
    while let Some(ev) = cal.pop() {
        clock.advance_to(ev.cycle);
        let now = clock.now();
        match ev.kind {
            EventKind::Arrival { idx } => {
                // Stream the calendar: materialize the next arrival only
                // when this one fires, keeping the heap O(fleet + batch).
                if idx + 1 < arrivals.len() {
                    cal.push(arrivals[idx + 1], EventKind::Arrival { idx: idx + 1 });
                }
                let target = route(&nodes, cfg.route, &mut rr_next, now);
                if nodes[target].offer(idx as u64, now, cfg.max_queue) {
                    service_node(&mut cal, &mut nodes[target], target, now);
                }
            }
            EventKind::Deadline { node } => {
                service_node(&mut cal, &mut nodes[node], node, now);
            }
            EventKind::Completion {
                node,
                arrived,
                injected,
            } => {
                nodes[node].complete_one();
                latencies.push(now - arrived);
                queueing.push(injected - arrived);
                drained_at = drained_at.max(now);
            }
        }
    }

    let completed = latencies.len() as u64;
    let rejected: u64 = nodes.iter().map(|n| n.rejected).sum();
    debug_assert_eq!(
        completed + rejected,
        arrivals.len() as u64,
        "conservation: every arrival completes or is rejected at drain"
    );
    // Utilization span: last completion or last reserved bottleneck slot,
    // whichever is later (injections spaced >= interval guarantee
    // busy <= span, so the fraction stays in [0, 1]).
    let busy_until = nodes.iter().map(|n| n.busy_until()).max().unwrap_or(0);
    let span = drained_at.max(busy_until).max(1);
    // Fleet energy: every injection (real or padding) costs one image's
    // dynamic energy ON TOP of the always-on idle floor every allocated
    // replica burns over the whole span (eDRAM refresh and routers never
    // power-gate, so a busy node always draws MORE than an idle one).
    // Dynamic energy is charged over the same span utilization uses, so
    // dynamic_j == Σ utilization x active power x span exactly (the
    // conservation identity tests/golden_energy.rs pins).
    let energy = model.energy.map(|p| {
        let t_s = p.logical_cycle_ns * 1e-9;
        let (mut dynamic_mj, mut padding_mj) = (0.0, 0.0);
        for n in &nodes {
            dynamic_mj += n.injected as f64 * p.image_mj;
            padding_mj += (n.injected - n.completed) as f64 * p.image_mj;
        }
        let idle_j = nodes.len() as f64 * span as f64 * t_s * p.idle_power_w;
        FleetEnergy {
            dynamic_j: dynamic_mj * 1e-3,
            idle_j,
            padding_waste_j: padding_mj * 1e-3,
            span_s: span as f64 * t_s,
            completed_ops: completed * p.ops_per_image,
            completed,
        }
    });
    ClusterStats {
        offered: arrivals.len() as u64,
        completed,
        rejected,
        horizon_cycles: cfg.horizon_cycles,
        drained_at,
        latency: LatencySummary::from_samples(latencies),
        queueing: LatencySummary::from_samples(queueing),
        node_utilization: nodes
            .iter()
            .map(|n| n.busy_cycles() as f64 / span as f64)
            .collect(),
        per_node_completed: nodes.iter().map(|n| n.completed).collect(),
        per_node_rejected: nodes.iter().map(|n| n.rejected).collect(),
        per_node_injected: nodes.iter().map(|n| n.injected).collect(),
        energy,
    }
}

/// Form whatever `node` releases at `now`, schedule the resulting
/// completion events, and re-arm the node's batch-timeout deadline.
///
/// Deadline invariant: whenever a node's queue is non-empty, the calendar
/// holds at least one Deadline event no later than the queue head's
/// timeout — so hoarded requests always get a future chance to form.
/// Stale deadlines (the head they were armed for already served) fire as
/// harmless no-ops and re-arm for the current head.
fn service_node(cal: &mut Calendar, node: &mut Node, node_idx: usize, now: u64) {
    for s in node.form_batches(now) {
        cal.push(
            s.completed,
            EventKind::Completion {
                node: node_idx,
                arrived: s.arrived,
                injected: s.injected,
            },
        );
    }
    if let Some(deadline) = node.next_deadline() {
        // The head is still hoarding; it will be releasable at `deadline`.
        cal.push(deadline.max(now), EventKind::Deadline { node: node_idx });
    }
}

fn route(nodes: &[Node], policy: RoutePolicy, rr_next: &mut usize, now: u64) -> usize {
    match policy {
        RoutePolicy::RoundRobin => {
            let t = *rr_next % nodes.len();
            *rr_next = (*rr_next + 1) % nodes.len();
            t
        }
        RoutePolicy::ShortestQueue => nodes
            .iter()
            .enumerate()
            .min_by_key(|&(i, n)| (n.in_flight(), i))
            .map(|(i, _)| i)
            .expect("non-empty fleet"),
        RoutePolicy::LeastWork => nodes
            .iter()
            .enumerate()
            .min_by_key(|&(i, n)| (n.backlog(now), i))
            .map(|(i, _)| i)
            .expect("non-empty fleet"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::ReplicationPlan;

    fn model() -> NodeModel {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        NodeModel::from_workload(&net, &arch, &plan).unwrap()
    }

    fn light_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            rate_per_cycle: 1e-4, // well under 2 nodes x 1/3136
            horizon_cycles: 1_000_000,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn light_load_completes_everything() {
        let s = simulate(&model(), &light_cfg());
        assert!(s.offered > 50, "horizon should produce arrivals");
        assert_eq!(s.completed + s.rejected, s.offered);
        assert_eq!(s.rejected, 0, "light load must not reject");
        assert!(s.latency.p50() >= model().fill, "fill is a lower bound");
        assert!(s.mean_utilization() > 0.0 && s.mean_utilization() < 0.5);
    }

    #[test]
    fn identical_seeds_are_bit_identical() {
        let a = simulate(&model(), &light_cfg());
        let b = simulate(&model(), &light_cfg());
        assert_eq!(a.latency.p50(), b.latency.p50());
        assert_eq!(a.latency.p999(), b.latency.p999());
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.node_utilization, b.node_utilization);
        let c = simulate(
            &model(),
            &ClusterConfig {
                seed: 999,
                ..light_cfg()
            },
        );
        assert_ne!(a.offered, 0);
        assert_ne!(
            (a.offered, a.latency.p50()),
            (c.offered, c.latency.p50()),
            "a different seed should perturb the run"
        );
    }

    #[test]
    fn overload_rejects_but_conserves() {
        // 1 node at ~3x its capacity with a tight admission bound.
        let cfg = ClusterConfig {
            nodes: 1,
            rate_per_cycle: 3.0 / 3136.0,
            max_queue: 8,
            horizon_cycles: 2_000_000,
            ..ClusterConfig::default()
        };
        let s = simulate(&model(), &cfg);
        assert_eq!(s.completed + s.rejected, s.offered);
        assert!(s.rejected > 0, "overload must reject");
        assert!(s.rejection_rate() > 0.3, "rate {}", s.rejection_rate());
        // The one node saturates: utilization near 1.
        assert!(s.node_utilization[0] > 0.9, "{}", s.node_utilization[0]);
        assert!(!s.meets_slo(u64::MAX), "rejections fail any SLO");
    }

    #[test]
    fn routing_policies_all_conserve_and_jsq_balances() {
        let mut spread = Vec::new();
        for route in RoutePolicy::ALL {
            let cfg = ClusterConfig {
                nodes: 4,
                rate_per_cycle: 8e-4,
                route,
                horizon_cycles: 1_000_000,
                ..ClusterConfig::default()
            };
            let s = simulate(&model(), &cfg);
            assert_eq!(s.completed + s.rejected, s.offered, "{}", route.name());
            let total: u64 = s.per_node_completed.iter().sum();
            assert_eq!(total, s.completed, "{}", route.name());
            let max = *s.per_node_completed.iter().max().unwrap() as f64;
            let min = *s.per_node_completed.iter().min().unwrap() as f64;
            spread.push(max - min);
        }
        // Load-aware routing should not be wildly worse-balanced than rr
        // (rr is balanced by construction; jsq's index tie-break gives the
        // low nodes a small edge whenever the fleet drains).
        assert!(spread[1] <= spread[0] + 64.0, "jsq spread {spread:?}");
    }

    #[test]
    fn trace_replay_drives_exact_arrivals() {
        let cfg = ClusterConfig {
            nodes: 1,
            pattern: ArrivalProcess::Trace(vec![0, 10_000, 500_000]),
            policy: BatchPolicy {
                sizes: vec![1],
                max_wait: 0,
                min_fill: 1.0,
            },
            horizon_cycles: 1_000_000,
            ..ClusterConfig::default()
        };
        let m = model();
        let s = simulate(&m, &cfg);
        assert_eq!(s.offered, 3);
        assert_eq!(s.completed, 3);
        // Request 0 and 2 hit an idle pipeline: latency == fill. Request 1
        // lands 10_000 cycles in, pipeline still busy until 3136 only —
        // idle again, latency == fill as well.
        assert_eq!(s.latency.p50(), m.fill);
        assert_eq!(s.latency.max(), m.fill);
        assert_eq!(s.queueing.max(), 0);
    }

    #[test]
    fn energy_accounting_rides_along() {
        let s = simulate(&model(), &light_cfg());
        let e = s.energy.expect("workload-built model carries energy");
        assert!(e.dynamic_j > 0.0 && e.idle_j > 0.0);
        assert!(e.total_j() > e.dynamic_j, "idle floor must add energy");
        assert!(e.joules_per_image() > 0.0);
        assert!(e.avg_power_w() > 0.0);
        // Light load on 2 nodes: a few watts of dynamic draw on top of the
        // 2-node always-on floor (~23.9 W), far below 2 peak envelopes.
        assert!((23.9..40.0).contains(&e.avg_power_w()), "{} W", e.avg_power_w());
        // Dynamic energy == injections x image energy, summed per node.
        let injected: u64 = s.per_node_injected.iter().sum();
        let img_mj = model().energy.unwrap().image_mj;
        assert!((e.dynamic_j - injected as f64 * img_mj * 1e-3).abs() < 1e-9);
        // Padding is a subset of dynamic energy.
        assert!(e.padding_waste_j >= 0.0 && e.padding_waste_j <= e.dynamic_j);
    }

    #[test]
    fn bare_shape_model_reports_no_energy() {
        let m = model();
        let bare = NodeModel::new(m.shape.clone());
        let s = simulate(&bare, &light_cfg());
        assert!(s.energy.is_none(), "no profile, no energy block");
        assert_eq!(s.completed + s.rejected, s.offered);
    }

    #[test]
    fn zero_arrivals_is_a_clean_empty_run() {
        let cfg = ClusterConfig {
            pattern: ArrivalProcess::Trace(vec![]),
            ..light_cfg()
        };
        let s = simulate(&model(), &cfg);
        assert_eq!(s.offered, 0);
        assert_eq!(s.completed, 0);
        assert_eq!(s.latency.count(), 0);
        assert_eq!(s.throughput_per_cycle(), 0.0);
    }

    #[test]
    fn route_policy_parses() {
        assert_eq!("rr".parse::<RoutePolicy>().unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            "jsq".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::ShortestQueue
        );
        assert_eq!(
            "least-work".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::LeastWork
        );
        assert!("random".parse::<RoutePolicy>().is_err());
    }
}
