//! The discrete-event cluster loop: a binary-heap calendar (the same idiom
//! as the event-driven NoC's wakeup calendar) over N node replicas, fed by
//! a seeded [`ArrivalProcess`], with pluggable routing and per-node
//! admission control. Virtual time only — a fleet-year simulates in
//! seconds, and identical seeds give bit-identical stats.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

use crate::coordinator::{BatchPolicy, Clock, VirtualClock};
use crate::obs::metrics::{LogHistogram, MetricsRegistry};
use crate::obs::trace::{NullSink, TraceEvent, TracePhase, TraceSink};

use super::arrival::ArrivalProcess;
use super::node::{Node, NodeModel, Served};
use super::stats::{ClusterStats, FleetEnergy, LatencySummary};

/// How arriving requests pick a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through nodes in order, stateless per request.
    RoundRobin,
    /// Join the node with the fewest outstanding requests (ties to the
    /// lowest index).
    ShortestQueue,
    /// Join the node with the least pending work in cycles (pipeline
    /// backlog + unformed queue; ties to the lowest index).
    LeastWork,
}

impl RoutePolicy {
    /// All policies, CLI/report order.
    pub const ALL: [RoutePolicy; 3] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::ShortestQueue,
        RoutePolicy::LeastWork,
    ];

    /// Short name for tables and flags.
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::ShortestQueue => "jsq",
            RoutePolicy::LeastWork => "least-work",
        }
    }
}

impl std::str::FromStr for RoutePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "jsq" | "shortest-queue" => Ok(RoutePolicy::ShortestQueue),
            "least-work" | "lw" => Ok(RoutePolicy::LeastWork),
            other => Err(format!(
                "unknown route policy {other:?} (rr | jsq | least-work)"
            )),
        }
    }
}

/// How the routing decision is computed. Both implementations produce
/// **bit-identical** [`ClusterStats`] — the tie-break contract (lowest
/// node index wins on equal signal) is part of each index's ordering key,
/// and `tests/prop_cluster_perf.rs` pins the parity across random
/// policy/routing/admission/seed mixes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouteImpl {
    /// Incrementally maintained routing indexes: a bucketed occupancy
    /// index for `jsq` and a ready/lagging backlog index for
    /// `least-work`, so each arrival routes in O(1)–O(log N) instead of
    /// scanning the fleet.
    #[default]
    Indexed,
    /// The original O(N)-per-arrival scan over every node — kept as the
    /// reference the indexes must match, and as the "old" side of the
    /// scaling bench.
    LinearScan,
}

impl RouteImpl {
    /// Short name for flags and bench rows.
    pub fn name(&self) -> &'static str {
        match self {
            RouteImpl::Indexed => "indexed",
            RouteImpl::LinearScan => "scan",
        }
    }
}

impl std::str::FromStr for RouteImpl {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "indexed" => Ok(RouteImpl::Indexed),
            "scan" | "linear-scan" => Ok(RouteImpl::LinearScan),
            other => Err(format!(
                "unknown route implementation {other:?} (indexed | scan)"
            )),
        }
    }
}

/// One cluster scenario: fleet size, offered load, arrival shape, routing
/// and admission, all in simulated cycles.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Node replicas in the fleet.
    pub nodes: usize,
    /// Offered arrival rate in requests per cycle (see
    /// [`rate_from_qps`] for the wall-clock conversion).
    pub rate_per_cycle: f64,
    /// Arrival process shape.
    pub pattern: ArrivalProcess,
    /// Routing policy.
    pub route: RoutePolicy,
    /// Admission bound: max outstanding requests per node; arrivals routed
    /// to a full node are rejected (counted against the SLO).
    pub max_queue: u64,
    /// Arrival horizon in cycles (generation stops here; the loop then
    /// drains). Ignored when `fixed_requests` is set.
    pub horizon_cycles: u64,
    /// Fixed-population mode: exactly this many arrivals regardless of
    /// horizon (the monotonicity properties compare equal counts).
    pub fixed_requests: Option<usize>,
    /// Batching policy each node runs (ticks = cycles).
    pub policy: BatchPolicy,
    /// Seed for the arrival process.
    pub seed: u64,
    /// Routing implementation ([`RouteImpl::Indexed`] by default; the
    /// linear scan is the bit-identical reference).
    pub route_impl: RouteImpl,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            rate_per_cycle: 1e-4,
            pattern: ArrivalProcess::Poisson,
            route: RoutePolicy::RoundRobin,
            max_queue: 64,
            horizon_cycles: 5_000_000,
            fixed_requests: None,
            policy: cycle_policy(),
            seed: 0xC105_E12,
            route_impl: RouteImpl::Indexed,
        }
    }
}

/// The default node batching policy in *cycles*: the server's [4, 1] shape
/// with a max_wait comparable to one VGG-E Fig. 7 interval, so hoarding
/// costs at most about one pipeline beat.
pub fn cycle_policy() -> BatchPolicy {
    BatchPolicy {
        sizes: vec![4, 1],
        max_wait: 4_000,
        min_fill: 0.5,
    }
}

/// Requests/cycle for an offered load in requests/second at
/// `logical_cycle_ns` per cycle.
pub fn rate_from_qps(qps: f64, logical_cycle_ns: f64) -> f64 {
    qps * logical_cycle_ns * 1e-9
}

#[derive(Debug, PartialEq, Eq)]
enum EventKind {
    /// Request `id` of the arrival stream reaches the cluster (ids count
    /// up from 0 in stream order; the next arrival is pulled from the
    /// [`ArrivalStream`](super::arrival::ArrivalStream) only when this
    /// one fires).
    Arrival { id: u64 },
    /// A node's batch-timeout deadline may have ripened. Lazy-deleted: the
    /// event is *live* only while it matches the node's armed target
    /// (`armed[node]`); superseded entries fire as skipped no-ops.
    Deadline { node: usize },
    /// A request finishes its pipeline on `node`.
    Completion { node: usize, arrived: u64, injected: u64 },
}

/// Calendar entry. `(cycle, seq)` is the heap key; `seq` is a unique push
/// counter, so same-cycle events fire deterministically in push order.
#[derive(Debug, PartialEq, Eq)]
struct Event {
    cycle: u64,
    seq: u64,
    kind: EventKind,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.cycle, self.seq).cmp(&(other.cycle, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap wakeup calendar with the deterministic tie-break counter,
/// instrumented with the perf gauges the scaling bench reports.
#[derive(Debug, Default)]
struct Calendar {
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    /// High-water mark of the heap (peak calendar depth).
    peak: usize,
    /// Events popped (arrivals + completions + deadline fires).
    pops: u64,
}

impl Calendar {
    fn push(&mut self, cycle: u64, kind: EventKind) {
        self.heap.push(Reverse(Event {
            cycle,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
        self.peak = self.peak.max(self.heap.len());
    }

    fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop().map(|Reverse(e)| e);
        if ev.is_some() {
            self.pops += 1;
        }
        ev
    }
}

/// Run one cluster scenario to completion (arrivals exhausted, queues
/// drained, pipelines empty) and report.
///
/// The event loop is asymptotically flat in fleet size and request count:
/// arrivals are pulled one at a time from an
/// [`ArrivalStream`](super::arrival::ArrivalStream) (O(1) arrival
/// memory), routing decisions come from incremental indexes (O(log
/// N) per arrival instead of an O(N) scan; see [`RouteImpl`]), and each
/// node keeps at most one *live* Deadline event in the calendar, so the
/// heap stays at O(fleet + in-flight batches) no matter the horizon. Every
/// flattening preserves bit-identical stats against the original loop —
/// see DESIGN.md §4a and `tests/prop_cluster_perf.rs`.
pub fn simulate(model: &NodeModel, cfg: &ClusterConfig) -> ClusterStats {
    simulate_with_sink(model, cfg, &mut NullSink)
}

/// [`simulate`] with a [`TraceSink`] tap. Three subsystems report:
/// `cluster.route` (arrival/reject instants on the router track),
/// `cluster.batch` (batch-form and live-deadline instants per node), and
/// `cluster.node` (per-request service spans `[injected, completed)` plus
/// completion instants per node). Stats are bit-identical whatever sink
/// is attached (`tests/obs_parity.rs`).
pub fn simulate_with_sink(
    model: &NodeModel,
    cfg: &ClusterConfig,
    sink: &mut dyn TraceSink,
) -> ClusterStats {
    let _prof = crate::obs::profile::scope("cluster.simulate");
    assert!(cfg.nodes > 0, "a cluster needs at least one node");
    assert!(
        !cfg.policy.sizes.is_empty() && cfg.policy.sizes.iter().all(|&s| s > 0),
        "batch policy sizes must be non-empty and positive (an empty list \
         never releases the queue; a zero size forms empty batches forever)"
    );
    let mut stream = match cfg.fixed_requests {
        Some(n) => cfg.pattern.stream_n(cfg.rate_per_cycle, n, cfg.seed),
        None => cfg
            .pattern
            .stream_horizon(cfg.rate_per_cycle, cfg.horizon_cycles, cfg.seed),
    };
    let mut nodes: Vec<Node> = (0..cfg.nodes)
        .map(|_| Node::new(model, cfg.policy.clone()))
        .collect();
    let mut router = Router::new(cfg.route, cfg.route_impl, cfg.nodes, model.interval);
    let traced = sink.enabled();
    if traced {
        sink.name_track("cluster.route", 0, cfg.route.name());
        for i in 0..cfg.nodes {
            sink.name_track("cluster.batch", i as u64, &format!("node {i}"));
            sink.name_track("cluster.node", i as u64, &format!("node {i}"));
        }
    }
    // Operation counters folded into the stats' metrics block at drain —
    // plain u64s (and one local histogram) so the hot loop never touches
    // a map.
    let mut released_hist = LogHistogram::new();
    let (mut n_rejected, mut n_deadline_live, mut n_deadline_stale) = (0u64, 0u64, 0u64);
    // Deadline suppression state: `armed[i] == Some(t)` iff the calendar
    // holds exactly one live Deadline event for node i at cycle t.
    let mut armed: Vec<Option<u64>> = vec![None; cfg.nodes];
    // One scratch buffer for every `form_batches_into` call in the run.
    let mut scratch: Vec<Served> = Vec::new();

    let mut cal = Calendar::default();
    let mut offered = 0u64;
    let mut last_arrival = 0u64;
    if let Some(c) = stream.next() {
        cal.push(c, EventKind::Arrival { id: 0 });
        offered = 1;
        last_arrival = c;
    }

    let mut latencies: Vec<u64> = Vec::new();
    let mut queueing: Vec<u64> = Vec::new();
    let mut drained_at = 0u64;

    // The simulation's time source: nodes batch against the same integer
    // ticks the real server's WallClock provides, and `advance_to` panics
    // if the calendar ever pops out of order — a live check on the heap's
    // (cycle, seq) contract.
    let mut clock = VirtualClock::new();
    while let Some(ev) = cal.pop() {
        clock.advance_to(ev.cycle);
        let now = clock.now();
        match ev.kind {
            EventKind::Arrival { id } => {
                // Pull the next arrival only when this one fires (and push
                // it FIRST, preserving the original loop's same-cycle push
                // order): the calendar holds at most one pending arrival.
                if let Some(c) = stream.next() {
                    cal.push(c, EventKind::Arrival { id: offered });
                    offered += 1;
                    last_arrival = c;
                }
                let target = router.pick(&nodes, now);
                let admitted = nodes[target].offer(id, now, cfg.max_queue);
                if traced {
                    sink.record(TraceEvent {
                        subsystem: "cluster.route",
                        track: 0,
                        name: if admitted { "arrival" } else { "reject" },
                        ts: now,
                        phase: TracePhase::Instant,
                        args: vec![("request", id), ("node", target as u64)],
                    });
                }
                if admitted {
                    service_node(
                        &mut cal,
                        &mut nodes[target],
                        target,
                        now,
                        &mut armed[target],
                        &mut scratch,
                        sink,
                        &mut released_hist,
                    );
                } else {
                    n_rejected += 1;
                }
                router.refresh(target, &nodes[target], now);
            }
            EventKind::Deadline { node } => {
                if armed[node] == Some(now) {
                    // Live: consume the armed slot and let the node form
                    // whatever ripened (service re-arms for the new head).
                    n_deadline_live += 1;
                    if traced {
                        sink.record(TraceEvent {
                            subsystem: "cluster.batch",
                            track: node as u64,
                            name: "deadline",
                            ts: now,
                            phase: TracePhase::Instant,
                            args: Vec::new(),
                        });
                    }
                    armed[node] = None;
                    service_node(
                        &mut cal,
                        &mut nodes[node],
                        node,
                        now,
                        &mut armed[node],
                        &mut scratch,
                        sink,
                        &mut released_hist,
                    );
                    router.refresh(node, &nodes[node], now);
                } else {
                    n_deadline_stale += 1;
                }
                // Superseded deadlines skip without touching the node: the
                // queue has not changed since its last service call, and
                // re-forming before the live target releases nothing — the
                // original loop's re-check here was provably a no-op.
            }
            EventKind::Completion {
                node,
                arrived,
                injected,
            } => {
                nodes[node].complete_one();
                router.refresh(node, &nodes[node], now);
                if traced {
                    sink.record(TraceEvent {
                        subsystem: "cluster.node",
                        track: node as u64,
                        name: "complete",
                        ts: now,
                        phase: TracePhase::Instant,
                        args: vec![("latency", now - arrived), ("queueing", injected - arrived)],
                    });
                }
                latencies.push(now - arrived);
                queueing.push(injected - arrived);
                drained_at = drained_at.max(now);
            }
        }
    }

    let completed = latencies.len() as u64;
    let rejected: u64 = nodes.iter().map(|n| n.rejected).sum();
    debug_assert_eq!(
        completed + rejected,
        offered,
        "conservation: every arrival completes or is rejected at drain"
    );
    debug_assert_eq!(n_rejected, rejected, "router-side and node-side reject counts agree");
    // The metrics block: a pure function of the run (never of the sink),
    // so the parity suite can compare it field-for-field across sinks.
    // `events.*` migrate the ad-hoc gauges (`events_processed`,
    // `peak_calendar_depth`) into the registry alongside the per-kind
    // breakdown the legacy fields never had.
    let mut metrics = MetricsRegistry::new();
    metrics.incr("cluster.events.arrival", offered);
    metrics.incr("cluster.events.rejected", rejected);
    metrics.incr("cluster.events.deadline_live", n_deadline_live);
    metrics.incr("cluster.events.deadline_stale", n_deadline_stale);
    metrics.incr("cluster.events.completion", completed);
    metrics.incr("cluster.events.processed", cal.pops);
    metrics.gauge("cluster.calendar.peak_depth", cal.peak as f64);
    if released_hist.count() > 0 {
        metrics.set_histogram("cluster.batch.released", released_hist);
    }
    // The effective generation span: under `fixed_requests` the configured
    // horizon is ignored entirely, and a trace replay only uses it as an
    // upper bound — report what the arrivals actually covered.
    let arrival_extent = if offered == 0 { 0 } else { last_arrival + 1 };
    let horizon_cycles = match (cfg.fixed_requests, &cfg.pattern) {
        (Some(_), _) => arrival_extent,
        (None, ArrivalProcess::Trace(_)) => cfg.horizon_cycles.min(arrival_extent),
        (None, _) => cfg.horizon_cycles,
    };
    // Utilization span: last completion or last reserved bottleneck slot,
    // whichever is later (injections spaced >= interval guarantee
    // busy <= span, so the fraction stays in [0, 1]).
    let busy_until = nodes.iter().map(|n| n.busy_until()).max().unwrap_or(0);
    let span = drained_at.max(busy_until).max(1);
    // Fleet energy: every injection (real or padding) costs one image's
    // dynamic energy ON TOP of the always-on idle floor every allocated
    // replica burns over the whole span (eDRAM refresh and routers never
    // power-gate, so a busy node always draws MORE than an idle one).
    // Dynamic energy is charged over the same span utilization uses, so
    // dynamic_j == Σ utilization x active power x span exactly (the
    // conservation identity tests/golden_energy.rs pins).
    let energy = model.energy.map(|p| {
        let t_s = p.logical_cycle_ns * 1e-9;
        let (mut dynamic_mj, mut padding_mj) = (0.0, 0.0);
        for n in &nodes {
            dynamic_mj += n.injected as f64 * p.image_mj;
            padding_mj += (n.injected - n.completed) as f64 * p.image_mj;
        }
        let idle_j = nodes.len() as f64 * span as f64 * t_s * p.idle_power_w;
        FleetEnergy {
            dynamic_j: dynamic_mj * 1e-3,
            idle_j,
            padding_waste_j: padding_mj * 1e-3,
            weight_writes_j: 0.0,
            span_s: span as f64 * t_s,
            completed_ops: completed * p.ops_per_image,
            completed,
        }
    });
    ClusterStats {
        offered,
        completed,
        rejected,
        horizon_cycles,
        drained_at,
        events_processed: cal.pops,
        peak_calendar_depth: cal.peak as u64,
        latency: LatencySummary::from_samples(latencies),
        queueing: LatencySummary::from_samples(queueing),
        node_utilization: nodes
            .iter()
            .map(|n| n.busy_cycles() as f64 / span as f64)
            .collect(),
        per_node_completed: nodes.iter().map(|n| n.completed).collect(),
        per_node_rejected: nodes.iter().map(|n| n.rejected).collect(),
        per_node_injected: nodes.iter().map(|n| n.injected).collect(),
        energy,
        metrics,
    }
}

/// Form whatever `node` releases at `now`, schedule the resulting
/// completion events, and re-arm the node's batch-timeout deadline.
///
/// Deadline invariant (suppressed form): whenever a node's queue is
/// non-empty, `*armed == Some(t)` and the calendar holds exactly one live
/// Deadline event at `t`, the current head's timeout — so hoarded requests
/// always get a future chance to form, and the heap holds at most one live
/// deadline per node. The target is strictly in the future after any
/// service call: `BatchPolicy::form`'s timeout branch always releases at
/// least one request, so the surviving head's age is under `max_wait`.
/// Superseded entries (the head they were armed for already formed early)
/// stay in the heap and fire as skipped no-ops; they cannot outnumber the
/// batches in flight.
#[allow(clippy::too_many_arguments)]
fn service_node(
    cal: &mut Calendar,
    node: &mut Node,
    node_idx: usize,
    now: u64,
    armed: &mut Option<u64>,
    scratch: &mut Vec<Served>,
    sink: &mut dyn TraceSink,
    released: &mut LogHistogram,
) {
    scratch.clear();
    node.form_batches_into(now, scratch);
    if !scratch.is_empty() {
        released.observe(scratch.len() as u64);
        if sink.enabled() {
            sink.record(TraceEvent {
                subsystem: "cluster.batch",
                track: node_idx as u64,
                name: "form",
                ts: now,
                phase: TracePhase::Instant,
                args: vec![("released", scratch.len() as u64)],
            });
            for s in scratch.iter() {
                sink.record(TraceEvent {
                    subsystem: "cluster.node",
                    track: node_idx as u64,
                    name: "service",
                    ts: s.injected,
                    phase: TracePhase::Span {
                        dur: s.completed - s.injected,
                    },
                    args: vec![("request", s.id)],
                });
            }
        }
    }
    for s in scratch.iter() {
        cal.push(
            s.completed,
            EventKind::Completion {
                node: node_idx,
                arrived: s.arrived,
                injected: s.injected,
            },
        );
    }
    if let Some(deadline) = node.next_deadline() {
        // The head is still hoarding; it will be releasable at `deadline`.
        let target = deadline.max(now);
        if *armed != Some(target) {
            cal.push(target, EventKind::Deadline { node: node_idx });
            *armed = Some(target);
        }
    }
}

/// The routing decision engine: either the original O(N) scans or the
/// incremental indexes, behind one interface so the event loop is
/// implementation-blind. `pick` is called with the *pre-offer* fleet state
/// (exactly what the scans observed); `refresh` folds a node's new state
/// into the index after every mutation (offer + service, live deadline
/// service, completion).
#[derive(Debug)]
enum Router {
    RoundRobin { next: usize },
    ScanJsq,
    ScanLw,
    Jsq(JsqIndex),
    Lw(LwIndex),
}

impl Router {
    fn new(route: RoutePolicy, imp: RouteImpl, n: usize, interval: u64) -> Self {
        match (route, imp) {
            (RoutePolicy::RoundRobin, _) => Router::RoundRobin { next: 0 },
            (RoutePolicy::ShortestQueue, RouteImpl::LinearScan) => Router::ScanJsq,
            (RoutePolicy::ShortestQueue, RouteImpl::Indexed) => Router::Jsq(JsqIndex::new(n)),
            (RoutePolicy::LeastWork, RouteImpl::LinearScan) => Router::ScanLw,
            (RoutePolicy::LeastWork, RouteImpl::Indexed) => Router::Lw(LwIndex::new(n, interval)),
        }
    }

    fn pick(&mut self, nodes: &[Node], now: u64) -> usize {
        match self {
            Router::RoundRobin { next } => {
                let t = *next % nodes.len();
                *next = (*next + 1) % nodes.len();
                t
            }
            Router::ScanJsq => nodes
                .iter()
                .enumerate()
                .min_by_key(|&(i, n)| (n.in_flight(), i))
                .map(|(i, _)| i)
                .expect("non-empty fleet"),
            Router::ScanLw => nodes
                .iter()
                .enumerate()
                .min_by_key(|&(i, n)| (n.backlog(now), i))
                .map(|(i, _)| i)
                .expect("non-empty fleet"),
            Router::Jsq(ix) => ix.best(),
            Router::Lw(ix) => ix.best(now),
        }
    }

    fn refresh(&mut self, i: usize, node: &Node, now: u64) {
        match self {
            Router::Jsq(ix) => ix.set(i, node.in_flight()),
            Router::Lw(ix) => ix.set(i, node.busy_until(), node.queue_len() as u64, now),
            _ => {}
        }
    }
}

/// Bucketed occupancy index for join-shortest-queue: `buckets[k]` is the
/// ordered set of nodes with `in_flight == k`, and `min_occ` is a cursor
/// below which every bucket is empty. `best` returns the lowest-index node
/// in the lowest non-empty bucket — exactly the scan's
/// `min_by_key((in_flight, i))` contract. The cursor only moves down when
/// a node's occupancy drops, so its total forward travel is amortized by
/// the number of `set` calls: O(1) amortized per operation plus one
/// O(log N) ordered-set update.
#[derive(Debug)]
struct JsqIndex {
    /// Per-node in_flight mirror.
    occ: Vec<u64>,
    /// Nodes by occupancy; grown lazily (admission bounds may be u64::MAX,
    /// so the vec tracks the highest occupancy actually seen).
    buckets: Vec<BTreeSet<usize>>,
    /// No non-empty bucket exists below this index.
    min_occ: usize,
}

impl JsqIndex {
    fn new(n: usize) -> Self {
        Self {
            occ: vec![0; n],
            buckets: vec![(0..n).collect()],
            min_occ: 0,
        }
    }

    fn set(&mut self, i: usize, occ: u64) {
        let old = self.occ[i] as usize;
        let new = occ as usize;
        if old == new {
            return;
        }
        self.buckets[old].remove(&i);
        if new >= self.buckets.len() {
            self.buckets.resize_with(new + 1, BTreeSet::new);
        }
        self.buckets[new].insert(i);
        self.occ[i] = occ;
        self.min_occ = self.min_occ.min(new);
    }

    fn best(&mut self) -> usize {
        while self.buckets[self.min_occ].is_empty() {
            // Cannot run off the end: every node sits in some bucket.
            self.min_occ += 1;
        }
        *self.buckets[self.min_occ]
            .first()
            .expect("cursor stopped at a non-empty bucket")
    }
}

/// Incremental least-work index. The routing signal is time-dependent —
/// `backlog(now) = max(next_free - now, 0) + queue_len * interval` — so a
/// single static order would go stale as `now` advances. Decompose by the
/// max: a node is *ready* once its pipeline has caught up
/// (`next_free <= now`, backlog is the constant `c = queue_len *
/// interval`) and *lagging* before that (backlog is `(next_free + c) -
/// now`, a shared `-now` shift that preserves order). Each group is kept
/// in its own ordered set — ready by `(c, i)`, lagging by `(next_free + c,
/// i)` — and a migration min-heap keyed by `next_free` moves nodes from
/// lagging to ready lazily as `now` passes them (stale heap entries are
/// skipped via per-node stamps). `best` compares the two group minima on
/// the common `(backlog, i)` key, reproducing the scan's
/// `min_by_key((backlog(now), i))` bit for bit.
#[derive(Debug)]
struct LwIndex {
    interval: u64,
    /// Nodes with `next_free <= now`, ordered by `(c, i)`.
    ready: BTreeSet<(u64, usize)>,
    /// Nodes with `next_free > now`, ordered by `(next_free + c, i)`.
    lagging: BTreeSet<(u64, usize)>,
    /// Pending lagging->ready migrations `(next_free, stamp, i)`; entries
    /// whose stamp no longer matches the node's are skipped.
    migrations: BinaryHeap<Reverse<(u64, u64, usize)>>,
    keys: Vec<LwKey>,
}

#[derive(Debug, Clone, Copy)]
struct LwKey {
    nf: u64,
    c: u64,
    stamp: u64,
    lagging: bool,
}

impl LwIndex {
    fn new(n: usize, interval: u64) -> Self {
        Self {
            interval,
            ready: (0..n).map(|i| (0, i)).collect(),
            lagging: BTreeSet::new(),
            migrations: BinaryHeap::new(),
            keys: vec![
                LwKey {
                    nf: 0,
                    c: 0,
                    stamp: 0,
                    lagging: false
                };
                n
            ],
        }
    }

    fn set(&mut self, i: usize, nf: u64, queue_len: u64, now: u64) {
        let c = queue_len * self.interval;
        let k = self.keys[i];
        if k.nf == nf && k.c == c {
            // Unchanged inputs (e.g. a completion event): membership may
            // still need a lagging->ready migration, but the pending heap
            // entry handles that lazily in `best`.
            return;
        }
        if k.lagging {
            self.lagging.remove(&(k.nf + k.c, i));
        } else {
            self.ready.remove(&(k.c, i));
        }
        let stamp = k.stamp + 1;
        if nf > now {
            self.lagging.insert((nf + c, i));
            self.migrations.push(Reverse((nf, stamp, i)));
            self.keys[i] = LwKey {
                nf,
                c,
                stamp,
                lagging: true,
            };
        } else {
            self.ready.insert((c, i));
            self.keys[i] = LwKey {
                nf,
                c,
                stamp,
                lagging: false,
            };
        }
    }

    fn best(&mut self, now: u64) -> usize {
        // Migrate every node whose pipeline caught up (`next_free <= now`)
        // out of the time-shifted lagging order. Each node enters the
        // migration heap at most once per `set`, so this drain is
        // amortized O(log N) per index update.
        while let Some(&Reverse((nf, stamp, i))) = self.migrations.peek() {
            if nf > now {
                break;
            }
            self.migrations.pop();
            let k = self.keys[i];
            if k.stamp == stamp && k.lagging {
                self.lagging.remove(&(k.nf + k.c, i));
                self.ready.insert((k.c, i));
                self.keys[i].lagging = false;
            }
        }
        let ready = self.ready.first().map(|&(c, i)| (c, i));
        let lag = self.lagging.first().map(|&(s, i)| (s - now, i));
        match (ready, lag) {
            // `(backlog, i)` tuple order settles ties to the lowest index;
            // a node is in exactly one set, so keys never fully collide.
            (Some(a), Some(b)) => {
                if a <= b {
                    a.1
                } else {
                    b.1
                }
            }
            (Some(a), None) => a.1,
            (None, Some(b)) => b.1,
            (None, None) => unreachable!("non-empty fleet"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::ReplicationPlan;

    fn model() -> NodeModel {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        NodeModel::from_workload(&net, &arch, &plan).unwrap()
    }

    fn light_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            rate_per_cycle: 1e-4, // well under 2 nodes x 1/3136
            horizon_cycles: 1_000_000,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn light_load_completes_everything() {
        let s = simulate(&model(), &light_cfg());
        assert!(s.offered > 50, "horizon should produce arrivals");
        assert_eq!(s.completed + s.rejected, s.offered);
        assert_eq!(s.rejected, 0, "light load must not reject");
        assert!(s.latency.p50() >= model().fill, "fill is a lower bound");
        assert!(s.mean_utilization() > 0.0 && s.mean_utilization() < 0.5);
    }

    #[test]
    fn identical_seeds_are_bit_identical() {
        let a = simulate(&model(), &light_cfg());
        let b = simulate(&model(), &light_cfg());
        assert_eq!(a.latency.p50(), b.latency.p50());
        assert_eq!(a.latency.p999(), b.latency.p999());
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.node_utilization, b.node_utilization);
        let c = simulate(
            &model(),
            &ClusterConfig {
                seed: 999,
                ..light_cfg()
            },
        );
        assert_ne!(a.offered, 0);
        assert_ne!(
            (a.offered, a.latency.p50()),
            (c.offered, c.latency.p50()),
            "a different seed should perturb the run"
        );
    }

    #[test]
    fn overload_rejects_but_conserves() {
        // 1 node at ~3x its capacity with a tight admission bound.
        let cfg = ClusterConfig {
            nodes: 1,
            rate_per_cycle: 3.0 / 3136.0,
            max_queue: 8,
            horizon_cycles: 2_000_000,
            ..ClusterConfig::default()
        };
        let s = simulate(&model(), &cfg);
        assert_eq!(s.completed + s.rejected, s.offered);
        assert!(s.rejected > 0, "overload must reject");
        assert!(s.rejection_rate() > 0.3, "rate {}", s.rejection_rate());
        // The one node saturates: utilization near 1.
        assert!(s.node_utilization[0] > 0.9, "{}", s.node_utilization[0]);
        assert!(!s.meets_slo(u64::MAX), "rejections fail any SLO");
    }

    #[test]
    fn routing_policies_all_conserve_and_jsq_balances() {
        let mut spread = Vec::new();
        for route in RoutePolicy::ALL {
            let cfg = ClusterConfig {
                nodes: 4,
                rate_per_cycle: 8e-4,
                route,
                horizon_cycles: 1_000_000,
                ..ClusterConfig::default()
            };
            let s = simulate(&model(), &cfg);
            assert_eq!(s.completed + s.rejected, s.offered, "{}", route.name());
            let total: u64 = s.per_node_completed.iter().sum();
            assert_eq!(total, s.completed, "{}", route.name());
            let max = *s.per_node_completed.iter().max().unwrap() as f64;
            let min = *s.per_node_completed.iter().min().unwrap() as f64;
            spread.push(max - min);
        }
        // Load-aware routing should not be wildly worse-balanced than rr
        // (rr is balanced by construction; jsq's index tie-break gives the
        // low nodes a small edge whenever the fleet drains).
        assert!(spread[1] <= spread[0] + 64.0, "jsq spread {spread:?}");
    }

    #[test]
    fn trace_replay_drives_exact_arrivals() {
        let cfg = ClusterConfig {
            nodes: 1,
            pattern: ArrivalProcess::Trace(vec![0, 10_000, 500_000]),
            policy: BatchPolicy {
                sizes: vec![1],
                max_wait: 0,
                min_fill: 1.0,
            },
            horizon_cycles: 1_000_000,
            ..ClusterConfig::default()
        };
        let m = model();
        let s = simulate(&m, &cfg);
        assert_eq!(s.offered, 3);
        assert_eq!(s.completed, 3);
        // Request 0 and 2 hit an idle pipeline: latency == fill. Request 1
        // lands 10_000 cycles in, pipeline still busy until 3136 only —
        // idle again, latency == fill as well.
        assert_eq!(s.latency.p50(), m.fill);
        assert_eq!(s.latency.max(), m.fill);
        assert_eq!(s.queueing.max(), 0);
    }

    #[test]
    fn energy_accounting_rides_along() {
        let s = simulate(&model(), &light_cfg());
        let e = s.energy.expect("workload-built model carries energy");
        assert!(e.dynamic_j > 0.0 && e.idle_j > 0.0);
        assert!(e.total_j() > e.dynamic_j, "idle floor must add energy");
        assert!(e.joules_per_image() > 0.0);
        assert!(e.avg_power_w() > 0.0);
        // Light load on 2 nodes: a few watts of dynamic draw on top of the
        // 2-node always-on floor (~23.9 W), far below 2 peak envelopes.
        assert!((23.9..40.0).contains(&e.avg_power_w()), "{} W", e.avg_power_w());
        // Dynamic energy == injections x image energy, summed per node.
        let injected: u64 = s.per_node_injected.iter().sum();
        let img_mj = model().energy.unwrap().image_mj;
        assert!((e.dynamic_j - injected as f64 * img_mj * 1e-3).abs() < 1e-9);
        // Padding is a subset of dynamic energy.
        assert!(e.padding_waste_j >= 0.0 && e.padding_waste_j <= e.dynamic_j);
    }

    #[test]
    fn bare_shape_model_reports_no_energy() {
        let m = model();
        let bare = NodeModel::new(m.shape.clone());
        let s = simulate(&bare, &light_cfg());
        assert!(s.energy.is_none(), "no profile, no energy block");
        assert_eq!(s.completed + s.rejected, s.offered);
    }

    #[test]
    fn zero_arrivals_is_a_clean_empty_run() {
        let cfg = ClusterConfig {
            pattern: ArrivalProcess::Trace(vec![]),
            ..light_cfg()
        };
        let s = simulate(&model(), &cfg);
        assert_eq!(s.offered, 0);
        assert_eq!(s.completed, 0);
        assert_eq!(s.latency.count(), 0);
        assert_eq!(s.throughput_per_cycle(), 0.0);
    }

    #[test]
    fn effective_horizon_reflects_the_generation_span() {
        let m = model();
        // Horizon-bounded synthetic runs report the configured horizon.
        let s = simulate(&m, &light_cfg());
        assert_eq!(s.horizon_cycles, 1_000_000);
        // fixed_requests ignores the configured horizon entirely: report
        // the arrival extent (last arrival + 1) instead.
        let cfg = ClusterConfig {
            nodes: 1,
            fixed_requests: Some(5),
            horizon_cycles: 123, // would be nonsense to report
            ..ClusterConfig::default()
        };
        let last = *cfg
            .pattern
            .generate_n(cfg.rate_per_cycle, 5, cfg.seed)
            .last()
            .unwrap();
        let s = simulate(&m, &cfg);
        assert_eq!(s.horizon_cycles, last + 1);
        assert!(s.horizon_cycles > 123, "5 Poisson arrivals at 1e-4/cycle");
        // A trace only uses the horizon as an upper bound: report the
        // replayed extent when the trace ends first...
        let cfg = ClusterConfig {
            nodes: 1,
            pattern: ArrivalProcess::Trace(vec![0, 10_000, 500_000]),
            horizon_cycles: 1_000_000,
            ..ClusterConfig::default()
        };
        assert_eq!(simulate(&m, &cfg).horizon_cycles, 500_001);
        // ...and the horizon when it cuts the trace short.
        let cfg = ClusterConfig {
            nodes: 1,
            pattern: ArrivalProcess::Trace(vec![0, 10_000, 500_000]),
            horizon_cycles: 200_000,
            ..ClusterConfig::default()
        };
        assert_eq!(simulate(&m, &cfg).horizon_cycles, 10_001);
        // An empty run spans nothing.
        let cfg = ClusterConfig {
            pattern: ArrivalProcess::Trace(vec![]),
            ..light_cfg()
        };
        assert_eq!(simulate(&m, &cfg).horizon_cycles, 0);
    }

    #[test]
    fn indexed_and_scan_routing_are_bit_identical_smoke() {
        // Quick in-crate check (the full random-mix property lives in
        // tests/prop_cluster_perf.rs): saturating load over both
        // load-aware policies, every stat equal.
        let m = model();
        for route in [RoutePolicy::ShortestQueue, RoutePolicy::LeastWork] {
            let cfg = ClusterConfig {
                nodes: 5,
                rate_per_cycle: 7.0 / 3136.0,
                route,
                max_queue: 6,
                horizon_cycles: 1_500_000,
                ..ClusterConfig::default()
            };
            let a = simulate(&m, &cfg);
            let b = simulate(
                &m,
                &ClusterConfig {
                    route_impl: RouteImpl::LinearScan,
                    ..cfg
                },
            );
            assert_eq!(a.offered, b.offered, "{}", route.name());
            assert_eq!(a.rejected, b.rejected, "{}", route.name());
            assert_eq!(a.drained_at, b.drained_at, "{}", route.name());
            assert_eq!(a.latency.mean(), b.latency.mean(), "{}", route.name());
            assert_eq!(a.per_node_completed, b.per_node_completed, "{}", route.name());
            assert_eq!(a.per_node_injected, b.per_node_injected, "{}", route.name());
            assert_eq!(a.node_utilization, b.node_utilization, "{}", route.name());
            assert_eq!(a.events_processed, b.events_processed, "{}", route.name());
            assert_eq!(a.peak_calendar_depth, b.peak_calendar_depth, "{}", route.name());
        }
    }

    #[test]
    fn deadline_suppression_bounds_the_calendar() {
        // Overload a hoarding fleet: without suppression every service
        // call would stack another Deadline entry. With at most one live
        // deadline per node, peak depth is bounded by 1 pending arrival +
        // per-node completions (<= max_queue) + live deadlines (<= 1) +
        // superseded strays (<= in-flight batches <= max_queue; max_wait
        // is far below the pipeline fill, so strays expire before their
        // batch completes).
        let m = model();
        let (nodes, max_queue) = (2u64, 8u64);
        let cfg = ClusterConfig {
            nodes: nodes as usize,
            rate_per_cycle: 3.0 * nodes as f64 / 3136.0,
            route: RoutePolicy::ShortestQueue,
            max_queue,
            horizon_cycles: 800_000,
            policy: BatchPolicy {
                sizes: vec![4, 1],
                max_wait: 500,
                min_fill: 0.9,
            },
            ..ClusterConfig::default()
        };
        let s = simulate(&m, &cfg);
        assert!(s.offered > 1_000, "overload run should be busy");
        let bound = 1 + nodes + 2 * nodes * max_queue;
        assert!(
            s.peak_calendar_depth <= bound,
            "peak {} exceeds the suppression bound {bound}",
            s.peak_calendar_depth
        );
        assert!(s.events_processed >= s.offered, "every arrival is an event");
    }

    #[test]
    fn route_impl_parses() {
        assert_eq!("indexed".parse::<RouteImpl>().unwrap(), RouteImpl::Indexed);
        assert_eq!("scan".parse::<RouteImpl>().unwrap(), RouteImpl::LinearScan);
        assert_eq!(
            "linear-scan".parse::<RouteImpl>().unwrap(),
            RouteImpl::LinearScan
        );
        assert_eq!(RouteImpl::default(), RouteImpl::Indexed);
        assert_eq!(RouteImpl::Indexed.name(), "indexed");
        assert_eq!(RouteImpl::LinearScan.name(), "scan");
        assert!("btree".parse::<RouteImpl>().is_err());
    }

    #[test]
    fn metrics_registry_mirrors_the_legacy_gauges() {
        let s = simulate(&model(), &light_cfg());
        let m = &s.metrics;
        assert_eq!(m.counter("cluster.events.processed"), s.events_processed);
        assert_eq!(
            m.gauge_value("cluster.calendar.peak_depth"),
            Some(s.peak_calendar_depth as f64)
        );
        assert_eq!(m.counter("cluster.events.arrival"), s.offered);
        assert_eq!(m.counter("cluster.events.completion"), s.completed);
        assert_eq!(m.counter("cluster.events.rejected"), s.rejected);
        // Per-kind counts partition the calendar pops exactly.
        assert_eq!(
            m.counter("cluster.events.arrival")
                + m.counter("cluster.events.completion")
                + m.counter("cluster.events.deadline_live")
                + m.counter("cluster.events.deadline_stale"),
            s.events_processed
        );
        // Every completed request was released by exactly one batch form.
        let h = m.histogram("cluster.batch.released").expect("batches formed");
        assert_eq!(h.sum(), s.completed as u128);
    }

    #[test]
    fn recording_sink_covers_three_subsystems_without_perturbing_stats() {
        use crate::obs::trace::RecordingSink;
        let base = simulate(&model(), &light_cfg());
        let mut sink = RecordingSink::new();
        let traced = simulate_with_sink(&model(), &light_cfg(), &mut sink);
        // The full cross-sink parity matrix lives in tests/obs_parity.rs;
        // this is the in-crate smoke.
        assert_eq!(base.offered, traced.offered);
        assert_eq!(base.drained_at, traced.drained_at);
        assert_eq!(base.latency.p999(), traced.latency.p999());
        assert_eq!(base.node_utilization, traced.node_utilization);
        assert_eq!(base.metrics, traced.metrics);
        for sub in ["cluster.route", "cluster.batch", "cluster.node"] {
            assert!(!sink.events_for(sub).is_empty(), "no {sub} events");
        }
        // One service span and one complete instant per completion.
        let spans = sink
            .events_for("cluster.node")
            .iter()
            .filter(|e| e.name == "service")
            .count();
        assert_eq!(spans as u64, traced.completed);
    }

    #[test]
    fn route_policy_parses() {
        assert_eq!("rr".parse::<RoutePolicy>().unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            "jsq".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::ShortestQueue
        );
        assert_eq!(
            "least-work".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::LeastWork
        );
        assert!("random".parse::<RoutePolicy>().is_err());
    }
}
