//! Serving statistics for cluster runs: latency percentiles, throughput,
//! per-node utilization, rejection rate — the SLO surface a capacity
//! planner bisects against — plus the run's [`MetricsRegistry`] block.

use crate::obs::metrics::{LogHistogram, MetricsRegistry};
use crate::util::Json;

/// Sample count above which [`LatencySummary`] switches from exact
/// storage to the streaming [`LogHistogram`] sketch. At or below the cap
/// every percentile is exact (bit-identical to the historical
/// store-everything summary); above it memory stays bounded (~2k buckets)
/// at the cost of ≤[`crate::obs::metrics::ALPHA`] (1%) relative error on
/// percentiles — `count`, `mean`, and `max` stay exact in both modes.
/// 256Ki samples ≈ 2 MiB per summary, comfortably under any bench
/// scenario today; fleet-year horizons blow past it.
pub const EXACT_SAMPLE_CAP: usize = 262_144;

/// Latency percentiles over a sample set: exact below
/// [`EXACT_SAMPLE_CAP`], streaming log-histogram sketch above (see the
/// cap's docs for the error contract).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    /// All per-request latencies in cycles, sorted ascending (exact mode;
    /// empty in sketch mode).
    sorted: Vec<u64>,
    /// Bounded-memory sketch (sketch mode only).
    sketch: Option<LogHistogram>,
}

impl LatencySummary {
    /// Summarize a sample set (takes ownership; sorts once). Switches to
    /// the sketch above [`EXACT_SAMPLE_CAP`].
    pub fn from_samples(samples: Vec<u64>) -> Self {
        Self::from_samples_with_cap(samples, EXACT_SAMPLE_CAP)
    }

    /// [`Self::from_samples`] with an explicit exact-storage cap — the
    /// error-band tests force the sketch on small sets with this.
    pub fn from_samples_with_cap(mut samples: Vec<u64>, cap: usize) -> Self {
        if samples.len() > cap {
            let mut h = LogHistogram::new();
            for &v in &samples {
                h.observe(v);
            }
            return Self {
                sorted: Vec::new(),
                sketch: Some(h),
            };
        }
        samples.sort_unstable();
        Self {
            sorted: samples,
            sketch: None,
        }
    }

    /// True when the summary holds the bounded sketch instead of the
    /// exact sample set.
    pub fn is_sketched(&self) -> bool {
        self.sketch.is_some()
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        match &self.sketch {
            Some(h) => h.count() as usize,
            None => self.sorted.len(),
        }
    }

    /// Percentile by the nearest-rank method (`p` in (0, 100]): the
    /// smallest sample such that at least `p`% of samples are <= it.
    /// Exact in exact mode; within 1% relative error in sketch mode.
    /// 0 for an empty summary.
    pub fn percentile(&self, p: f64) -> u64 {
        if let Some(h) = &self.sketch {
            return h.percentile(p);
        }
        if self.sorted.is_empty() {
            return 0;
        }
        debug_assert!(p > 0.0 && p <= 100.0);
        let n = self.sorted.len();
        let rank = (p / 100.0 * n as f64).ceil() as usize;
        self.sorted[rank.clamp(1, n) - 1]
    }

    /// Median latency in cycles.
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 95th percentile in cycles.
    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    /// 99th percentile in cycles.
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// 99.9th percentile in cycles.
    pub fn p999(&self) -> u64 {
        self.percentile(99.9)
    }

    /// Arithmetic mean in cycles (exact in both modes; 0 when empty).
    pub fn mean(&self) -> f64 {
        if let Some(h) = &self.sketch {
            return h.mean();
        }
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sorted.iter().map(|&x| x as u128).sum::<u128>() as f64 / self.sorted.len() as f64
    }

    /// Largest sample (exact in both modes; 0 when empty).
    pub fn max(&self) -> u64 {
        match &self.sketch {
            Some(h) => h.max(),
            None => self.sorted.last().copied().unwrap_or(0),
        }
    }
}

/// Fleet-wide energy accounting over one simulated run (present when the
/// [`NodeModel`] carried an [`EnergyProfile`], i.e. was built from a real
/// workload). Semantics (DESIGN.md §5): every allocated replica burns the
/// always-on node idle floor (eDRAM refresh + routers never power-gate)
/// over the whole span; each pipeline injection — real or padding — adds
/// one image's dynamic energy on top, so a busy node always draws more
/// than an idle one; padding injections are pure waste (their outputs
/// are discarded).
///
/// [`NodeModel`]: super::node::NodeModel
/// [`EnergyProfile`]: super::node::EnergyProfile
#[derive(Debug, Clone, Copy)]
pub struct FleetEnergy {
    /// Dynamic (above-floor) energy of all pipeline injections, real +
    /// padding (J). Identity pinned by `tests/golden_energy.rs`: this
    /// equals Σ_node utilization x active power x span — the "fleet
    /// dynamic energy = per-node utilization x active power" conservation
    /// law.
    pub dynamic_j: f64,
    /// Always-on floor energy of the whole fleet over the full span (J):
    /// fleet size x span x idle power, burned whether or not a replica
    /// serves traffic.
    pub idle_j: f64,
    /// The subset of `dynamic_j` spent on padding injections (J) — batches
    /// padded to an executable size occupy real pipeline slots whose
    /// outputs are discarded.
    pub padding_waste_j: f64,
    /// ReRAM weight-programming energy of all model swaps (J): every
    /// reprogram-on-miss swap pays its tenant's full
    /// [`WriteCost::energy_j`](crate::power::WriteCost) footprint. Zero
    /// for single-tenant runs and partitioned fleets (weights are
    /// programmed once, off the measured span).
    pub weight_writes_j: f64,
    /// Simulated span in wall seconds (the utilization span: last
    /// completion or last reserved bottleneck slot).
    pub span_s: f64,
    /// Crossbar operations completed (completed images x ops/image).
    pub completed_ops: u64,
    /// Completed images (the joules-per-image denominator).
    pub completed: u64,
}

impl FleetEnergy {
    /// Total fleet energy: dynamic + idle + weight writes (J). The
    /// three-way split is exact — `tests/prop_tenant.rs` pins the
    /// conservation identity.
    pub fn total_j(&self) -> f64 {
        self.dynamic_j + self.idle_j + self.weight_writes_j
    }

    /// Joules per completed image, idle floor included (0 when nothing
    /// completed — an empty run burned idle energy for no images, which
    /// has no meaningful per-image cost).
    pub fn joules_per_image(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.total_j() / self.completed as f64
    }

    /// Average fleet power over the simulated span (W); 0 for a zero span.
    pub fn avg_power_w(&self) -> f64 {
        if self.span_s <= 0.0 {
            return 0.0;
        }
        self.total_j() / self.span_s
    }

    /// Fleet-level energy efficiency: completed crossbar tera-ops per
    /// watt. Unlike the single-node Fig. 9 number this includes the idle
    /// floor and padding waste, so it is bounded above by the workload's
    /// dynamic-only TOPS/W and degrades as the fleet idles. 0 when no
    /// energy was burned.
    pub fn tops_per_watt(&self) -> f64 {
        let j = self.total_j();
        if j <= 0.0 {
            return 0.0;
        }
        self.completed_ops as f64 / j / 1e12
    }

    /// Machine-readable form (merged into [`ClusterStats::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("energy_dynamic_j", self.dynamic_j.into()),
            ("energy_idle_j", self.idle_j.into()),
            ("energy_padding_waste_j", self.padding_waste_j.into()),
            ("energy_weight_writes_j", self.weight_writes_j.into()),
            ("energy_total_j", self.total_j().into()),
            ("joules_per_image", self.joules_per_image().into()),
            ("avg_power_w", self.avg_power_w().into()),
            ("fleet_tops_per_watt", self.tops_per_watt().into()),
        ])
    }
}

/// Everything a cluster simulation reports.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Requests the arrival process offered.
    pub offered: u64,
    /// Requests completed (served to the end of the pipeline).
    pub completed: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// *Effective* arrival-generation span in cycles: the configured
    /// horizon for horizon-bounded synthetic runs, the arrival extent
    /// (last arrival + 1, or 0 when empty) under `fixed_requests`, and the
    /// smaller of the two for trace replays — the configured horizon is
    /// ignored or only an upper bound in those modes, so reporting it
    /// verbatim would misstate the span (pinned by
    /// `effective_horizon_reflects_the_generation_span` in `sim.rs`).
    pub horizon_cycles: u64,
    /// Cycle of the last completion (the drain point; >= horizon under
    /// load). 0 when nothing completed.
    pub drained_at: u64,
    /// Calendar events processed (arrivals + completions + deadline
    /// fires, stale ones included) — the denominator of the events/sec
    /// throughput the scaling bench reports.
    pub events_processed: u64,
    /// High-water mark of the calendar heap. Deadline suppression plus
    /// streamed arrivals bound this by fleet size + in-flight batches + 1
    /// instead of growing with the horizon
    /// (`tests/prop_cluster_perf.rs` pins the bound).
    pub peak_calendar_depth: u64,
    /// End-to-end latency (arrival -> pipeline completion) in cycles.
    pub latency: LatencySummary,
    /// Queueing component only (arrival -> pipeline injection) in cycles.
    pub queueing: LatencySummary,
    /// Per-node bottleneck-stage busy fraction, in [0, 1], over the
    /// simulated span (last completion or last reserved pipeline slot,
    /// whichever is later).
    pub node_utilization: Vec<f64>,
    /// Per-node completed-request counts.
    pub per_node_completed: Vec<u64>,
    /// Per-node rejected-request counts.
    pub per_node_rejected: Vec<u64>,
    /// Per-node pipeline injections, real + padding (the energy model's
    /// dynamic-energy unit; `injected - completed` per node is padding).
    pub per_node_injected: Vec<u64>,
    /// Fleet energy accounting; `None` when the node model carried no
    /// [`EnergyProfile`](super::node::EnergyProfile).
    pub energy: Option<FleetEnergy>,
    /// Structured operation counters and distributions from the event
    /// loop (arrivals, rejections, deadline live/stale fires, batch-size
    /// histogram, ...), rendered as the `metrics` block in `--json`
    /// output. A pure function of the run: identical seeds give identical
    /// registries.
    pub metrics: MetricsRegistry,
}

impl ClusterStats {
    /// Completed requests per simulated cycle.
    pub fn throughput_per_cycle(&self) -> f64 {
        if self.drained_at == 0 {
            return 0.0;
        }
        self.completed as f64 / self.drained_at as f64
    }

    /// Completed requests per wall second at `logical_cycle_ns` per cycle.
    pub fn throughput_rps(&self, logical_cycle_ns: f64) -> f64 {
        self.throughput_per_cycle() / (logical_cycle_ns * 1e-9)
    }

    /// Fraction of offered requests rejected by admission control.
    pub fn rejection_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.offered as f64
    }

    /// Mean utilization across the fleet.
    pub fn mean_utilization(&self) -> f64 {
        crate::util::stats::mean(&self.node_utilization)
    }

    /// The run meets an SLO of `p99 <= target cycles` with zero rejections.
    /// Rejections count against the SLO (a dropped request is an infinite
    /// latency), so any rejection fails the point.
    pub fn meets_slo(&self, p99_target_cycles: u64) -> bool {
        self.rejected == 0 && self.completed > 0 && self.latency.p99() <= p99_target_cycles
    }

    /// Machine-readable form (BENCH_cluster.json rows, `cluster --json`).
    /// Fleet-energy fields ride along when energy accounting ran.
    pub fn to_json(&self, logical_cycle_ns: f64) -> Json {
        let mut doc = Json::obj(vec![
            ("offered", self.offered.into()),
            ("completed", self.completed.into()),
            ("rejected", self.rejected.into()),
            ("rejection_rate", self.rejection_rate().into()),
            ("horizon_cycles", self.horizon_cycles.into()),
            ("drained_at", self.drained_at.into()),
            ("events_processed", self.events_processed.into()),
            ("peak_calendar_depth", self.peak_calendar_depth.into()),
            ("throughput_rps", self.throughput_rps(logical_cycle_ns).into()),
            ("latency_mean_cycles", self.latency.mean().into()),
            ("latency_p50_cycles", self.latency.p50().into()),
            ("latency_p95_cycles", self.latency.p95().into()),
            ("latency_p99_cycles", self.latency.p99().into()),
            ("latency_p999_cycles", self.latency.p999().into()),
            ("latency_max_cycles", self.latency.max().into()),
            ("queueing_p99_cycles", self.queueing.p99().into()),
            ("mean_utilization", self.mean_utilization().into()),
            (
                "node_utilization",
                Json::Arr(self.node_utilization.iter().map(|&u| u.into()).collect()),
            ),
            (
                "per_node_completed",
                Json::Arr(self.per_node_completed.iter().map(|&c| c.into()).collect()),
            ),
            (
                "per_node_injected",
                Json::Arr(self.per_node_injected.iter().map(|&c| c.into()).collect()),
            ),
        ]);
        if let (Json::Obj(pairs), Some(e)) = (&mut doc, &self.energy) {
            if let Json::Obj(extra) = e.to_json() {
                pairs.extend(extra);
            }
        }
        if !self.metrics.is_empty() {
            if let Json::Obj(pairs) = &mut doc {
                pairs.push(("metrics".to_string(), self.metrics.to_json()));
            }
        }
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        // 1..=100: pN is exactly N by nearest rank.
        let s = LatencySummary::from_samples((1..=100).rev().collect());
        assert_eq!(s.p50(), 50);
        assert_eq!(s.p95(), 95);
        assert_eq!(s.p99(), 99);
        assert_eq!(s.p999(), 100);
        assert_eq!(s.percentile(1.0), 1);
        assert_eq!(s.max(), 100);
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let s = LatencySummary::from_samples(vec![42]);
        assert_eq!(s.p50(), 42);
        assert_eq!(s.p999(), 42);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = LatencySummary::from_samples(vec![]);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.count(), 0);
        assert!(!s.is_sketched());
    }

    #[test]
    fn sketch_mode_stays_within_the_error_band() {
        // Force the sketch on a sample set small enough to also keep
        // exactly, and check every promised bound: count/mean/max exact,
        // percentiles within ALPHA relative error of the exact
        // nearest-rank answer.
        use crate::util::Rng;
        let mut rng = Rng::new(0xC1A5_51C);
        let samples: Vec<u64> = (0..20_000).map(|_| rng.below(2_000_000)).collect();
        let exact = LatencySummary::from_samples(samples.clone());
        let sketched = LatencySummary::from_samples_with_cap(samples, 1_000);
        assert!(sketched.is_sketched() && !exact.is_sketched());
        assert_eq!(sketched.count(), exact.count());
        assert_eq!(sketched.max(), exact.max());
        assert!((sketched.mean() - exact.mean()).abs() < 1e-9);
        for p in [50.0, 95.0, 99.0, 99.9] {
            let (e, s) = (exact.percentile(p), sketched.percentile(p));
            let rel = (s as f64 - e as f64).abs() / (e as f64).max(1.0);
            assert!(
                rel <= crate::obs::metrics::ALPHA + 1e-9,
                "p{p}: exact {e} sketch {s} rel {rel}"
            );
        }
    }

    #[test]
    fn cap_boundary_is_exact_inclusive() {
        // Exactly at the cap stays exact; one past switches to the sketch.
        let at = LatencySummary::from_samples_with_cap((0..100).collect(), 100);
        let over = LatencySummary::from_samples_with_cap((0..101).collect(), 100);
        assert!(!at.is_sketched());
        assert!(over.is_sketched());
        assert_eq!(over.count(), 101);
    }

    fn stats() -> ClusterStats {
        ClusterStats {
            offered: 10,
            completed: 8,
            rejected: 2,
            horizon_cycles: 1000,
            drained_at: 2000,
            events_processed: 30,
            peak_calendar_depth: 5,
            latency: LatencySummary::from_samples(vec![10, 20, 30, 40, 50, 60, 70, 80]),
            queueing: LatencySummary::from_samples(vec![0; 8]),
            node_utilization: vec![0.5, 0.7],
            per_node_completed: vec![4, 4],
            per_node_rejected: vec![1, 1],
            per_node_injected: vec![5, 5],
            energy: None,
            metrics: MetricsRegistry::new(),
        }
    }

    #[test]
    fn throughput_and_rejection() {
        let s = stats();
        assert_eq!(s.throughput_per_cycle(), 8.0 / 2000.0);
        assert_eq!(s.rejection_rate(), 0.2);
        assert!((s.mean_utilization() - 0.6).abs() < 1e-12);
        // 306 ns cycles: rps = per-cycle / 306e-9.
        let rps = s.throughput_rps(306.0);
        assert!((rps - (8.0 / 2000.0) / 306e-9).abs() < 1e-6);
    }

    #[test]
    fn slo_counts_rejections_as_failures() {
        let mut s = stats();
        assert!(!s.meets_slo(1_000_000), "rejections must fail the SLO");
        s.rejected = 0;
        assert!(s.meets_slo(80));
        assert!(!s.meets_slo(79), "p99 is 80");
    }

    #[test]
    fn json_renders_key_fields() {
        let j = stats().to_json(306.0).render();
        assert!(j.contains("\"latency_p99_cycles\":80"), "{j}");
        assert!(j.contains("\"rejected\":2"), "{j}");
        assert!(j.contains("\"events_processed\":30"), "{j}");
        assert!(j.contains("\"peak_calendar_depth\":5"), "{j}");
        assert!(j.contains("\"node_utilization\""), "{j}");
        assert!(j.contains("\"per_node_injected\""), "{j}");
        assert!(!j.contains("energy_total_j"), "no profile, no energy: {j}");
        assert!(!j.contains("\"metrics\""), "empty registry is omitted: {j}");
    }

    #[test]
    fn json_appends_metrics_block_when_present() {
        let mut s = stats();
        s.metrics.incr("cluster.events.arrival", 10);
        s.metrics.observe("cluster.batch.size", 4);
        let j = s.to_json(306.0).render();
        assert!(j.contains("\"metrics\""), "{j}");
        assert!(j.contains("\"cluster.events.arrival\":10"), "{j}");
        assert!(j.contains("\"cluster.batch.size\""), "{j}");
    }

    fn energy() -> FleetEnergy {
        FleetEnergy {
            dynamic_j: 8.0,
            idle_j: 2.0,
            padding_waste_j: 0.5,
            weight_writes_j: 0.0,
            span_s: 4.0,
            completed_ops: 100 * 39_300_000_000,
            completed: 100,
        }
    }

    #[test]
    fn fleet_energy_derived_quantities() {
        let e = energy();
        assert_eq!(e.total_j(), 10.0);
        assert_eq!(e.joules_per_image(), 0.1);
        assert_eq!(e.avg_power_w(), 2.5);
        // 3.93e12 ops / 10 J / 1e12 = 0.393 TOPS/W.
        assert!((e.tops_per_watt() - 0.393).abs() < 1e-9);
    }

    #[test]
    fn fleet_energy_guards_empty_runs() {
        let mut e = energy();
        e.completed = 0;
        e.completed_ops = 0;
        assert_eq!(e.joules_per_image(), 0.0, "no images, no per-image cost");
        assert_eq!(e.tops_per_watt(), 0.0);
        e.span_s = 0.0;
        assert_eq!(e.avg_power_w(), 0.0, "zero span must not divide");
        e.dynamic_j = 0.0;
        e.idle_j = 0.0;
        assert_eq!(e.tops_per_watt(), 0.0, "zero energy must not divide");
    }

    #[test]
    fn weight_writes_add_into_total() {
        let mut e = energy();
        e.weight_writes_j = 1.5;
        assert_eq!(e.total_j(), 11.5);
        let j = e.to_json().render();
        assert!(j.contains("\"energy_weight_writes_j\":1.5"), "{j}");
    }

    #[test]
    fn json_includes_energy_when_present() {
        let mut s = stats();
        s.energy = Some(energy());
        let j = s.to_json(306.0).render();
        assert!(j.contains("\"energy_total_j\":10"), "{j}");
        assert!(j.contains("\"energy_padding_waste_j\":0.5"), "{j}");
        assert!(j.contains("\"fleet_tops_per_watt\""), "{j}");
        assert!(j.contains("\"avg_power_w\":2.5"), "{j}");
    }
}
