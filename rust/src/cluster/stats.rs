//! Serving statistics for cluster runs: exact latency percentiles,
//! throughput, per-node utilization, rejection rate — the SLO surface a
//! capacity planner bisects against.

use crate::util::Json;

/// Exact latency percentiles over the full sample set (no sketches: a
/// cluster run holds every completion anyway, and SLO math on p999 cannot
/// afford approximation error).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    /// All per-request latencies in cycles, sorted ascending.
    sorted: Vec<u64>,
}

impl LatencySummary {
    /// Summarize a sample set (takes ownership; sorts once).
    pub fn from_samples(mut samples: Vec<u64>) -> Self {
        samples.sort_unstable();
        Self { sorted: samples }
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.sorted.len()
    }

    /// Exact percentile by the nearest-rank method (`p` in (0, 100]):
    /// the smallest sample such that at least `p`% of samples are <= it.
    /// 0 for an empty summary.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.sorted.is_empty() {
            return 0;
        }
        debug_assert!(p > 0.0 && p <= 100.0);
        let n = self.sorted.len();
        let rank = (p / 100.0 * n as f64).ceil() as usize;
        self.sorted[rank.clamp(1, n) - 1]
    }

    /// Median latency in cycles.
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 95th percentile in cycles.
    pub fn p95(&self) -> u64 {
        self.percentile(95.0)
    }

    /// 99th percentile in cycles.
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// 99.9th percentile in cycles.
    pub fn p999(&self) -> u64 {
        self.percentile(99.9)
    }

    /// Arithmetic mean in cycles (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sorted.iter().map(|&x| x as u128).sum::<u128>() as f64 / self.sorted.len() as f64
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.sorted.last().copied().unwrap_or(0)
    }
}

/// Everything a cluster simulation reports.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Requests the arrival process offered.
    pub offered: u64,
    /// Requests completed (served to the end of the pipeline).
    pub completed: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Cycles of simulated arrival horizon.
    pub horizon_cycles: u64,
    /// Cycle of the last completion (the drain point; >= horizon under
    /// load). 0 when nothing completed.
    pub drained_at: u64,
    /// End-to-end latency (arrival -> pipeline completion) in cycles.
    pub latency: LatencySummary,
    /// Queueing component only (arrival -> pipeline injection) in cycles.
    pub queueing: LatencySummary,
    /// Per-node bottleneck-stage busy fraction, in [0, 1], over the
    /// simulated span (last completion or last reserved pipeline slot,
    /// whichever is later).
    pub node_utilization: Vec<f64>,
    /// Per-node completed-request counts.
    pub per_node_completed: Vec<u64>,
    /// Per-node rejected-request counts.
    pub per_node_rejected: Vec<u64>,
}

impl ClusterStats {
    /// Completed requests per simulated cycle.
    pub fn throughput_per_cycle(&self) -> f64 {
        if self.drained_at == 0 {
            return 0.0;
        }
        self.completed as f64 / self.drained_at as f64
    }

    /// Completed requests per wall second at `logical_cycle_ns` per cycle.
    pub fn throughput_rps(&self, logical_cycle_ns: f64) -> f64 {
        self.throughput_per_cycle() / (logical_cycle_ns * 1e-9)
    }

    /// Fraction of offered requests rejected by admission control.
    pub fn rejection_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.offered as f64
    }

    /// Mean utilization across the fleet.
    pub fn mean_utilization(&self) -> f64 {
        crate::util::stats::mean(&self.node_utilization)
    }

    /// The run meets an SLO of `p99 <= target cycles` with zero rejections.
    /// Rejections count against the SLO (a dropped request is an infinite
    /// latency), so any rejection fails the point.
    pub fn meets_slo(&self, p99_target_cycles: u64) -> bool {
        self.rejected == 0 && self.completed > 0 && self.latency.p99() <= p99_target_cycles
    }

    /// Machine-readable form (BENCH_cluster.json rows, `cluster --json`).
    pub fn to_json(&self, logical_cycle_ns: f64) -> Json {
        Json::obj(vec![
            ("offered", self.offered.into()),
            ("completed", self.completed.into()),
            ("rejected", self.rejected.into()),
            ("rejection_rate", self.rejection_rate().into()),
            ("horizon_cycles", self.horizon_cycles.into()),
            ("drained_at", self.drained_at.into()),
            ("throughput_rps", self.throughput_rps(logical_cycle_ns).into()),
            ("latency_mean_cycles", self.latency.mean().into()),
            ("latency_p50_cycles", self.latency.p50().into()),
            ("latency_p95_cycles", self.latency.p95().into()),
            ("latency_p99_cycles", self.latency.p99().into()),
            ("latency_p999_cycles", self.latency.p999().into()),
            ("latency_max_cycles", self.latency.max().into()),
            ("queueing_p99_cycles", self.queueing.p99().into()),
            ("mean_utilization", self.mean_utilization().into()),
            (
                "node_utilization",
                Json::Arr(self.node_utilization.iter().map(|&u| u.into()).collect()),
            ),
            (
                "per_node_completed",
                Json::Arr(self.per_node_completed.iter().map(|&c| c.into()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles_are_exact() {
        // 1..=100: pN is exactly N by nearest rank.
        let s = LatencySummary::from_samples((1..=100).rev().collect());
        assert_eq!(s.p50(), 50);
        assert_eq!(s.p95(), 95);
        assert_eq!(s.p99(), 99);
        assert_eq!(s.p999(), 100);
        assert_eq!(s.percentile(1.0), 1);
        assert_eq!(s.max(), 100);
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let s = LatencySummary::from_samples(vec![42]);
        assert_eq!(s.p50(), 42);
        assert_eq!(s.p999(), 42);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = LatencySummary::from_samples(vec![]);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.count(), 0);
    }

    fn stats() -> ClusterStats {
        ClusterStats {
            offered: 10,
            completed: 8,
            rejected: 2,
            horizon_cycles: 1000,
            drained_at: 2000,
            latency: LatencySummary::from_samples(vec![10, 20, 30, 40, 50, 60, 70, 80]),
            queueing: LatencySummary::from_samples(vec![0; 8]),
            node_utilization: vec![0.5, 0.7],
            per_node_completed: vec![4, 4],
            per_node_rejected: vec![1, 1],
        }
    }

    #[test]
    fn throughput_and_rejection() {
        let s = stats();
        assert_eq!(s.throughput_per_cycle(), 8.0 / 2000.0);
        assert_eq!(s.rejection_rate(), 0.2);
        assert!((s.mean_utilization() - 0.6).abs() < 1e-12);
        // 306 ns cycles: rps = per-cycle / 306e-9.
        let rps = s.throughput_rps(306.0);
        assert!((rps - (8.0 / 2000.0) / 306e-9).abs() < 1e-6);
    }

    #[test]
    fn slo_counts_rejections_as_failures() {
        let mut s = stats();
        assert!(!s.meets_slo(1_000_000), "rejections must fail the SLO");
        s.rejected = 0;
        assert!(s.meets_slo(80));
        assert!(!s.meets_slo(79), "p99 is 80");
    }

    #[test]
    fn json_renders_key_fields() {
        let j = stats().to_json(306.0).render();
        assert!(j.contains("\"latency_p99_cycles\":80"), "{j}");
        assert!(j.contains("\"rejected\":2"), "{j}");
        assert!(j.contains("\"node_utilization\""), "{j}");
    }
}
