//! Capacity planning: the minimum fleet size whose p99 end-to-end latency
//! meets an SLO target at the offered load.
//!
//! The search leans on a monotonicity invariant of the serving model
//! (pinned by `tests/prop_cluster.rs`): with the arrival stream held
//! fixed (same seed), per-request waits are non-increasing in fleet size,
//! so "meets the SLO" is a monotone predicate over `nodes` and section
//! search applies. Each probe is a full [`simulate`] run; probes within a
//! round are independent, so they fan out on [`SweepRunner`]. Probes
//! clone `base` (default [`RouteImpl::Indexed`](super::RouteImpl)), so
//! the planner inherits the flattened event loop's speed for free —
//! 10k-node probe points finish in seconds, which is what makes the
//! paper-scale "millions of users" ladders checkable at all.

use crate::sweep::SweepRunner;

use super::node::NodeModel;
use super::sim::{simulate, ClusterConfig};
use super::stats::ClusterStats;
use super::tenant::{simulate_tenants, TenantConfig, TenantWorkload};

/// One probed fleet size (for the report table).
#[derive(Debug, Clone, Copy)]
pub struct CapacityPoint {
    /// Fleet size simulated.
    pub nodes: usize,
    /// Measured p99 end-to-end latency in cycles.
    pub p99: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Whether the point met the SLO (no rejections, p99 <= target).
    pub meets: bool,
    /// Average fleet power at this size (W); `None` when the node model
    /// carries no energy profile.
    pub power_w: Option<f64>,
}

/// The planner's answer.
#[derive(Debug, Clone)]
pub struct CapacityReport {
    /// Minimum fleet size meeting the SLO.
    pub nodes: usize,
    /// The confirming simulation at that size (a direct run, not an
    /// interpolation).
    pub stats: ClusterStats,
    /// Every probed point, in probe order.
    pub evaluated: Vec<CapacityPoint>,
    /// The SLO target the search ran against (p99 cycles).
    pub p99_target: u64,
    /// The power budget the answer was checked against, if any (W).
    pub power_budget_w: Option<f64>,
}

/// Find the minimum `nodes <= max_nodes` such that the scenario in `base`
/// (its `nodes` field is ignored) meets `p99 <= p99_target` cycles with
/// zero rejections — and, when `power_budget_w` is set, draws at most that
/// average fleet power. Errors when even `max_nodes` misses the target, or
/// when the p99-minimal fleet busts the power budget.
///
/// Power needs no second search: average fleet power is non-decreasing in
/// fleet size (every extra replica adds its always-on idle floor while
/// the dynamic work — one image's energy per injection — stays fixed by
/// the offered load; a smaller-but-slower fleet additionally spreads the
/// same energy over a longer drain span). The p99-minimal size from the
/// existing k-section is therefore also the power-minimal size among
/// SLO-feasible fleets: if it exceeds the budget, no feasible size exists.
pub fn plan_capacity(
    model: &NodeModel,
    base: &ClusterConfig,
    p99_target: u64,
    max_nodes: usize,
    power_budget_w: Option<f64>,
    runner: &SweepRunner,
) -> Result<CapacityReport, String> {
    assert!(max_nodes >= 1, "max_nodes must be at least 1");
    let probe = |sizes: &[usize]| -> Vec<ClusterStats> {
        runner.run(sizes, |_, &n| {
            simulate(
                model,
                &ClusterConfig {
                    nodes: n,
                    ..base.clone()
                },
            )
        })
    };
    if let Some(b) = power_budget_w {
        if !b.is_finite() || b <= 0.0 {
            return Err(format!("power budget must be a positive wattage, got {b}"));
        }
        if model.energy.is_none() {
            return Err("a power budget needs an energy profile: build the \
                        NodeModel from a workload (NodeModel::from_workload)"
                .into());
        }
    }
    let mut evaluated: Vec<CapacityPoint> = Vec::new();
    let mut record = |sizes: &[usize], stats: &[ClusterStats]| {
        for (&n, s) in sizes.iter().zip(stats) {
            evaluated.push(CapacityPoint {
                nodes: n,
                p99: s.latency.p99(),
                rejected: s.rejected,
                meets: s.meets_slo(p99_target),
                power_w: s.energy.as_ref().map(|e| e.avg_power_w()),
            });
        }
    };

    // Round 1 — geometric ladder, all points in one parallel fan-out.
    let mut ladder: Vec<usize> = std::iter::successors(Some(1usize), |&n| {
        (n < max_nodes).then_some((n * 2).min(max_nodes))
    })
    .collect();
    ladder.dedup();
    let ladder_stats = probe(&ladder);
    record(&ladder, &ladder_stats);

    let Some(first_ok) = ladder_stats.iter().position(|s| s.meets_slo(p99_target)) else {
        let best = ladder_stats.last().expect("ladder is non-empty");
        if best.offered == 0 {
            return Err("the arrival process produced no requests; \
                        nothing to plan capacity for"
                .into());
        }
        return Err(format!(
            "even {max_nodes} nodes miss the SLO: p99 {} cycles > target \
             {p99_target}, {} rejected of {} offered — raise --max-nodes, \
             relax --p99-target, or lower the load",
            best.latency.p99(),
            best.rejected,
            best.offered
        ));
    };

    let mut hi = ladder[first_ok];
    let mut hi_stats = ladder_stats[first_ok].clone();
    let mut lo = if first_ok == 0 { 0 } else { ladder[first_ok - 1] };

    // Rounds 2..n — k-section: shrink (lo, hi] with up to `k` evenly
    // spaced interior probes per round, all simulated in parallel. With
    // the monotone predicate, hi tracks the smallest meeting size seen
    // and lo the largest missing one.
    let k = runner.threads().clamp(1, 8);
    while hi - lo > 1 {
        let width = hi - lo - 1; // interior candidates
        let probes: Vec<usize> = if width <= k {
            ((lo + 1)..hi).collect()
        } else {
            (1..=k).map(|i| lo + i * (width + 1) / (k + 1)).collect()
        };
        let stats = probe(&probes);
        record(&probes, &stats);
        for (&n, s) in probes.iter().zip(&stats) {
            if s.meets_slo(p99_target) {
                if n < hi {
                    hi = n;
                    hi_stats = s.clone();
                }
            } else if n > lo {
                lo = n;
            }
        }
        if lo >= hi {
            // A locally non-monotone draw (batch padding can invert the
            // ordering between adjacent sizes): trust the smallest size
            // that met the SLO and stop narrowing.
            lo = hi - 1;
        }
    }

    // Power gate: the p99-minimal fleet is also the power-minimal one
    // among SLO-feasible sizes (see the function docs), so a budget
    // violation here means no fleet size can satisfy both constraints.
    if let Some(budget) = power_budget_w {
        let power = hi_stats
            .energy
            .as_ref()
            .map(|e| e.avg_power_w())
            .expect("profile presence checked on entry");
        if power > budget {
            return Err(format!(
                "the minimum SLO-feasible fleet ({hi} nodes) draws {power:.1} W \
                 > budget {budget} W, and larger fleets only draw more (each \
                 replica adds its idle floor) — relax --power-budget-w or \
                 --p99-target, or lower the load"
            ));
        }
    }

    Ok(CapacityReport {
        nodes: hi,
        stats: hi_stats,
        evaluated,
        p99_target,
        power_budget_w,
    })
}

/// One probed fleet size of the multi-tenant ladder.
#[derive(Debug, Clone)]
pub struct TenantCapacityPoint {
    /// Fleet size simulated.
    pub nodes: usize,
    /// The worst per-tenant p99 at this size (cycles) — the SLO is
    /// per-tenant, so the fleet is only as good as its slowest tenant.
    pub worst_p99: u64,
    /// Total rejections across tenants.
    pub rejected: u64,
    /// Total model swaps across tenants.
    pub swaps: u64,
    /// Joules per completed image (idle + swaps included); `None` without
    /// energy profiles.
    pub joules_per_image: Option<f64>,
    /// Every tenant met `p99 <= target` with zero rejections.
    pub meets: bool,
}

/// Probe the multi-tenant scenario in `base` (its `nodes` field is
/// ignored) at each fleet size in `sizes`, in parallel on `runner`, and
/// report the per-size worst-tenant SLO outcome. Unlike single-model
/// [`plan_capacity`] this is a *ladder*, not a section search: under
/// reprogram-on-miss, adding nodes changes the resident striping and can
/// shift swap storms, so per-tenant p99 is not a certified-monotone
/// predicate over fleet size — the planner reports every probe and lets
/// the caller pick, rather than trusting a bisection invariant that does
/// not hold.
pub fn tenant_capacity_ladder(
    tenants: &[TenantWorkload],
    base: &TenantConfig,
    sizes: &[usize],
    p99_target: u64,
    runner: &SweepRunner,
) -> Result<Vec<TenantCapacityPoint>, String> {
    if sizes.is_empty() {
        return Err("the capacity ladder needs at least one fleet size".to_string());
    }
    let probed = runner.run(sizes, |_, &n| {
        simulate_tenants(
            tenants,
            &TenantConfig {
                nodes: n,
                ..base.clone()
            },
        )
    });
    let mut out = Vec::with_capacity(sizes.len());
    for (&n, r) in sizes.iter().zip(probed) {
        let s = r?;
        let worst_p99 = s.tenants.iter().map(|t| t.latency.p99()).max().unwrap_or(0);
        out.push(TenantCapacityPoint {
            nodes: n,
            worst_p99,
            rejected: s.rejected,
            swaps: s.total_swaps(),
            joules_per_image: s.energy.as_ref().map(|e| e.joules_per_image()),
            meets: s.rejected == 0 && s.completed > 0 && worst_p99 <= p99_target,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::ReplicationPlan;

    fn model() -> NodeModel {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        NodeModel::from_workload(&net, &arch, &plan).unwrap()
    }

    fn base(rate: f64) -> ClusterConfig {
        ClusterConfig {
            rate_per_cycle: rate,
            horizon_cycles: 1_500_000,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn planner_answer_is_minimal_and_confirmed() {
        let m = model();
        // ~2.5 nodes of offered load: the answer must be >= 3 and the
        // returned stats must themselves meet the SLO.
        let cfg = base(2.5 / 3136.0);
        let target = 40_000;
        let r = plan_capacity(&m, &cfg, target, 32, None, &SweepRunner::with_threads(4)).unwrap();
        assert!(r.stats.meets_slo(target), "confirming run must meet SLO");
        assert!(r.nodes >= 3, "cannot serve 2.5 nodes of load on {}", r.nodes);
        // Minimality: one node fewer must miss (re-simulate directly).
        if r.nodes > 1 {
            let under = simulate(
                &m,
                &ClusterConfig {
                    nodes: r.nodes - 1,
                    ..cfg.clone()
                },
            );
            assert!(
                !under.meets_slo(target),
                "{} nodes already meet the target; planner said {}",
                r.nodes - 1,
                r.nodes
            );
        }
    }

    #[test]
    fn planner_is_deterministic() {
        let m = model();
        let cfg = base(1.5 / 3136.0);
        let a = plan_capacity(&m, &cfg, 50_000, 16, None, &SweepRunner::with_threads(1)).unwrap();
        let b = plan_capacity(&m, &cfg, 50_000, 16, None, &SweepRunner::with_threads(4)).unwrap();
        assert_eq!(a.nodes, b.nodes, "thread count must not change the answer");
        assert_eq!(a.stats.latency.p99(), b.stats.latency.p99());
    }

    #[test]
    fn tenant_ladder_reports_every_probe_deterministically() {
        use crate::power::WriteCost;
        let tenants = vec![
            TenantWorkload::new(
                "a",
                1.0,
                100,
                500,
                WriteCost {
                    rows: 0,
                    latency_cycles: 1_000,
                    energy_j: 0.5,
                },
            ),
            TenantWorkload::new(
                "b",
                1.0,
                300,
                700,
                WriteCost {
                    rows: 0,
                    latency_cycles: 2_000,
                    energy_j: 0.25,
                },
            ),
        ];
        let base = TenantConfig {
            rate_per_cycle: 0.004,
            horizon_cycles: 400_000,
            max_queue: 8,
            ..TenantConfig::default()
        };
        let sizes = [2usize, 4, 8];
        let pts =
            tenant_capacity_ladder(&tenants, &base, &sizes, 100_000, &SweepRunner::with_threads(2))
                .unwrap();
        assert_eq!(pts.len(), 3);
        for (p, &n) in pts.iter().zip(&sizes) {
            assert_eq!(p.nodes, n);
            assert!(p.joules_per_image.is_none(), "no profiles on synthetic tenants");
        }
        let again =
            tenant_capacity_ladder(&tenants, &base, &sizes, 100_000, &SweepRunner::with_threads(1))
                .unwrap();
        for (a, b) in pts.iter().zip(&again) {
            assert_eq!(a.worst_p99, b.worst_p99, "thread count changed the ladder");
            assert_eq!(a.swaps, b.swaps);
            assert_eq!(a.rejected, b.rejected);
        }
        assert!(
            tenant_capacity_ladder(&tenants, &base, &[], 1, &SweepRunner::with_threads(1))
                .is_err(),
            "an empty ladder is a usage error"
        );
    }

    #[test]
    fn unreachable_target_errors_with_context() {
        let m = model();
        // Below one pipeline fill: no fleet size can meet it.
        let err = plan_capacity(
            &m,
            &base(1e-4),
            m.fill / 2,
            8,
            None,
            &SweepRunner::with_threads(2),
        )
        .unwrap_err();
        assert!(err.contains("miss the SLO"), "{err}");
    }

    #[test]
    fn single_node_answer_when_load_is_light() {
        let m = model();
        let r = plan_capacity(
            &m,
            &base(0.2 / 3136.0),
            60_000,
            8,
            None,
            &SweepRunner::with_threads(2),
        )
        .unwrap();
        assert_eq!(r.nodes, 1, "light load needs one node");
    }

    #[test]
    fn generous_power_budget_does_not_change_the_answer() {
        let m = model();
        let cfg = base(1.5 / 3136.0);
        let runner = SweepRunner::with_threads(2);
        let plain = plan_capacity(&m, &cfg, 50_000, 16, None, &runner).unwrap();
        // 1 kW covers any fleet this search can return (16 nodes idle at
        // ~191 W; even 16 peaks stay well under).
        let budgeted = plan_capacity(&m, &cfg, 50_000, 16, Some(1_000.0), &runner).unwrap();
        assert_eq!(plain.nodes, budgeted.nodes);
        assert_eq!(budgeted.power_budget_w, Some(1_000.0));
        let power = budgeted.stats.energy.unwrap().avg_power_w();
        assert!(power > 0.0 && power <= 1_000.0, "power {power} W");
        assert!(
            budgeted.evaluated.iter().all(|p| p.power_w.is_some()),
            "every probe must record its power"
        );
    }

    #[test]
    fn impossible_power_budget_errors_with_wattage() {
        // 1 W is below a single node's ~12 W idle floor: no fleet can
        // meet it, and the error must say so with the measured draw.
        let m = model();
        let err = plan_capacity(
            &m,
            &base(1.5 / 3136.0),
            50_000,
            16,
            Some(1.0),
            &SweepRunner::with_threads(2),
        )
        .unwrap_err();
        assert!(err.contains("budget 1 W"), "{err}");
        assert!(err.contains("W >"), "{err}");
    }

    #[test]
    fn power_budget_rejects_bad_inputs() {
        let m = model();
        let cfg = base(1e-4);
        for bad in [0.0, -5.0, f64::NAN] {
            let err = plan_capacity(&m, &cfg, 50_000, 8, Some(bad), &SweepRunner::with_threads(1))
                .unwrap_err();
            assert!(err.contains("positive wattage"), "{bad}: {err}");
        }
        // A bare-shape model has no energy profile to budget against.
        let bare = NodeModel::new(m.shape.clone());
        let err = plan_capacity(
            &bare,
            &cfg,
            50_000,
            8,
            Some(100.0),
            &SweepRunner::with_threads(1),
        )
        .unwrap_err();
        assert!(err.contains("energy profile"), "{err}");
    }
}
