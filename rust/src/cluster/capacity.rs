//! Capacity planning: the minimum fleet size whose p99 end-to-end latency
//! meets an SLO target at the offered load.
//!
//! The search leans on a monotonicity invariant of the serving model
//! (pinned by `tests/prop_cluster.rs`): with the arrival stream held
//! fixed (same seed), per-request waits are non-increasing in fleet size,
//! so "meets the SLO" is a monotone predicate over `nodes` and section
//! search applies. Each probe is a full [`simulate`] run; probes within a
//! round are independent, so they fan out on [`SweepRunner`].

use crate::sweep::SweepRunner;

use super::node::NodeModel;
use super::sim::{simulate, ClusterConfig};
use super::stats::ClusterStats;

/// One probed fleet size (for the report table).
#[derive(Debug, Clone, Copy)]
pub struct CapacityPoint {
    /// Fleet size simulated.
    pub nodes: usize,
    /// Measured p99 end-to-end latency in cycles.
    pub p99: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Whether the point met the SLO (no rejections, p99 <= target).
    pub meets: bool,
}

/// The planner's answer.
#[derive(Debug, Clone)]
pub struct CapacityReport {
    /// Minimum fleet size meeting the SLO.
    pub nodes: usize,
    /// The confirming simulation at that size (a direct run, not an
    /// interpolation).
    pub stats: ClusterStats,
    /// Every probed point, in probe order.
    pub evaluated: Vec<CapacityPoint>,
    /// The SLO target the search ran against (p99 cycles).
    pub p99_target: u64,
}

/// Find the minimum `nodes <= max_nodes` such that the scenario in `base`
/// (its `nodes` field is ignored) meets `p99 <= p99_target` cycles with
/// zero rejections. Errors when even `max_nodes` misses the target.
pub fn plan_capacity(
    model: &NodeModel,
    base: &ClusterConfig,
    p99_target: u64,
    max_nodes: usize,
    runner: &SweepRunner,
) -> Result<CapacityReport, String> {
    assert!(max_nodes >= 1, "max_nodes must be at least 1");
    let probe = |sizes: &[usize]| -> Vec<ClusterStats> {
        runner.run(sizes, |_, &n| {
            simulate(
                model,
                &ClusterConfig {
                    nodes: n,
                    ..base.clone()
                },
            )
        })
    };
    let mut evaluated: Vec<CapacityPoint> = Vec::new();
    let mut record = |sizes: &[usize], stats: &[ClusterStats]| {
        for (&n, s) in sizes.iter().zip(stats) {
            evaluated.push(CapacityPoint {
                nodes: n,
                p99: s.latency.p99(),
                rejected: s.rejected,
                meets: s.meets_slo(p99_target),
            });
        }
    };

    // Round 1 — geometric ladder, all points in one parallel fan-out.
    let mut ladder: Vec<usize> = std::iter::successors(Some(1usize), |&n| {
        (n < max_nodes).then_some((n * 2).min(max_nodes))
    })
    .collect();
    ladder.dedup();
    let ladder_stats = probe(&ladder);
    record(&ladder, &ladder_stats);

    let Some(first_ok) = ladder_stats.iter().position(|s| s.meets_slo(p99_target)) else {
        let best = ladder_stats.last().expect("ladder is non-empty");
        if best.offered == 0 {
            return Err("the arrival process produced no requests; \
                        nothing to plan capacity for"
                .into());
        }
        return Err(format!(
            "even {max_nodes} nodes miss the SLO: p99 {} cycles > target \
             {p99_target}, {} rejected of {} offered — raise --max-nodes, \
             relax --p99-target, or lower the load",
            best.latency.p99(),
            best.rejected,
            best.offered
        ));
    };

    let mut hi = ladder[first_ok];
    let mut hi_stats = ladder_stats[first_ok].clone();
    let mut lo = if first_ok == 0 { 0 } else { ladder[first_ok - 1] };

    // Rounds 2..n — k-section: shrink (lo, hi] with up to `k` evenly
    // spaced interior probes per round, all simulated in parallel. With
    // the monotone predicate, hi tracks the smallest meeting size seen
    // and lo the largest missing one.
    let k = runner.threads().clamp(1, 8);
    while hi - lo > 1 {
        let width = hi - lo - 1; // interior candidates
        let probes: Vec<usize> = if width <= k {
            ((lo + 1)..hi).collect()
        } else {
            (1..=k).map(|i| lo + i * (width + 1) / (k + 1)).collect()
        };
        let stats = probe(&probes);
        record(&probes, &stats);
        for (&n, s) in probes.iter().zip(&stats) {
            if s.meets_slo(p99_target) {
                if n < hi {
                    hi = n;
                    hi_stats = s.clone();
                }
            } else if n > lo {
                lo = n;
            }
        }
        if lo >= hi {
            // A locally non-monotone draw (batch padding can invert the
            // ordering between adjacent sizes): trust the smallest size
            // that met the SLO and stop narrowing.
            lo = hi - 1;
        }
    }

    Ok(CapacityReport {
        nodes: hi,
        stats: hi_stats,
        evaluated,
        p99_target,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, VggVariant};
    use crate::config::ArchConfig;
    use crate::mapping::ReplicationPlan;

    fn model() -> NodeModel {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        NodeModel::from_workload(&net, &arch, &plan).unwrap()
    }

    fn base(rate: f64) -> ClusterConfig {
        ClusterConfig {
            rate_per_cycle: rate,
            horizon_cycles: 1_500_000,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn planner_answer_is_minimal_and_confirmed() {
        let m = model();
        // ~2.5 nodes of offered load: the answer must be >= 3 and the
        // returned stats must themselves meet the SLO.
        let cfg = base(2.5 / 3136.0);
        let target = 40_000;
        let r = plan_capacity(&m, &cfg, target, 32, &SweepRunner::with_threads(4)).unwrap();
        assert!(r.stats.meets_slo(target), "confirming run must meet SLO");
        assert!(r.nodes >= 3, "cannot serve 2.5 nodes of load on {}", r.nodes);
        // Minimality: one node fewer must miss (re-simulate directly).
        if r.nodes > 1 {
            let under = simulate(
                &m,
                &ClusterConfig {
                    nodes: r.nodes - 1,
                    ..cfg.clone()
                },
            );
            assert!(
                !under.meets_slo(target),
                "{} nodes already meet the target; planner said {}",
                r.nodes - 1,
                r.nodes
            );
        }
    }

    #[test]
    fn planner_is_deterministic() {
        let m = model();
        let cfg = base(1.5 / 3136.0);
        let a = plan_capacity(&m, &cfg, 50_000, 16, &SweepRunner::with_threads(1)).unwrap();
        let b = plan_capacity(&m, &cfg, 50_000, 16, &SweepRunner::with_threads(4)).unwrap();
        assert_eq!(a.nodes, b.nodes, "thread count must not change the answer");
        assert_eq!(a.stats.latency.p99(), b.stats.latency.p99());
    }

    #[test]
    fn unreachable_target_errors_with_context() {
        let m = model();
        // Below one pipeline fill: no fleet size can meet it.
        let err = plan_capacity(
            &m,
            &base(1e-4),
            m.fill / 2,
            8,
            &SweepRunner::with_threads(2),
        )
        .unwrap_err();
        assert!(err.contains("miss the SLO"), "{err}");
    }

    #[test]
    fn single_node_answer_when_load_is_light() {
        let m = model();
        let r = plan_capacity(
            &m,
            &base(0.2 / 3136.0),
            60_000,
            8,
            &SweepRunner::with_threads(2),
        )
        .unwrap();
        assert_eq!(r.nodes, 1, "light load needs one node");
    }
}
