//! Multi-tenant fleet serving: which model's weights occupy which node,
//! and what it costs to change your mind.
//!
//! The single-model cluster ([`super::sim`]) assumes every node serves the
//! same network forever. A production fleet hosts *many* models, and on
//! ReRAM the distinction matters because weight writes are orders of
//! magnitude more expensive than reads (~1.76e-4 s and ~6.76e-7 J per
//! crossbar row — [`crate::power::write`]): swapping the resident model on
//! a node costs a pipeline drain plus hundreds of thousands of cycles of
//! reprogramming, charged into [`FleetEnergy::weight_writes_j`]. Residency
//! is therefore a first-class scheduling decision with two policies:
//!
//! - [`Residency::Reprogram`] (reprogram-on-miss): any node may serve any
//!   tenant; routing prefers nodes already holding the tenant's weights
//!   (jsq-with-affinity), and a miss pays the full
//!   [`WriteCost`](crate::power::WriteCost) — drain the pipeline, program
//!   every resident crossbar row, then inject. Anti-phase diurnal tenant
//!   mixes ([`MixMode::Diurnal`]) produce reproducible *swap storms*: each
//!   mix flip turns the whole fleet over.
//! - [`Residency::Partition`] (dedicated-partition): a static weighted
//!   tenant→node-set split ([`partition_counts`]); zero swaps by
//!   construction, but a tenant whose partition saturates rejects even
//!   while other partitions idle.
//!
//! The event loop is the flattened calendar idiom of [`super::sim`]
//! (streamed arrivals, `(cycle, seq)` min-heap, indexed vs linear-scan
//! routing with pinned bit-parity — `tests/prop_tenant.rs`), specialized
//! to eager-scheduling FIFO singles nodes ([`TenantNode`]): every accepted
//! request's injection and completion cycles are computed at admission, so
//! per-request latency decomposes *exactly* into queueing (drain-wait
//! before a swap) + swap (reprogramming) + backlog (injection-hazard
//! wait) + fill — `tests/golden_tenant.rs` pins the decomposition on an
//! alternating trace.
//!
//! Everything is deterministic from the seed; `smart-pim cluster
//! --tenants` is the CLI surface and the per-tenant grid rides in
//! `benches/cluster_scale.rs`.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

use crate::obs::metrics::MetricsRegistry;
use crate::obs::trace::{NullSink, TraceEvent, TracePhase, TraceSink};
use crate::power::WriteCost;
use crate::util::Json;

use super::arrival::{ArrivalProcess, LabeledArrivals, MixMode, TenantMix};
use super::node::{EnergyProfile, NodeModel, TenantNode};
use super::sim::RouteImpl;
use super::stats::{FleetEnergy, LatencySummary};

/// How a node's resident model is managed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Any node serves any tenant; a routing miss drains the pipeline and
    /// pays the tenant's full [`WriteCost`] to reprogram.
    Reprogram,
    /// Static weighted tenant→node-set assignment; no swaps ever, but a
    /// saturated partition rejects.
    Partition,
}

impl Residency {
    /// Policy name for tables and flags.
    pub fn name(&self) -> &'static str {
        match self {
            Residency::Reprogram => "reprogram",
            Residency::Partition => "partition",
        }
    }
}

impl std::str::FromStr for Residency {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "reprogram" | "reprogram-on-miss" => Ok(Residency::Reprogram),
            "partition" | "dedicated-partition" => Ok(Residency::Partition),
            other => Err(format!(
                "unknown residency policy {other:?} (reprogram | partition)"
            )),
        }
    }
}

/// How arrivals pick a node (the tenant-aware subset of
/// [`RoutePolicy`](super::RoutePolicy) — least-work has no meaning when
/// the dominant cost is *whose weights are resident*, not queue depth).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantRoute {
    /// Cycle through the tenant's candidate nodes in order (per-tenant
    /// counter under partition, one global counter under reprogram).
    RoundRobin,
    /// Join the shortest queue **with residency affinity**: first the
    /// least-loaded candidate already holding the tenant's weights, then —
    /// under reprogram only — the least-loaded node overall (paying the
    /// swap). Ties go to the lowest node index.
    ShortestQueue,
}

impl TenantRoute {
    /// Route name for tables and flags.
    pub fn name(&self) -> &'static str {
        match self {
            TenantRoute::RoundRobin => "rr",
            TenantRoute::ShortestQueue => "jsq",
        }
    }
}

impl std::str::FromStr for TenantRoute {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(TenantRoute::RoundRobin),
            "jsq" | "shortest-queue" => Ok(TenantRoute::ShortestQueue),
            other => Err(format!(
                "unknown tenant route {other:?} (rr | jsq)"
            )),
        }
    }
}

/// One hosted model: its pipeline constants, arrival share, and the price
/// of programming its weights onto a node.
#[derive(Debug, Clone)]
pub struct TenantWorkload {
    /// Tenant name for reports (usually the network name).
    pub name: String,
    /// Arrival-mix weight (relative share of offered traffic).
    pub weight: f64,
    /// Hazard-free injection interval in cycles.
    pub interval: u64,
    /// Injection-to-completion cycles for one image.
    pub fill: u64,
    /// Full weight-programming cost of one model swap.
    pub write: WriteCost,
    /// Per-image energy parameters; fleet energy is reported only when
    /// *every* tenant carries a profile.
    pub energy: Option<EnergyProfile>,
}

impl TenantWorkload {
    /// A synthetic tenant from bare constants (tests, what-if scenarios).
    pub fn new(name: &str, weight: f64, interval: u64, fill: u64, write: WriteCost) -> Self {
        Self {
            name: name.to_string(),
            weight,
            interval,
            fill,
            write,
            energy: None,
        }
    }

    /// A tenant from a built [`NodeModel`] (the real-workload path:
    /// interval/fill/energy from the validated single-node chain, write
    /// cost from the model's mapping footprint).
    pub fn from_model(name: &str, weight: f64, model: &NodeModel, write: WriteCost) -> Self {
        Self {
            name: name.to_string(),
            weight,
            interval: model.interval,
            fill: model.fill,
            write,
            energy: model.energy,
        }
    }
}

/// One multi-tenant scenario in simulated cycles.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    /// Node replicas in the fleet.
    pub nodes: usize,
    /// Residency policy.
    pub residency: Residency,
    /// Routing policy.
    pub route: TenantRoute,
    /// Routing implementation (bit-identical pair, like the base sim's).
    pub route_impl: RouteImpl,
    /// Arrival process shape (timing only; labels come from `mix`).
    pub pattern: ArrivalProcess,
    /// Offered arrival rate in requests per cycle, across all tenants.
    pub rate_per_cycle: f64,
    /// Tenant-labeling mode over the workloads' weights.
    pub mix: MixMode,
    /// Admission bound: max outstanding requests per node.
    pub max_queue: u64,
    /// Arrival horizon in cycles (ignored under `fixed_requests`).
    pub horizon_cycles: u64,
    /// Fixed-population mode: exactly this many arrivals.
    pub fixed_requests: Option<usize>,
    /// Seed for both the timing stream and the (salted) label stream.
    pub seed: u64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            residency: Residency::Reprogram,
            route: TenantRoute::ShortestQueue,
            route_impl: RouteImpl::Indexed,
            pattern: ArrivalProcess::Poisson,
            rate_per_cycle: 1e-4,
            mix: MixMode::Static,
            max_queue: 64,
            horizon_cycles: 5_000_000,
            fixed_requests: None,
            seed: 0xC105_7E4,
        }
    }
}

/// Weighted contiguous node partition: every tenant gets at least one
/// node, and the `nodes - tenants` remainder splits by largest-remainder
/// apportionment over the weights (ties to the lowest tenant index).
/// Errors when the fleet is smaller than the tenant count.
pub fn partition_counts(nodes: usize, weights: &[f64]) -> Result<Vec<usize>, String> {
    let t = weights.len();
    if t == 0 {
        return Err("partition needs at least one tenant".to_string());
    }
    if nodes < t {
        return Err(format!(
            "dedicated-partition needs >= 1 node per tenant: {t} tenants, {nodes} nodes"
        ));
    }
    let total: f64 = weights.iter().sum();
    let rem = (nodes - t) as f64;
    let ideal: Vec<f64> = weights.iter().map(|&w| rem * w / total).collect();
    let mut counts: Vec<usize> = ideal.iter().map(|&x| 1 + x as usize).collect();
    let leftover = nodes - counts.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..t).collect();
    order.sort_by(|&a, &b| {
        let (fa, fb) = (ideal[a] - ideal[a].trunc(), ideal[b] - ideal[b].trunc());
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in order.iter().take(leftover) {
        counts[i] += 1;
    }
    Ok(counts)
}

/// Per-tenant serving outcome.
#[derive(Debug, Clone)]
pub struct TenantStats {
    /// Tenant name (mirrors [`TenantWorkload::name`]).
    pub name: String,
    /// Arrivals labeled with this tenant.
    pub offered: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Model swaps performed on this tenant's behalf.
    pub swaps: u64,
    /// Routing misses (request landed on a node holding another tenant's
    /// weights). Under reprogram-on-miss every miss swaps, so
    /// `misses == swaps`; under partition both are zero.
    pub misses: u64,
    /// Weight-programming energy charged to this tenant (J):
    /// `swaps x write.energy_j`.
    pub swap_energy_j: f64,
    /// End-to-end latency distribution (arrival → completion).
    pub latency: LatencySummary,
    /// Exact latency decomposition sums over completed requests:
    /// Σ total = Σ queueing + Σ swap + Σ backlog + completed x fill.
    pub total_latency_cycles: u64,
    /// Σ drain-waits before swaps (cycles).
    pub queueing_cycles: u64,
    /// Σ reprogramming cycles charged to triggering requests.
    pub swap_cycles: u64,
    /// Σ injection-hazard waits on resident hits (cycles).
    pub backlog_cycles: u64,
    /// The tenant's per-request fill constant (closes the decomposition).
    pub fill: u64,
}

impl TenantStats {
    /// Fraction of this tenant's offered requests rejected.
    pub fn rejection_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.offered as f64
    }

    /// Machine-readable form (one row of `cluster --tenants --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tenant", self.name.as_str().into()),
            ("offered", self.offered.into()),
            ("completed", self.completed.into()),
            ("rejected", self.rejected.into()),
            ("rejection_rate", self.rejection_rate().into()),
            ("swaps", self.swaps.into()),
            ("swap_energy_j", self.swap_energy_j.into()),
            ("latency_mean_cycles", self.latency.mean().into()),
            ("latency_p50_cycles", self.latency.p50().into()),
            ("latency_p95_cycles", self.latency.p95().into()),
            ("latency_p99_cycles", self.latency.p99().into()),
            ("latency_p999_cycles", self.latency.p999().into()),
            ("latency_max_cycles", self.latency.max().into()),
            ("queueing_cycles", self.queueing_cycles.into()),
            ("swap_cycles", self.swap_cycles.into()),
            ("backlog_cycles", self.backlog_cycles.into()),
        ])
    }
}

/// Whole-run outcome of one multi-tenant scenario.
#[derive(Debug, Clone)]
pub struct TenantClusterStats {
    /// Residency policy the run used.
    pub residency: Residency,
    /// Routing policy the run used.
    pub route: TenantRoute,
    /// Per-tenant outcomes, workload order.
    pub tenants: Vec<TenantStats>,
    /// Total arrivals offered.
    pub offered: u64,
    /// Total completions.
    pub completed: u64,
    /// Total rejections.
    pub rejected: u64,
    /// Effective generation span in cycles (same semantics as the base
    /// sim: the configured horizon, clipped/replaced by the actual
    /// arrival extent under traces and fixed populations).
    pub horizon_cycles: u64,
    /// Cycle of the last completion.
    pub drained_at: u64,
    /// Calendar events processed.
    pub events_processed: u64,
    /// High-water mark of the calendar.
    pub peak_calendar_depth: usize,
    /// Per-node busy fraction over the drain span — streaming *plus*
    /// reprogramming cycles ([`TenantNode::active_cycles`]).
    pub node_utilization: Vec<f64>,
    /// Per-node model-swap counts.
    pub per_node_swaps: Vec<u64>,
    /// Per-node injections (accepted requests; singles, no padding).
    pub per_node_injected: Vec<u64>,
    /// Nodes per tenant under [`Residency::Partition`] (`None` under
    /// reprogram).
    pub partition: Option<Vec<usize>>,
    /// Fleet energy with the weight-write component; present when every
    /// tenant carried an [`EnergyProfile`].
    pub energy: Option<FleetEnergy>,
    /// Structured operation counters (arrivals, misses, swaps, calendar
    /// gauges), rendered as the `metrics` block in `--json` output. A pure
    /// function of the run.
    pub metrics: MetricsRegistry,
}

impl TenantClusterStats {
    /// Total model swaps across the fleet.
    pub fn total_swaps(&self) -> u64 {
        self.tenants.iter().map(|t| t.swaps).sum()
    }

    /// Total weight-programming energy across tenants (J).
    pub fn total_swap_energy_j(&self) -> f64 {
        self.tenants.iter().map(|t| t.swap_energy_j).sum()
    }

    /// Machine-readable form (`cluster --tenants --json`).
    pub fn to_json(&self, logical_cycle_ns: f64) -> Json {
        let throughput = if self.drained_at == 0 {
            0.0
        } else {
            self.completed as f64 / self.drained_at as f64 / (logical_cycle_ns * 1e-9)
        };
        let mut doc = Json::obj(vec![
            ("residency", self.residency.name().into()),
            ("route", self.route.name().into()),
            ("offered", self.offered.into()),
            ("completed", self.completed.into()),
            ("rejected", self.rejected.into()),
            ("swaps", self.total_swaps().into()),
            ("swap_energy_j", self.total_swap_energy_j().into()),
            ("horizon_cycles", self.horizon_cycles.into()),
            ("drained_at", self.drained_at.into()),
            ("events_processed", self.events_processed.into()),
            ("peak_calendar_depth", self.peak_calendar_depth.into()),
            ("throughput_rps", throughput.into()),
            (
                "node_utilization",
                Json::Arr(self.node_utilization.iter().map(|&u| u.into()).collect()),
            ),
            (
                "per_node_swaps",
                Json::Arr(self.per_node_swaps.iter().map(|&s| s.into()).collect()),
            ),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(TenantStats::to_json).collect()),
            ),
        ]);
        if let (Json::Obj(pairs), Some(counts)) = (&mut doc, &self.partition) {
            pairs.push((
                "partition_nodes".to_string(),
                Json::Arr(counts.iter().map(|&c| c.into()).collect()),
            ));
        }
        if let (Json::Obj(pairs), Some(e)) = (&mut doc, &self.energy) {
            if let Json::Obj(extra) = e.to_json() {
                pairs.extend(extra);
            }
        }
        if !self.metrics.is_empty() {
            if let Json::Obj(pairs) = &mut doc {
                pairs.push(("metrics".to_string(), self.metrics.to_json()));
            }
        }
        doc
    }
}

/// Calendar entry kinds. Payloads carry the decomposition so completions
/// need no lookaside table.
#[derive(Debug)]
enum Ev {
    Arrival {
        tenant: usize,
    },
    Completion {
        node: usize,
        tenant: usize,
        arrived: u64,
        queueing: u64,
        swap: u64,
        backlog: u64,
    },
}

/// `(cycle, seq)` min-heap — the deterministic tie-break idiom shared
/// with [`super::sim`]'s calendar.
#[derive(Default)]
struct Cal {
    heap: BinaryHeap<Reverse<(u64, u64, EvBox)>>,
    seq: u64,
    peak: usize,
}

/// Wrapper making `Ev` heap-storable without participating in ordering
/// (the `(cycle, seq)` prefix is already a total order; seq is unique).
struct EvBox(Ev);

impl PartialEq for EvBox {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl Eq for EvBox {}
impl PartialOrd for EvBox {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for EvBox {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl Cal {
    fn push(&mut self, cycle: u64, ev: Ev) {
        self.heap.push(Reverse((cycle, self.seq, EvBox(ev))));
        self.seq += 1;
        self.peak = self.peak.max(self.heap.len());
    }

    fn pop(&mut self) -> Option<(u64, Ev)> {
        self.heap.pop().map(|Reverse((c, _, EvBox(e)))| (c, e))
    }
}

/// Tenant-aware router: round-robin counters plus, for indexed jsq, a
/// per-tenant resident index and a global occupancy index. Both
/// implementations share one tie-break contract — lowest `(in_flight,
/// node)` wins — so their picks (and therefore the whole run's stats) are
/// bit-identical; `tests/prop_tenant.rs` pins the parity.
struct TenantRouter {
    route: TenantRoute,
    imp: RouteImpl,
    residency: Residency,
    max_queue: u64,
    rr_global: usize,
    rr_per_tenant: Vec<usize>,
    /// `(in_flight, node)` for every node whose resident tenant is the
    /// index — the jsq-with-affinity pass-1 index.
    by_tenant: Vec<BTreeSet<(u64, usize)>>,
    /// `(in_flight, node)` over the whole fleet — the reprogram pass-2
    /// index (under partition pass 2 never widens past pass 1).
    global: BTreeSet<(u64, usize)>,
}

impl TenantRouter {
    fn new(
        route: TenantRoute,
        imp: RouteImpl,
        residency: Residency,
        max_queue: u64,
        tenants: usize,
        resident: &[usize],
    ) -> Self {
        let mut by_tenant = vec![BTreeSet::new(); tenants];
        let mut global = BTreeSet::new();
        if route == TenantRoute::ShortestQueue && imp == RouteImpl::Indexed {
            for (n, &t) in resident.iter().enumerate() {
                by_tenant[t].insert((0u64, n));
                global.insert((0u64, n));
            }
        }
        Self {
            route,
            imp,
            residency,
            max_queue,
            rr_global: 0,
            rr_per_tenant: vec![0; tenants],
            by_tenant,
            global,
        }
    }

    /// True when the occupancy indexes are live and must track changes.
    fn tracking(&self) -> bool {
        self.route == TenantRoute::ShortestQueue && self.imp == RouteImpl::Indexed
    }

    /// A node's outstanding count changed.
    fn occ_changed(&mut self, node: usize, tenant: usize, old: u64, new: u64) {
        if !self.tracking() {
            return;
        }
        self.by_tenant[tenant].remove(&(old, node));
        self.by_tenant[tenant].insert((new, node));
        self.global.remove(&(old, node));
        self.global.insert((new, node));
    }

    /// A node's resident tenant changed (occupancy unchanged).
    fn resident_changed(&mut self, node: usize, occ: u64, old_t: usize, new_t: usize) {
        if !self.tracking() {
            return;
        }
        self.by_tenant[old_t].remove(&(occ, node));
        self.by_tenant[new_t].insert((occ, node));
    }

    /// Route one arrival of `tenant`. `None` rejects. Round-robin
    /// counters advance even when the picked node is full (stateless
    /// cycling, matching the base sim's rr).
    fn pick(
        &mut self,
        tenant: usize,
        nodes: &[TenantNode],
        bounds: Option<&Vec<Vec<usize>>>,
    ) -> Option<usize> {
        match self.route {
            TenantRoute::RoundRobin => {
                let n = match bounds {
                    Some(b) => {
                        let lst = &b[tenant];
                        let n = lst[self.rr_per_tenant[tenant] % lst.len()];
                        self.rr_per_tenant[tenant] += 1;
                        n
                    }
                    None => {
                        let n = self.rr_global % nodes.len();
                        self.rr_global += 1;
                        n
                    }
                };
                (nodes[n].in_flight < self.max_queue).then_some(n)
            }
            TenantRoute::ShortestQueue => match self.imp {
                RouteImpl::Indexed => {
                    if let Some(&(occ, n)) = self.by_tenant[tenant].first() {
                        if occ < self.max_queue {
                            return Some(n);
                        }
                    }
                    if self.residency == Residency::Reprogram {
                        if let Some(&(occ, n)) = self.global.first() {
                            if occ < self.max_queue {
                                return Some(n);
                            }
                        }
                    }
                    None
                }
                RouteImpl::LinearScan => {
                    let scan = |want_resident: bool| -> Option<(u64, usize)> {
                        let mut best: Option<(u64, usize)> = None;
                        let mut consider = |n: usize| {
                            let nd = &nodes[n];
                            if (!want_resident || nd.resident == tenant)
                                && nd.in_flight < self.max_queue
                            {
                                let key = (nd.in_flight, n);
                                if best.map_or(true, |b| key < b) {
                                    best = Some(key);
                                }
                            }
                        };
                        match bounds {
                            Some(b) => b[tenant].iter().for_each(|&n| consider(n)),
                            None => (0..nodes.len()).for_each(&mut consider),
                        }
                        best
                    };
                    scan(true).or_else(|| scan(false)).map(|(_, n)| n)
                }
            },
        }
    }
}

/// Run one multi-tenant scenario to completion (arrivals exhausted,
/// pipelines drained) and report per-tenant SLO stats plus fleet energy
/// with the weight-write component. Deterministic from `cfg.seed`;
/// bit-identical across [`RouteImpl`]s.
pub fn simulate_tenants(
    tenants: &[TenantWorkload],
    cfg: &TenantConfig,
) -> Result<TenantClusterStats, String> {
    simulate_tenants_with_sink(tenants, cfg, &mut NullSink)
}

/// [`simulate_tenants`] with a [`TraceSink`] tap. The `tenant` subsystem
/// reports one track per node: on a miss, a `drain` span (pipeline
/// drain-wait), a `reprogram` span carrying the write cost
/// (rows/latency), and the `service` span; on a hit, the `service` span
/// alone; plus a `complete` instant per completion. Stats are
/// bit-identical whatever sink is attached (`tests/obs_parity.rs`).
pub fn simulate_tenants_with_sink(
    tenants: &[TenantWorkload],
    cfg: &TenantConfig,
    sink: &mut dyn TraceSink,
) -> Result<TenantClusterStats, String> {
    let _prof = crate::obs::profile::scope("tenant.simulate");
    if tenants.is_empty() {
        return Err("need at least one tenant workload".to_string());
    }
    if cfg.nodes == 0 {
        return Err("a fleet needs at least one node".to_string());
    }
    for t in tenants {
        if t.interval == 0 || t.fill == 0 {
            return Err(format!("tenant {:?} needs positive interval and fill", t.name));
        }
    }
    let t_count = tenants.len();
    let weights: Vec<f64> = tenants.iter().map(|t| t.weight).collect();

    // Initial residency: the partition map, or model i%T striped across
    // the fleet under reprogram (every tenant starts warm somewhere).
    let (resident_init, bounds, partition) = match cfg.residency {
        Residency::Partition => {
            let counts = partition_counts(cfg.nodes, &weights)?;
            let mut resident = Vec::with_capacity(cfg.nodes);
            let mut bounds = Vec::with_capacity(t_count);
            let mut start = 0usize;
            for (t, &c) in counts.iter().enumerate() {
                resident.extend(std::iter::repeat(t).take(c));
                bounds.push((start..start + c).collect::<Vec<usize>>());
                start += c;
            }
            (resident, Some(bounds), Some(counts))
        }
        Residency::Reprogram => {
            ((0..cfg.nodes).map(|i| i % t_count).collect(), None, None)
        }
    };

    let stream = match cfg.fixed_requests {
        Some(n) => cfg.pattern.stream_n(cfg.rate_per_cycle, n, cfg.seed),
        None => cfg
            .pattern
            .stream_horizon(cfg.rate_per_cycle, cfg.horizon_cycles, cfg.seed),
    };
    let mut arrivals =
        LabeledArrivals::new(stream, TenantMix::new(weights, cfg.mix, cfg.seed));

    let mut nodes: Vec<TenantNode> =
        resident_init.iter().map(|&t| TenantNode::new(t)).collect();
    let mut router = TenantRouter::new(
        cfg.route,
        cfg.route_impl,
        cfg.residency,
        cfg.max_queue,
        t_count,
        &resident_init,
    );

    let mut offered = vec![0u64; t_count];
    let mut completed = vec![0u64; t_count];
    let mut rejected = vec![0u64; t_count];
    let mut swaps = vec![0u64; t_count];
    let mut misses = vec![0u64; t_count];
    let mut lat: Vec<Vec<u64>> = vec![Vec::new(); t_count];
    let mut q_sum = vec![0u64; t_count];
    let mut s_sum = vec![0u64; t_count];
    let mut b_sum = vec![0u64; t_count];
    let mut events = 0u64;
    let mut drained_at = 0u64;
    let mut last_arrival: Option<u64> = None;

    let traced = sink.enabled();
    if traced {
        for i in 0..cfg.nodes {
            sink.name_track("tenant", i as u64, &format!("node {i}"));
        }
        sink.name_track("tenant", cfg.nodes as u64, "router");
    }
    // Stream-order request counter: only trace args use it, but it is
    // maintained unconditionally so traced and untraced control flow are
    // textually identical.
    let mut arrival_seq = 0u64;

    let mut cal = Cal::default();
    if let Some((c, t)) = arrivals.next() {
        last_arrival = Some(c);
        cal.push(c, Ev::Arrival { tenant: t });
    }

    while let Some((cycle, ev)) = cal.pop() {
        events += 1;
        match ev {
            Ev::Arrival { tenant: t } => {
                let req = arrival_seq;
                arrival_seq += 1;
                // Pull-and-push FIRST: the calendar holds at most one
                // pending arrival, and same-cycle events keep push order.
                if let Some((c, t2)) = arrivals.next() {
                    last_arrival = Some(c);
                    cal.push(c, Ev::Arrival { tenant: t2 });
                }
                offered[t] += 1;
                let Some(n) = router.pick(t, &nodes, bounds.as_ref()) else {
                    rejected[t] += 1;
                    if traced {
                        sink.record(TraceEvent {
                            subsystem: "tenant",
                            track: cfg.nodes as u64,
                            name: "reject",
                            ts: cycle,
                            phase: TracePhase::Instant,
                            args: vec![("request", req), ("tenant", t as u64)],
                        });
                    }
                    continue;
                };
                let occ = nodes[n].in_flight;
                nodes[n].in_flight = occ + 1;
                router.occ_changed(n, nodes[n].resident, occ, occ + 1);
                let missed = nodes[n].resident != t;
                let (inject, queueing, swap, backlog);
                if missed {
                    debug_assert!(
                        cfg.residency == Residency::Reprogram,
                        "partition nodes never swap"
                    );
                    // Miss: drain the pipeline, reprogram, then inject.
                    let swap_start = cycle.max(nodes[n].drain_at);
                    queueing = swap_start - cycle;
                    swap = tenants[t].write.latency_cycles;
                    inject = swap_start + swap;
                    backlog = 0;
                    let old_t = nodes[n].resident;
                    nodes[n].resident = t;
                    router.resident_changed(n, occ + 1, old_t, t);
                    swaps[t] += 1;
                    misses[t] += 1;
                    nodes[n].swaps += 1;
                    nodes[n].swap_cycles += swap;
                } else {
                    // Hit: wait out the injection hazard only.
                    inject = cycle.max(nodes[n].next_inject);
                    queueing = 0;
                    swap = 0;
                    backlog = inject - cycle;
                }
                nodes[n].next_inject = inject + tenants[t].interval;
                let comp = inject + tenants[t].fill;
                if traced {
                    let track = n as u64;
                    if missed {
                        if queueing > 0 {
                            sink.record(TraceEvent {
                                subsystem: "tenant",
                                track,
                                name: "drain",
                                ts: cycle,
                                phase: TracePhase::Span { dur: queueing },
                                args: vec![("request", req), ("tenant", t as u64)],
                            });
                        }
                        sink.record(TraceEvent {
                            subsystem: "tenant",
                            track,
                            name: "reprogram",
                            ts: cycle + queueing,
                            phase: TracePhase::Span { dur: swap },
                            args: vec![
                                ("request", req),
                                ("tenant", t as u64),
                                ("write_rows", tenants[t].write.rows),
                                ("write_cycles", tenants[t].write.latency_cycles),
                            ],
                        });
                    }
                    sink.record(TraceEvent {
                        subsystem: "tenant",
                        track,
                        name: "service",
                        ts: inject,
                        phase: TracePhase::Span { dur: comp - inject },
                        args: vec![("request", req), ("tenant", t as u64)],
                    });
                }
                // FIFO by construction: a tenant switch forces a full
                // drain, and same-tenant completions are monotone under a
                // constant fill.
                debug_assert!(comp >= nodes[n].drain_at, "completions must stay FIFO");
                nodes[n].drain_at = comp;
                nodes[n].busy_cycles += tenants[t].interval;
                nodes[n].injected += 1;
                cal.push(
                    comp,
                    Ev::Completion {
                        node: n,
                        tenant: t,
                        arrived: cycle,
                        queueing,
                        swap,
                        backlog,
                    },
                );
            }
            Ev::Completion {
                node: n,
                tenant: t,
                arrived,
                queueing,
                swap,
                backlog,
            } => {
                let occ = nodes[n].in_flight;
                nodes[n].in_flight = occ - 1;
                router.occ_changed(n, nodes[n].resident, occ, occ - 1);
                completed[t] += 1;
                let total = cycle - arrived;
                if traced {
                    sink.record(TraceEvent {
                        subsystem: "tenant",
                        track: n as u64,
                        name: "complete",
                        ts: cycle,
                        phase: TracePhase::Instant,
                        args: vec![
                            ("tenant", t as u64),
                            ("latency", total),
                            ("queueing", queueing),
                            ("swap", swap),
                            ("backlog", backlog),
                        ],
                    });
                }
                lat[t].push(total);
                q_sum[t] += queueing;
                s_sum[t] += swap;
                b_sum[t] += backlog;
                drained_at = drained_at.max(cycle);
            }
        }
    }

    // Effective generation span: same semantics as the base sim.
    let arrival_extent = last_arrival.map_or(0, |c| c + 1);
    let horizon_cycles = match (cfg.fixed_requests, &cfg.pattern) {
        (Some(_), _) => arrival_extent,
        (None, ArrivalProcess::Trace(_)) => cfg.horizon_cycles.min(arrival_extent),
        (None, _) => cfg.horizon_cycles,
    };

    // Fleet energy, computed at drain in tenant order (one accumulation
    // order = one exact identity: total == dynamic + idle + writes).
    // Requires every tenant priced; a single unpriced tenant would make
    // the split meaningless.
    let total_completed: u64 = completed.iter().sum();
    let energy = if tenants.iter().all(|t| t.energy.is_some()) {
        let p0 = tenants[0].energy.as_ref().unwrap();
        let span_s = drained_at as f64 * p0.logical_cycle_ns * 1e-9;
        let mut dynamic_j = 0.0;
        let mut ops = 0u64;
        for (i, tw) in tenants.iter().enumerate() {
            let p = tw.energy.as_ref().unwrap();
            // Every accepted request completes (eager singles): injected
            // == completed, so dynamic energy has no padding component.
            dynamic_j += completed[i] as f64 * p.image_mj * 1e-3;
            ops += completed[i] * p.ops_per_image;
        }
        let mut weight_writes_j = 0.0;
        for (i, tw) in tenants.iter().enumerate() {
            weight_writes_j += swaps[i] as f64 * tw.write.energy_j;
        }
        let idle_j = cfg.nodes as f64 * span_s * p0.idle_power_w;
        Some(FleetEnergy {
            dynamic_j,
            idle_j,
            padding_waste_j: 0.0,
            weight_writes_j,
            span_s,
            completed_ops: ops,
            completed: total_completed,
        })
    } else {
        None
    };

    let node_utilization: Vec<f64> = nodes
        .iter()
        .map(|n| {
            if drained_at == 0 {
                0.0
            } else {
                n.active_cycles() as f64 / drained_at as f64
            }
        })
        .collect();

    let per_tenant: Vec<TenantStats> = (0..t_count)
        .map(|i| {
            let samples = std::mem::take(&mut lat[i]);
            let total_latency_cycles: u64 = samples.iter().sum();
            TenantStats {
                name: tenants[i].name.clone(),
                offered: offered[i],
                completed: completed[i],
                rejected: rejected[i],
                swaps: swaps[i],
                misses: misses[i],
                swap_energy_j: swaps[i] as f64 * tenants[i].write.energy_j,
                latency: LatencySummary::from_samples(samples),
                total_latency_cycles,
                queueing_cycles: q_sum[i],
                swap_cycles: s_sum[i],
                backlog_cycles: b_sum[i],
                fill: tenants[i].fill,
            }
        })
        .collect();

    // The metrics block mirrors the ad-hoc gauges into the registry and
    // adds the per-kind breakdown; a pure function of the run.
    let mut metrics = MetricsRegistry::new();
    metrics.incr("tenant.events.arrival", offered.iter().sum());
    metrics.incr("tenant.events.rejected", rejected.iter().sum());
    metrics.incr("tenant.events.completion", total_completed);
    metrics.incr("tenant.events.processed", events);
    metrics.incr("tenant.swaps", swaps.iter().sum());
    metrics.incr("tenant.misses", misses.iter().sum());
    metrics.gauge("tenant.calendar.peak_depth", cal.peak as f64);

    Ok(TenantClusterStats {
        residency: cfg.residency,
        route: cfg.route,
        tenants: per_tenant,
        offered: offered.iter().sum(),
        completed: total_completed,
        rejected: rejected.iter().sum(),
        horizon_cycles,
        drained_at,
        events_processed: events,
        peak_calendar_depth: cal.peak,
        node_utilization,
        per_node_swaps: nodes.iter().map(|n| n.swaps).collect(),
        per_node_injected: nodes.iter().map(|n| n.injected).collect(),
        partition,
        energy,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants() -> Vec<TenantWorkload> {
        vec![
            TenantWorkload::new(
                "a",
                1.0,
                100,
                500,
                WriteCost {
                    rows: 0,
                    latency_cycles: 1_000,
                    energy_j: 0.5,
                },
            ),
            TenantWorkload::new(
                "b",
                1.0,
                300,
                700,
                WriteCost {
                    rows: 0,
                    latency_cycles: 2_000,
                    energy_j: 0.25,
                },
            ),
        ]
    }

    #[test]
    fn residency_and_route_parse() {
        assert_eq!("reprogram".parse::<Residency>().unwrap(), Residency::Reprogram);
        assert_eq!(
            "dedicated-partition".parse::<Residency>().unwrap(),
            Residency::Partition
        );
        assert!("lru".parse::<Residency>().is_err());
        assert_eq!("jsq".parse::<TenantRoute>().unwrap(), TenantRoute::ShortestQueue);
        assert_eq!("rr".parse::<TenantRoute>().unwrap(), TenantRoute::RoundRobin);
        assert!("least-work".parse::<TenantRoute>().is_err());
    }

    #[test]
    fn partition_counts_apportion_by_weight() {
        assert_eq!(partition_counts(4, &[1.0, 1.0]).unwrap(), vec![2, 2]);
        assert_eq!(partition_counts(10, &[3.0, 1.0]).unwrap(), vec![7, 3]);
        // Every tenant keeps a floor of one node.
        assert_eq!(partition_counts(3, &[100.0, 1.0, 1.0]).unwrap(), vec![1, 1, 1]);
        assert!(partition_counts(1, &[1.0, 1.0]).is_err(), "1 node, 2 tenants");
        assert!(partition_counts(4, &[]).is_err());
    }

    #[test]
    fn partition_never_swaps_and_splits_traffic() {
        let stats = simulate_tenants(
            &two_tenants(),
            &TenantConfig {
                nodes: 4,
                residency: Residency::Partition,
                rate_per_cycle: 0.005,
                horizon_cycles: 500_000,
                max_queue: 8,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        assert_eq!(stats.total_swaps(), 0);
        assert_eq!(stats.total_swap_energy_j(), 0.0);
        assert_eq!(stats.partition, Some(vec![2, 2]));
        for t in &stats.tenants {
            assert_eq!(t.offered, t.completed + t.rejected, "{}", t.name);
            assert!(t.completed > 0, "{}", t.name);
        }
    }

    #[test]
    fn reprogram_charges_swaps_on_misses() {
        let stats = simulate_tenants(
            &two_tenants(),
            &TenantConfig {
                nodes: 2,
                residency: Residency::Reprogram,
                rate_per_cycle: 0.002,
                horizon_cycles: 500_000,
                mix: MixMode::Alternate,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        assert!(stats.total_swaps() > 0, "alternating mix on 2 nodes must miss");
        for t in &stats.tenants {
            assert_eq!(t.swaps, t.misses, "reprogram-on-miss swaps every miss");
        }
        let e = stats.energy;
        assert!(e.is_none(), "synthetic tenants carry no energy profile");
    }

    #[test]
    fn single_tenant_reprogram_never_swaps() {
        let one = vec![two_tenants().remove(0)];
        let stats = simulate_tenants(
            &one,
            &TenantConfig {
                nodes: 4,
                residency: Residency::Reprogram,
                rate_per_cycle: 0.01,
                horizon_cycles: 300_000,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        assert_eq!(stats.total_swaps(), 0);
        assert_eq!(stats.offered, stats.completed + stats.rejected);
    }

    #[test]
    fn route_impls_are_bit_identical() {
        for residency in [Residency::Reprogram, Residency::Partition] {
            for route in [TenantRoute::RoundRobin, TenantRoute::ShortestQueue] {
                let run = |imp: RouteImpl| {
                    simulate_tenants(
                        &two_tenants(),
                        &TenantConfig {
                            nodes: 4,
                            residency,
                            route,
                            route_impl: imp,
                            rate_per_cycle: 0.01,
                            horizon_cycles: 200_000,
                            max_queue: 4,
                            mix: MixMode::Diurnal { period: 50_000 },
                            ..TenantConfig::default()
                        },
                    )
                    .unwrap()
                };
                let (a, b) = (run(RouteImpl::Indexed), run(RouteImpl::LinearScan));
                for (x, y) in a.tenants.iter().zip(&b.tenants) {
                    assert_eq!(x.completed, y.completed, "{residency:?} {route:?}");
                    assert_eq!(x.rejected, y.rejected, "{residency:?} {route:?}");
                    assert_eq!(x.swaps, y.swaps, "{residency:?} {route:?}");
                    assert_eq!(
                        x.total_latency_cycles, y.total_latency_cycles,
                        "{residency:?} {route:?}"
                    );
                }
                assert_eq!(a.drained_at, b.drained_at);
            }
        }
    }

    #[test]
    fn latency_decomposes_exactly() {
        let stats = simulate_tenants(
            &two_tenants(),
            &TenantConfig {
                nodes: 2,
                residency: Residency::Reprogram,
                rate_per_cycle: 0.005,
                horizon_cycles: 400_000,
                mix: MixMode::Alternate,
                max_queue: 16,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        for t in &stats.tenants {
            assert_eq!(
                t.total_latency_cycles,
                t.queueing_cycles + t.swap_cycles + t.backlog_cycles + t.completed * t.fill,
                "{}",
                t.name
            );
        }
    }

    #[test]
    fn json_carries_the_tenant_grid() {
        let stats = simulate_tenants(
            &two_tenants(),
            &TenantConfig {
                nodes: 2,
                rate_per_cycle: 0.002,
                horizon_cycles: 100_000,
                mix: MixMode::Alternate,
                ..TenantConfig::default()
            },
        )
        .unwrap();
        let j = stats.to_json(306.0).render();
        assert!(j.contains("\"residency\":\"reprogram\""), "{j}");
        assert!(j.contains("\"tenants\":["), "{j}");
        assert!(j.contains("\"tenant\":\"a\""), "{j}");
        assert!(j.contains("\"swap_energy_j\""), "{j}");
        assert!(!j.contains("energy_weight_writes_j"), "no profile: {j}");
    }

    #[test]
    fn sink_and_metrics_ride_along_without_perturbing_stats() {
        use crate::obs::trace::RecordingSink;
        let cfg = TenantConfig {
            nodes: 2,
            residency: Residency::Reprogram,
            rate_per_cycle: 0.002,
            horizon_cycles: 300_000,
            mix: MixMode::Alternate,
            ..TenantConfig::default()
        };
        let base = simulate_tenants(&two_tenants(), &cfg).unwrap();
        let mut sink = RecordingSink::new();
        let traced = simulate_tenants_with_sink(&two_tenants(), &cfg, &mut sink).unwrap();
        assert_eq!(base.offered, traced.offered);
        assert_eq!(base.drained_at, traced.drained_at);
        assert_eq!(base.total_swaps(), traced.total_swaps());
        assert_eq!(base.metrics, traced.metrics);
        assert_eq!(
            traced.metrics.counter("tenant.events.processed"),
            traced.events_processed
        );
        assert_eq!(traced.metrics.counter("tenant.swaps"), traced.total_swaps());
        assert_eq!(traced.metrics.counter("tenant.misses"), traced.total_swaps());
        // An alternating mix on a 2-node reprogram fleet swaps, so every
        // span kind shows up; one service span per completion.
        for name in ["reprogram", "service", "complete"] {
            assert!(
                sink.events_for("tenant").iter().any(|e| e.name == name),
                "no {name} events"
            );
        }
        let services = sink
            .events_for("tenant")
            .iter()
            .filter(|e| e.name == "service")
            .count();
        assert_eq!(services as u64, traced.completed);
        // The metrics block renders in --json.
        let j = traced.to_json(306.0).render();
        assert!(j.contains("\"metrics\""), "{j}");
        assert!(j.contains("\"tenant.swaps\""), "{j}");
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(simulate_tenants(&[], &TenantConfig::default()).is_err());
        let mut bad = two_tenants();
        bad[0].interval = 0;
        assert!(simulate_tenants(&bad, &TenantConfig::default()).is_err());
        assert!(simulate_tenants(
            &two_tenants(),
            &TenantConfig {
                nodes: 1,
                residency: Residency::Partition,
                ..TenantConfig::default()
            }
        )
        .is_err(), "2 tenants cannot partition 1 node");
    }
}
