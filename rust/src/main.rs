//! smart-pim CLI — the leader entrypoint.
//!
//! Subcommands regenerate every table/figure of the paper, run ad-hoc
//! simulations, and serve real quantized CNN inference through the PJRT
//! runtime:
//!
//! ```text
//! smart-pim fig4                      # component power/area table
//! smart-pim fig5 [--noc smart]        # pipelining speedups
//! smart-pim fig6 [--scenario 4]       # NoC speedups
//! smart-pim fig7                      # weight replication plans
//! smart-pim fig8                      # VGG-E throughput grid
//! smart-pim fig9                      # energy efficiency
//! smart-pim fig10 | fig11             # synthetic-traffic sweeps
//! smart-pim plan --network resnet18 [--tiles 320] [--depth 8] [--mapping vwsdk] [--compare] [--frontier]
//! smart-pim simulate --network vgg19|resnet18 --scenario 4 --noc smart [--mapping auto] [--gantt]
//! smart-pim noc --pattern tornado --rate 0.1 [--noc smart] [--topology torus] [--json FILE]
//! smart-pim serve --requests 64 [--artifacts artifacts]
//! smart-pim cluster --network vgg_e --nodes 4 --qps 500 --pattern poisson [--mapping vwsdk]
//! smart-pim cluster --qps 3000 --capacity --p99-target 20000 [--power-budget-w 60]
//! smart-pim cluster --tenants vgg_a,resnet18:2 --residency reprogram|partition [--mix diurnal]
//! smart-pim reproduce                 # paper-headline scoreboard + BENCH_headline.json
//! smart-pim profile [--json FILE]     # self-profiling micro-suite (hot-path wall times)
//! smart-pim dump-config               # active ArchConfig in file format
//! smart-pim report-all                # everything (minutes)
//! ```
//!
//! Every command accepts `--config FILE` (a `key = value` override file,
//! see `config/parse.rs`) to simulate nodes other than the paper's;
//! `noc`, `simulate`, `fig10`, and `fig11` also take
//! `--topology mesh|torus|prism` to swap the fabric (default: the config's
//! `topology` key, which is the paper's mesh). Every command accepts
//! `--profile` to append a wall-clock hot-path timing table. `simulate`,
//! `noc`, and `cluster` accept `--trace-out FILE` to export the run as
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`;
//! timestamps are virtual cycles, so traces are deterministic per seed).

use smart_pim::cnn::{vgg, VggVariant};
use smart_pim::config::{ArchConfig, NocKind, Scenario, TopologyKind};
use smart_pim::coordinator::{assess_ingress, startup_plan, BatchPolicy, Server};
use smart_pim::mapping::{
    plan_tiles, MappingKind, MappingMode, MappingSelection, ReplicationPlan,
};
use smart_pim::metrics::{cluster_table, paper, planner_table, tenant_table, Grid};
use smart_pim::noc::{build_backend, AnyTopology, Mesh, Pattern, StepMode, SyntheticConfig};
use smart_pim::planner::{evaluate_candidates, Planner, PlannerConfig};
use smart_pim::power::components::{aggregates, CORE_ROWS, TILE_ROWS};
use smart_pim::power::AreaBreakdown;
use smart_pim::sweep::{SweepRunner, SyntheticSweep};
use smart_pim::util::cli::Args;
use smart_pim::util::table::{fnum, Table};
use smart_pim::util::Rng;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!(
            "usage: smart-pim <fig4..fig11|plan|simulate|noc|serve|cluster|profile|reproduce|\
             report-all> [options]"
        );
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = match Args::parse(
        argv,
        &["batch", "no-batch", "gantt", "compare", "frontier", "capacity", "profile"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = init_arch(&args) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    // Global `--profile`: wall-clock timers on the crate's hot paths,
    // reported after the command finishes. Never perturbs simulated stats
    // (virtual time is untouched).
    if args.flag("profile") || cmd == "profile" {
        smart_pim::obs::profile::enable();
    }
    let result = match cmd.as_str() {
        "fig4" => fig4(),
        "fig5" => fig5(&args),
        "fig6" => fig6(&args),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10_11(&args, true),
        "fig11" => fig10_11(&args, false),
        "plan" => plan_cmd(&args),
        "simulate" => simulate(&args),
        "noc" => noc_cmd(&args),
        "serve" => serve(&args),
        "cluster" => cluster_cmd(&args),
        "profile" => profile_cmd(&args),
        "reproduce" => reproduce(&args),
        "dump-config" => {
            print!("{}", smart_pim::config::render_arch(&arch()));
            Ok(())
        }
        "report-all" => report_all(&args),
        other => Err(format!("unknown command {other:?}")),
    };
    // The `profile` subcommand prints its own report (it owns layout and
    // the optional --json export); --profile on any other command appends
    // the aggregate table here.
    if args.flag("profile") && cmd != "profile" {
        print!("\n{}", smart_pim::obs::profile::report_table());
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

static ACTIVE_ARCH: std::sync::OnceLock<ArchConfig> = std::sync::OnceLock::new();

/// Resolve `--config FILE` once; all commands read the active config.
fn init_arch(args: &Args) -> Result<(), String> {
    let cfg = match args.get("config") {
        Some(path) => smart_pim::config::load_arch(path, &ArchConfig::paper_node())?,
        None => ArchConfig::paper_node(),
    };
    let _ = ACTIVE_ARCH.set(cfg);
    Ok(())
}

fn arch() -> ArchConfig {
    ACTIVE_ARCH
        .get()
        .cloned()
        .unwrap_or_else(ArchConfig::paper_node)
}

/// Write a recorded trace to `path` as Chrome trace-event JSON (the
/// `--trace-out` surface; Perfetto / `chrome://tracing` load it directly).
fn write_trace(path: &str, rec: &smart_pim::obs::trace::RecordingSink) -> Result<(), String> {
    std::fs::write(path, rec.chrome_trace().render_pretty())
        .map_err(|e| format!("writing {path}: {e}"))?;
    println!("wrote trace {path} ({} events)", rec.len());
    Ok(())
}

fn fig4() -> Result<(), String> {
    let mut t = Table::new(
        "Fig. 4 — power and area of each hardware component (32 nm)",
        &["component", "area (mm^2)", "power (mW)", "count", "spec"],
    );
    for r in CORE_ROWS.iter().chain(TILE_ROWS) {
        t.row(&[
            r.name.into(),
            format!("{}", r.area_mm2),
            format!("{}", r.power_mw),
            format!("{}", r.count),
            r.spec.into(),
        ]);
    }
    t.row(&[
        "Core".into(),
        format!("{}", aggregates::CORE_AREA_MM2),
        format!("{}", aggregates::CORE_POWER_MW),
        "12/tile".into(),
        "".into(),
    ]);
    t.row(&[
        "Tile".into(),
        format!("{}", aggregates::TILE_AREA_MM2),
        format!("{}", aggregates::TILE_POWER_MW),
        "320/node".into(),
        "".into(),
    ]);
    t.row(&[
        "Node".into(),
        format!("{}", aggregates::NODE_AREA_MM2),
        format!("{}", aggregates::NODE_POWER_MW),
        "1".into(),
        "peak, all units active".into(),
    ]);
    t.print();
    let a = AreaBreakdown::node(&arch());
    println!(
        "node area check: tiles {} + routers {} = {} mm^2",
        fnum(a.tiles_mm2, 3),
        fnum(a.routers_mm2, 3),
        fnum(a.total_mm2(), 3)
    );
    Ok(())
}

fn fig5(args: &Args) -> Result<(), String> {
    args.check_known(&["noc", "config"])?;
    let noc: NocKind = args.get_or("noc", "smart").parse()?;
    let a = arch();
    let grid = Grid::run(&a, &VggVariant::ALL, &Scenario::ALL, &[noc]);
    let (t, geo) = grid.fig5_table(noc, &VggVariant::ALL);
    t.print();
    println!(
        "paper geomeans: {} / {} / {}",
        paper::FIG5_GEOMEANS[0],
        paper::FIG5_GEOMEANS[1],
        paper::FIG5_GEOMEANS[2]
    );
    println!(
        "ours:           {} / {} / {}",
        fnum(geo[0], 4),
        fnum(geo[1], 4),
        fnum(geo[2], 4)
    );
    Ok(())
}

fn fig6(args: &Args) -> Result<(), String> {
    args.check_known(&["scenario", "config"])?;
    let scenario: Scenario = args.get_or("scenario", "4").parse()?;
    let a = arch();
    let grid = Grid::run(&a, &VggVariant::ALL, &[scenario], &NocKind::ALL);
    let (t, geo) = grid.fig6_table(scenario, &VggVariant::ALL);
    t.print();
    println!(
        "paper geomean (ideal/wormhole): {}; ours smart {} ideal {}",
        paper::FIG6_IDEAL_GEOMEAN,
        fnum(geo[0], 4),
        fnum(geo[1], 4)
    );
    Ok(())
}

fn fig7() -> Result<(), String> {
    let a = arch();
    let max_convs = 16;
    let mut header: Vec<String> = vec!["layer".into()];
    header.extend(
        VggVariant::ALL
            .iter()
            .map(|v| format!("{} replicate", v.name())),
    );
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig. 7 — weight replications of each VGG", &hdr_refs);
    let plans: Vec<(usize, ReplicationPlan)> = VggVariant::ALL
        .iter()
        .map(|&v| {
            let net = vgg::build(v);
            (net.n_conv(), ReplicationPlan::fig7(v))
        })
        .collect();
    for i in 0..max_convs {
        let mut row = vec![format!("conv layer {}", i + 1)];
        for (n_conv, plan) in &plans {
            row.push(if i < *n_conv {
                plan.factor(i).to_string()
            } else {
                "N/A".into()
            });
        }
        t.row(&row);
    }
    for f in 0..3 {
        let mut row = vec![format!("fc layer {}", f + 1)];
        for (n_conv, plan) in &plans {
            row.push(plan.factor(n_conv + f).to_string());
        }
        t.row(&row);
    }
    t.print();
    for (v, (_, plan)) in VggVariant::ALL.iter().zip(&plans) {
        let net = vgg::build(*v);
        let tiles = plan_tiles(&net, &a, &plan.factors);
        println!("{}: {} tiles (budget 320)", v.name(), tiles);
    }
    Ok(())
}

fn fig8() -> Result<(), String> {
    let a = arch();
    let grid = Grid::run(&a, &[VggVariant::E], &Scenario::ALL, &NocKind::ALL);
    grid.fig8_table().print();
    println!(
        "paper best case: {} TOPS ({} FPS, smart scenario 4); wormhole {} TOPS",
        paper::FIG8_BEST_TOPS,
        paper::FIG8_BEST_FPS,
        paper::FIG8_WORMHOLE_TOPS
    );
    Ok(())
}

fn fig9() -> Result<(), String> {
    let a = arch();
    let grid = Grid::run(
        &a,
        &VggVariant::ALL,
        &[Scenario::ReplicationBatch],
        &[NocKind::Smart],
    );
    grid.fig9_table(&VggVariant::ALL).print();
    println!("paper: A 2.8841, B 2.5538, C 2.5846, D 3.1271, E 3.5914 TOPS/W");
    Ok(())
}

fn fig10_11(args: &Args, latency: bool) -> Result<(), String> {
    args.check_known(&[
        "rates", "measure", "seed", "scenario", "noc", "config", "threads", "topology",
    ])?;
    let rates: Vec<f64> = args
        .get_or("rates", "0.02,0.05,0.08,0.12,0.2,0.3,0.5,0.8")
        .split(',')
        .map(|s| s.parse::<f64>().map_err(|e| format!("{s:?}: {e}")))
        .collect::<Result<_, _>>()?;
    let measure = args.get_parse_or("measure", 6_000u64)?;
    let seed = args.get_parse_or("seed", 0xA5A5u64)?;
    let runner = match args.get("threads") {
        Some(t) => SweepRunner::with_threads(t.parse().map_err(|e| format!("--threads: {e}"))?),
        None => SweepRunner::new(),
    };
    let tkind: TopologyKind = match args.get("topology") {
        Some(t) => t.parse()?,
        None => arch().topology,
    };
    // The whole figure is one parallel sweep over the grid.
    let mut sweep = SyntheticSweep::new(AnyTopology::new(tkind, 8, 8), arch().hpc_max);
    sweep.rates = rates;
    sweep.base = SyntheticConfig {
        measure,
        warmup: measure / 4,
        drain: measure * 2,
        seed,
        ..Default::default()
    };
    sweep.per_point_seeds = false; // match the seed CLI's one-seed output
    let outcomes = sweep.run(&runner);
    let which = if latency {
        "latency (cycles)"
    } else {
        "reception (flits/node/cycle)"
    };
    for pattern in Pattern::ALL {
        let mut t = Table::new(
            format!(
                "Fig. {} — {} / {}{}",
                if latency { 10 } else { 11 },
                pattern.name(),
                which,
                match tkind {
                    TopologyKind::Mesh => String::new(),
                    other => format!(" [{}]", other.name()),
                }
            ),
            &["rate", "wormhole", "smart"],
        );
        let cell = |x: &smart_pim::noc::NocStats| {
            let v = if latency {
                x.avg_latency
            } else {
                x.reception_rate
            };
            format!(
                "{}{}",
                fnum(v, if latency { 1 } else { 4 }),
                if x.saturated() { " SAT" } else { "" }
            )
        };
        // Grid order is pattern-major, then rate, then kind (wormhole,
        // smart): consecutive outcome pairs are one table row.
        for pair in sweep.rows_for(&outcomes, pattern).chunks(2) {
            let (w, s) = (pair[0], pair[1]);
            debug_assert_eq!(w.rate, s.rate);
            t.row(&[format!("{}", w.rate), cell(&w.stats), cell(&s.stats)]);
        }
        t.print();
    }
    Ok(())
}

/// `smart-pim plan`: search a replication plan for any workload (VGG A-E
/// or ResNet-18/34) x tile budget x batch depth, confirm it through the
/// cycle-accurate engine, and report it against the paper's hand-tuned
/// Fig. 7 plan (VGGs; branching workloads compare against no replication).
fn plan_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "variant", "network", "tiles", "depth", "beam", "max-factor", "mapping", "images",
        "config", "threads",
    ])?;
    // `--network` takes any workload name; `--variant` stays as the
    // VGG-only spelling from earlier revisions.
    let name: &str = match args.get("network") {
        Some(n) => n,
        None => args.get_or("variant", "E"),
    };
    let net = smart_pim::cnn::workload(name)?;
    let a = arch();
    let tiles: usize = args.get_parse_or("tiles", a.total_tiles())?;
    let depth: u64 = args.get_parse_or("depth", 8u64)?;
    let beam: usize = args.get_parse_or("beam", 4usize)?;
    let max_factor: usize = args.get_parse_or("max-factor", 1024usize)?;
    let mapping: MappingMode = args.get_or("mapping", "im2col").parse()?;
    let images: u64 = args.get_parse_or("images", 10u64)?;
    let runner = match args.get("threads") {
        Some(t) => SweepRunner::with_threads(t.parse().map_err(|e| format!("--threads: {e}"))?),
        None => SweepRunner::new(),
    };

    let planner = Planner::new(
        &net,
        &a,
        PlannerConfig {
            tile_budget: tiles,
            batch_depth: depth,
            max_factor,
            beam_width: beam,
            mapping,
        },
    );
    let mut result = planner.search()?;
    evaluate_candidates(&net, &a, &runner, std::slice::from_mut(&mut result.best), images);

    let best = &result.best;
    // Replay the winning plan under its own mapping selection so the table
    // can show the per-layer backend and parallel-window count.
    let best_map =
        smart_pim::mapping::NetworkMapping::build_with(&net, &a, &best.plan, &best.mapping)?;
    let mut t = Table::new(
        format!(
            "searched plan — {} @ {} tiles, batch depth {depth}, mapping {mapping} \
             ({} states explored)",
            net.name,
            result.tile_budget,
            result.explored
        ),
        &["layer", "replicate", "mapping", "occupancy (cycles)"],
    );
    for (i, layer) in net.layers().iter().enumerate() {
        let lm = &best_map.layers[i];
        t.row(&[
            layer.name.clone(),
            best.plan.factor(i).to_string(),
            if lm.parallel_windows > 1 {
                format!("{} pw={}", lm.mapping, lm.parallel_windows)
            } else {
                lm.mapping.to_string()
            },
            best.assessment.occupancy[i].to_string(),
        ]);
    }
    t.print();

    let cm = smart_pim::planner::CostModel::new(&net, &a);
    // Reference plan: Fig. 7 for the VGGs, no-replication otherwise.
    let (ref_label, reference) = match net.name.parse::<VggVariant>() {
        Ok(v) => ("fig7 hand plan", cm.assess(&ReplicationPlan::fig7(v))?),
        Err(_) => ("no replication", cm.assess(&ReplicationPlan::none(&net))?),
    };
    let mut s = Table::new("plan summary", &["metric", "searched", ref_label]);
    s.row(&[
        "mapping".into(),
        best.mapping.summary(),
        "im2col".into(),
    ]);
    s.row(&[
        "tiles used".into(),
        best.assessment.tiles.to_string(),
        reference.tiles.to_string(),
    ]);
    s.row(&[
        "modeled interval (cycles)".into(),
        best.assessment.interval.to_string(),
        reference.interval.to_string(),
    ]);
    s.row(&[
        "engine interval (cycles)".into(),
        best.measured_interval
            .map(|m| fnum(m, 1))
            .unwrap_or_else(|| "-".into()),
        "-".into(),
    ]);
    s.row(&[
        "pipeline fill (cycles)".into(),
        best.assessment.fill_cycles.to_string(),
        reference.fill_cycles.to_string(),
    ]);
    s.row(&[
        "padding waste".into(),
        format!("{:.1} %", 100.0 * best.assessment.padding_waste),
        format!("{:.1} %", 100.0 * reference.padding_waste),
    ]);
    s.row(&[
        format!("modeled cycles/image @ B={depth}"),
        fnum(best.assessment.batch_cost(depth), 1),
        fnum(reference.batch_cost(depth), 1),
    ]);
    s.print();
    println!(
        "speedup vs {ref_label} (modeled steady-state): {}x",
        fnum(reference.interval as f64 / best.assessment.interval as f64, 2)
    );

    if args.flag("frontier") {
        // Frontier members are trade-off points a user may pick over
        // `best`, so they get the same engine confirmation.
        evaluate_candidates(&net, &a, &runner, &mut result.frontier, images);
        let mut f = Table::new(
            "Pareto frontier (interval vs tiles vs padding waste, engine-confirmed)",
            &["interval", "engine", "tiles", "waste", "conv factors"],
        );
        for c in &result.frontier {
            let convs: Vec<String> = net
                .layers()
                .iter()
                .zip(&c.plan.factors)
                .filter(|(l, _)| l.is_conv())
                .map(|(_, r)| r.to_string())
                .collect();
            f.row(&[
                c.assessment.interval.to_string(),
                c.measured_interval
                    .map(|m| fnum(m, 0))
                    .unwrap_or_else(|| "-".into()),
                c.assessment.tiles.to_string(),
                format!("{:.1} %", 100.0 * c.assessment.padding_waste),
                convs.join(","),
            ]);
        }
        f.print();
    }

    if args.flag("compare") {
        println!();
        mapping_compare_table(&net, &a).print();
        println!();
        planner_table(
            &a,
            &smart_pim::metrics::all_workloads(),
            tiles,
            depth,
            mapping,
            &runner,
        )?
        .print();
    }
    Ok(())
}

/// `plan --compare`: per-conv-layer subarray accounting, im2col vs VW-SDK.
/// The "per rate" columns divide each backend's subarrays per copy by the
/// output positions it retires per cycle — the honest comparison, since a
/// VW-SDK copy is bigger but runs `pw`x faster. On the paper node's
/// 128-column subarrays the two tie per rate (the column-conservation law,
/// see `mapping::backend`); VW-SDK still wins whole-layer interval where
/// its tie-break packs more parallel windows into one copy.
fn mapping_compare_table(net: &smart_pim::cnn::Network, a: &ArchConfig) -> Table {
    use smart_pim::mapping::pack_layer;
    let mut t = Table::new(
        format!("mapping comparison — {} (subarrays per replica copy)", net.name),
        &[
            "layer",
            "im2col subs",
            "vwsdk subs",
            "pw",
            "window",
            "im2col subs/rate",
            "vwsdk subs/rate",
        ],
    );
    for layer in net.layers().iter().filter(|l| l.is_conv()) {
        let seed = pack_layer(MappingKind::Im2col, layer, a);
        let vw = pack_layer(MappingKind::VwSdk, layer, a);
        let (s_subs, v_subs) = (seed.demand.subarrays(), vw.demand.subarrays());
        t.row(&[
            layer.name.clone(),
            s_subs.to_string(),
            v_subs.to_string(),
            vw.parallel_windows.to_string(),
            format!("{}x{}", vw.window.0, vw.window.1),
            fnum(s_subs as f64, 0),
            fnum(v_subs as f64 / vw.parallel_windows as f64, 2),
        ]);
    }
    t
}

fn simulate(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "vgg", "network", "scenario", "noc", "mapping", "config", "trace-out", "topology",
    ])?;
    let s: Scenario = args.get_or("scenario", "4").parse()?;
    let n: NocKind = args.get_or("noc", "smart").parse()?;
    let mapping: MappingMode = args.get_or("mapping", "im2col").parse()?;
    let mut a = arch();
    if let Some(t) = args.get("topology") {
        // Swap the fabric for this run: placement, flow extraction, and
        // the flit engine all follow `arch.topology`.
        a.topology = t.parse()?;
    }
    // `--network` runs any workload through the generic path (branching
    // workloads use the searched plan when the scenario replicates, since
    // they have no Fig. 7 hand plan).
    if let Some(name) = args.get("network") {
        if name.parse::<VggVariant>().is_err() {
            return simulate_network(name, s, n, &a, mapping, args.flag("gantt"), args);
        }
    }
    let v: VggVariant = match args.get("network") {
        Some(name) => name.parse()?,
        None => args.get_or("vgg", "E").parse()?,
    };
    if mapping != MappingMode::Im2col {
        // The classic VGG path replays the seed im2col goldens (Fig. 7 +
        // `sim::evaluate`); a non-default mapping runs the same workload
        // through the generic mapped path instead.
        return simulate_network(v.name(), s, n, &a, mapping, args.flag("gantt"), args);
    }
    let rec = args
        .get("trace-out")
        .map(|_| smart_pim::obs::trace::RecordingSink::new().shared());
    let shared = rec
        .clone()
        .map(|r| r as smart_pim::obs::trace::SharedSink);
    let r = smart_pim::sim::evaluate_traced(v, s, n, &a, shared);
    if let (Some(path), Some(sink)) = (args.get("trace-out"), &rec) {
        write_trace(path, &sink.borrow())?;
    }
    let mut t = Table::new(
        format!(
            "simulate {} scenario {} noc {}{}",
            v.name(),
            s.label(),
            n.name(),
            match a.topology {
                TopologyKind::Mesh => String::new(),
                other => format!(" topology {}", other.name()),
            }
        ),
        &["metric", "value"],
    );
    t.row(&[
        "interval (logical cycles)".into(),
        fnum(r.interval_cycles, 1),
    ]);
    t.row(&[
        "latency (logical cycles)".into(),
        fnum(r.latency_cycles, 1),
    ]);
    t.row(&["throughput (FPS)".into(), fnum(r.fps, 1)]);
    t.row(&["throughput (TOPS)".into(), fnum(r.tops, 4)]);
    t.row(&["energy/image (mJ)".into(), fnum(r.energy.total_mj(), 3)]);
    t.row(&["  core (mJ)".into(), fnum(r.energy.core_mj, 3)]);
    t.row(&["  tile periph (mJ)".into(), fnum(r.energy.tile_mj, 3)]);
    t.row(&["  noc (mJ)".into(), fnum(r.energy.noc_mj, 3)]);
    t.row(&[
        "  noc per link (uJ)".into(),
        // Total NoC energy spread over the fabric's directed link set
        // (see EnergyModel::mean_link_energy_mj).
        fnum(
            r.energy.noc_mj * 1e3 / AnyTopology::for_node(&a).n_links() as f64,
            4,
        ),
    ]);
    t.row(&["efficiency (TOPS/W)".into(), fnum(r.tops_per_watt, 4)]);
    {
        use smart_pim::power::EnergyModel;
        let em = EnergyModel::new(&a);
        t.row(&[
            "avg power (W)".into(),
            fnum(em.avg_power_w(&r.energy, r.fps), 2),
        ]);
        t.row(&[
            "peak-power utilization".into(),
            format!("{:.1} %", 100.0 * em.peak_utilization(&r.energy, r.fps)),
        ]);
    }
    if args.flag("gantt") {
        // Re-derive the stage plans for the trace view.
        use smart_pim::mapping::{NetworkMapping, Placement, ReplicationPlan};
        use smart_pim::pipeline::build_plans;
        let net = vgg::build(v);
        let plan = if s.replication() {
            ReplicationPlan::fig7(v)
        } else {
            ReplicationPlan::none(&net)
        };
        let m = NetworkMapping::build(&net, &a, &plan)?;
        let _ = Placement::for_topology(&a);
        let plans = build_plans(&net, &m, &a);
        println!("{}", smart_pim::sim::gantt(&plans, &r.sim, 100));
    }
    t.print();
    Ok(())
}

/// Generic-workload `simulate` path: searched (or none) plan + the
/// cycle-accurate engine through
/// [`smart_pim::sim::evaluate_network_mapped`]. Under a replicating
/// scenario the plan *and* the per-layer mapping selection come from the
/// planner (`--mapping auto` makes that search joint); without
/// replication, `vwsdk`/`auto` apply the VW-SDK backend uniformly — at a
/// fixed replication a VW-SDK layer retires `pw`x more positions per
/// cycle, so its interval can only improve.
#[allow(clippy::too_many_arguments)]
fn simulate_network(
    name: &str,
    s: Scenario,
    n: NocKind,
    a: &ArchConfig,
    mapping: MappingMode,
    gantt: bool,
    args: &Args,
) -> Result<(), String> {
    let net = smart_pim::cnn::workload(name)?;
    let (plan, selection) = if s.replication() {
        let r = smart_pim::planner::plan_for_mapped(&net, a, 0, mapping)?;
        (r.best.plan, r.best.mapping)
    } else {
        (ReplicationPlan::none(&net), selection_for(mapping, net.len()))
    };
    let images = smart_pim::sim::integrate::default_images(s);
    let rec = args
        .get("trace-out")
        .map(|_| smart_pim::obs::trace::RecordingSink::new().shared());
    let shared = rec
        .clone()
        .map(|r| r as smart_pim::obs::trace::SharedSink);
    let r = smart_pim::sim::evaluate_network_mapped_traced(
        &net,
        &plan,
        &selection,
        s.batch(),
        n,
        a,
        images,
        shared,
    )?;
    if let (Some(path), Some(sink)) = (args.get("trace-out"), &rec) {
        write_trace(path, &sink.borrow())?;
    }
    if gantt {
        // Re-derive the stage plans for the trace view (same as the VGG
        // path does).
        use smart_pim::mapping::NetworkMapping;
        use smart_pim::pipeline::build_plans;
        let m = NetworkMapping::build_with(&net, a, &plan, &selection)?;
        let plans = build_plans(&net, &m, a);
        println!("{}", smart_pim::sim::gantt(&plans, &r.sim, 100));
    }
    let mut t = Table::new(
        format!(
            "simulate {} scenario {} noc {} mapping {} ({} layers, {} edges, {} merges)",
            net.name,
            s.label(),
            n.name(),
            selection.summary(),
            net.len(),
            net.n_edges(),
            net.n_merge()
        ),
        &["metric", "value"],
    );
    t.row(&[
        "interval (logical cycles)".into(),
        fnum(r.interval_cycles, 1),
    ]);
    t.row(&[
        "latency (logical cycles)".into(),
        fnum(r.latency_cycles, 1),
    ]);
    t.row(&["throughput (FPS)".into(), fnum(r.fps, 1)]);
    t.row(&["throughput (TOPS)".into(), fnum(r.tops, 4)]);
    t.row(&["energy/image (mJ)".into(), fnum(r.energy.total_mj(), 3)]);
    t.row(&["efficiency (TOPS/W)".into(), fnum(r.tops_per_watt, 4)]);
    t.print();
    Ok(())
}

/// Mapping selection for a fixed (non-searched) replication plan. At a
/// fixed replication the VW-SDK backend can only lower a layer's occupancy
/// (it retires `pw` positions per cycle from one copy), so both `vwsdk`
/// and `auto` apply it uniformly; non-conv layers fall back to im2col
/// inside `NetworkMapping::build_with`.
fn selection_for(mapping: MappingMode, n: usize) -> MappingSelection {
    match mapping {
        MappingMode::Im2col => MappingSelection::im2col(n),
        MappingMode::VwSdk | MappingMode::Auto => {
            MappingSelection::uniform(MappingKind::VwSdk, n)
        }
    }
}

fn noc_cmd(args: &Args) -> Result<(), String> {
    args.check_known(&[
        "pattern", "rate", "noc", "mesh", "topology", "measure", "seed", "config", "mode",
        "trace-out", "json",
    ])?;
    let pattern: Pattern = args.get_or("pattern", "uniform_random").parse()?;
    let rate: f64 = args.get_parse_or("rate", 0.1)?;
    let kind: NocKind = args.get_or("noc", "smart").parse()?;
    // --mode reference replays the seed cycle-stepped engine (golden
    // parity; must print the exact same stats as the event-driven default).
    let mode: StepMode = args.get_or("mode", "event").parse()?;
    let mesh_s = args.get_or("mesh", "8x8");
    let (w, h) = mesh_s
        .split_once('x')
        .ok_or_else(|| format!("--mesh {mesh_s:?} (expected WxH)"))?;
    // --topology overrides the config's `topology` key for this run.
    let tkind: TopologyKind = match args.get("topology") {
        Some(t) => t.parse()?,
        None => arch().topology,
    };
    let topo = AnyTopology::new(
        tkind,
        w.parse().map_err(|e| format!("{e}"))?,
        h.parse().map_err(|e| format!("{e}"))?,
    );
    let cfg = SyntheticConfig {
        pattern,
        injection_rate: rate,
        measure: args.get_parse_or("measure", 10_000u64)?,
        seed: args.get_parse_or("seed", 0xA5A5u64)?,
        ..Default::default()
    };
    let rec = args
        .get("trace-out")
        .map(|_| smart_pim::obs::trace::RecordingSink::new().shared());
    let shared = rec
        .clone()
        .map(|r| r as smart_pim::obs::trace::SharedSink);
    let s = smart_pim::noc::run_synthetic_traced(kind, topo, &cfg, arch().hpc_max, mode, shared);
    if let (Some(path), Some(r)) = (args.get("trace-out"), &rec) {
        write_trace(path, &r.borrow())?;
    }
    println!(
        "{} {} {} rate {}: net latency {}, total latency {}, reception {}, completed {}, dropped {}{}",
        tkind.name(),
        kind.name(),
        pattern.name(),
        rate,
        fnum(s.avg_net_latency, 1),
        fnum(s.avg_latency, 1),
        fnum(s.reception_rate, 4),
        s.completed,
        s.dropped,
        if s.saturated() { " [SATURATED]" } else { "" }
    );
    // --json: one machine-readable row per run, keyed by topology, for
    // scripts and the CI determinism gate.
    if let Some(path) = args.get("json") {
        use smart_pim::util::json::Json;
        let row = Json::obj(vec![
            ("schema", Json::Str("smart-pim/noc-point/v1".into())),
            ("topology", Json::Str(tkind.name().into())),
            ("mesh", Json::Str(mesh_s.to_string())),
            ("noc", Json::Str(kind.name().into())),
            ("pattern", Json::Str(pattern.name().into())),
            ("rate", Json::Num(rate)),
            ("avg_net_latency", Json::Num(s.avg_net_latency)),
            ("avg_latency", Json::Num(s.avg_latency)),
            ("reception_rate", Json::Num(s.reception_rate)),
            ("completed", Json::Num(s.completed as f64)),
            ("dropped", Json::Num(s.dropped as f64)),
        ]);
        std::fs::write(path, row.render_pretty()).map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `smart-pim reproduce`: recompute the paper's five abstract-level
/// headline claims (best-case TOPS / FPS / TOPS/W, the ~14x pipelining
/// speedup, the ~1.08x SMART-over-wormhole speedup) plus the VW-SDK
/// mapping-search gate through the full model stack, check each against
/// its pinned tolerance band
/// (`metrics::headline::bands`), and write the scoreboard to
/// `BENCH_headline.json`. Exits non-zero when any band fails, so CI and
/// scripts can gate on it.
fn reproduce(args: &Args) -> Result<(), String> {
    args.check_known(&["json", "threads", "config"])?;
    let runner = match args.get("threads") {
        Some(t) => SweepRunner::with_threads(t.parse().map_err(|e| format!("--threads: {e}"))?),
        None => SweepRunner::new(),
    };
    println!(
        "recomputing the 6 headline metrics (20-point grid, SMART + wormhole, \
         + VW-SDK search gate) ..."
    );
    let board = smart_pim::metrics::scoreboard(&arch(), &runner);
    board.table().print();
    // Informational topology study (PR-10): the pinned claims above are
    // mesh-only; rerun the VGG-E scenario-4 SMART-vs-wormhole point per
    // fabric. Rows are reported and exported but never gate the exit code.
    let mut study = Vec::new();
    {
        let mut t = Table::new(
            "topology study (informational) — VGG-E scenario 4",
            &["topology", "wormhole FPS", "smart FPS", "smart/wormhole"],
        );
        for tk in TopologyKind::ALL {
            let mut a = arch();
            a.topology = tk;
            let fps = |k| {
                smart_pim::sim::evaluate(VggVariant::E, Scenario::ReplicationBatch, k, &a).fps
            };
            let (w, s) = (fps(NocKind::Wormhole), fps(NocKind::Smart));
            t.row(&[
                tk.name().into(),
                fnum(w, 1),
                fnum(s, 1),
                fnum(s / w, 4),
            ]);
            study.push((tk, w, s));
        }
        t.print();
    }
    let path = args.get_or("json", "BENCH_headline.json");
    let mut json = board.to_json();
    if let smart_pim::util::json::Json::Obj(kvs) = &mut json {
        use smart_pim::util::json::Json;
        kvs.push((
            "topology_study".into(),
            Json::Arr(
                study
                    .iter()
                    .map(|&(tk, w, s)| {
                        Json::obj(vec![
                            ("topology", Json::Str(tk.name().into())),
                            ("wormhole_fps", Json::Num(w)),
                            ("smart_fps", Json::Num(s)),
                            ("smart_speedup", Json::Num(s / w)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    std::fs::write(path, json.render_pretty()).map_err(|e| format!("writing {path}: {e}"))?;
    println!("wrote {path}");
    if board.all_pass() {
        println!("all 6 headline metrics within their pinned bands");
        Ok(())
    } else {
        Err(format!(
            "headline metrics out of band: {}",
            board.failures().join(", ")
        ))
    }
}

/// `smart-pim cluster`: trace-driven multi-node serving simulation over
/// node replicas running the workload's replication plan, with SLO
/// metrics; `--capacity` turns it into a planner ("minimum nodes such
/// that p99 <= --p99-target at this --qps", optionally also under a
/// fleet power budget).
fn cluster_cmd(args: &Args) -> Result<(), String> {
    use smart_pim::cluster::{
        plan_capacity, rate_from_qps, simulate_with_sink, ArrivalProcess, ClusterConfig,
        NodeModel, RouteImpl, RoutePolicy,
    };
    use smart_pim::obs::trace::{NullSink, RecordingSink};

    args.check_known(&[
        "network", "plan", "mapping", "nodes", "qps", "pattern", "trace", "route",
        "route-impl", "requests", "max-queue", "horizon", "seed", "p99-target", "max-nodes",
        "power-budget-w", "json", "threads", "config", "tenants", "residency", "mix",
        "mix-period", "trace-out",
    ])?;
    let a = arch();
    if args.get("tenants").is_some() {
        return cluster_tenants_cmd(args, &a);
    }
    for opt in ["residency", "mix", "mix-period"] {
        if args.get(opt).is_some() {
            return Err(format!("--{opt} only applies with --tenants"));
        }
    }
    let name = args.get_or("network", "vggE");
    let net = smart_pim::cnn::workload(name)?;
    let mapping: MappingMode = args.get_or("mapping", "im2col").parse()?;

    // Replication plan carried by every replica: Fig. 7 for the VGGs by
    // default (the validated single-node anchor), searched otherwise. A
    // searched plan is derived jointly with its mapping selection; the
    // fixed plans pair with the uniform selection (`selection_for`).
    let plan_name = args.get_or(
        "plan",
        if net.name.parse::<VggVariant>().is_ok() {
            "fig7"
        } else {
            "searched"
        },
    );
    let (plan, selection) = match plan_name {
        "none" => (
            ReplicationPlan::none(&net),
            selection_for(mapping, net.len()),
        ),
        "fig7" => (
            ReplicationPlan::fig7(net.name.parse::<VggVariant>().map_err(|_| {
                format!("--plan fig7 needs a VGG workload, not {}", net.name)
            })?),
            selection_for(mapping, net.len()),
        ),
        "searched" => {
            let r = smart_pim::planner::plan_for_mapped(&net, &a, 0, mapping)?;
            (r.best.plan, r.best.mapping)
        }
        other => return Err(format!("--plan {other:?} (none | fig7 | searched)")),
    };
    let model = NodeModel::from_workload_mapped(&net, &a, &plan, &selection)?;

    let qps: f64 = args.get_parse_or("qps", 500.0)?;
    if qps <= 0.0 || !qps.is_finite() {
        return Err(format!("--qps must be positive, got {qps}"));
    }
    let pattern = match args.get("trace") {
        Some(path) => {
            if args.get("pattern").is_some_and(|p| p != "trace") {
                return Err(format!(
                    "--pattern {} conflicts with --trace (a trace replaces \
                     the synthetic pattern); drop one of them",
                    args.get("pattern").unwrap_or_default()
                ));
            }
            if args.get("qps").is_some() {
                return Err(
                    "--qps conflicts with --trace (the trace fixes every \
                     arrival time); drop one of them"
                        .into(),
                );
            }
            ArrivalProcess::from_trace_file(path)?
        }
        None => {
            let p = args.get_or("pattern", "poisson");
            if p == "trace" {
                return Err("--pattern trace needs --trace FILE".into());
            }
            ArrivalProcess::from_name(p)?
        }
    };
    let capacity_mode = args.flag("capacity");
    if capacity_mode && args.get("trace-out").is_some() {
        return Err(
            "--trace-out conflicts with --capacity (the search evaluates many \
             fleets; trace a single run at the chosen size instead)"
                .into(),
        );
    }
    if capacity_mode && args.get("nodes").is_some() {
        return Err(
            "--nodes conflicts with --capacity (the planner searches the \
             fleet size); bound the search with --max-nodes instead"
                .into(),
        );
    }
    if !capacity_mode {
        for opt in ["p99-target", "max-nodes", "threads", "power-budget-w"] {
            if args.get(opt).is_some() {
                return Err(format!("--{opt} only applies with --capacity"));
            }
        }
    }
    let nodes: usize = args.get_parse_or("nodes", 4usize)?;
    if nodes == 0 {
        return Err("--nodes must be at least 1".into());
    }
    let max_nodes: usize = args.get_parse_or("max-nodes", 64usize)?;
    if max_nodes == 0 {
        return Err("--max-nodes must be at least 1".into());
    }
    // A trace fixes every arrival time, so by default the horizon covers
    // the whole trace (an explicit --horizon still windows it on purpose).
    let horizon_default = if matches!(pattern, ArrivalProcess::Trace(_)) {
        u64::MAX
    } else {
        5_000_000
    };
    // Fixed-population mode: exactly N arrivals, horizon-independent
    // (10k-node x millions-of-requests scale runs pick a count, not a
    // window — the stats then report the effective arrival span).
    let fixed_requests: Option<usize> = args.get_parse::<usize>("requests")?;
    if let Some(n) = fixed_requests {
        if n == 0 {
            return Err("--requests must be at least 1".into());
        }
        if args.get("horizon").is_some() {
            return Err(
                "--horizon conflicts with --requests (a fixed population \
                 ignores the horizon); drop one of them"
                    .into(),
            );
        }
    }
    let cfg = ClusterConfig {
        nodes,
        rate_per_cycle: rate_from_qps(qps, a.logical_cycle_ns),
        pattern,
        route: args.get_or("route", "rr").parse::<RoutePolicy>()?,
        route_impl: args.get_or("route-impl", "indexed").parse::<RouteImpl>()?,
        max_queue: args.get_parse_or("max-queue", 64u64)?,
        horizon_cycles: args.get_parse_or("horizon", horizon_default)?,
        fixed_requests,
        seed: args.get_parse_or("seed", 0xC105_7E4u64)?,
        ..ClusterConfig::default()
    };
    let ms = |cycles: f64| cycles * a.logical_cycle_ns / 1e6;

    let fleet = if capacity_mode {
        format!("<={max_nodes} (searching)")
    } else {
        cfg.nodes.to_string()
    };
    let load = if matches!(cfg.pattern, ArrivalProcess::Trace(_)) {
        "trace-driven arrivals".to_string()
    } else if let Some(n) = cfg.fixed_requests {
        format!("{qps} qps {} arrivals (fixed {n} requests)", cfg.pattern.name())
    } else {
        format!("{qps} qps {} arrivals", cfg.pattern.name())
    };
    println!(
        "cluster: {} x {} ({} plan, {} mapping, interval {} cycles, fill {} cycles), \
         {load}, route {}, max queue {}",
        fleet,
        net.name,
        plan_name,
        selection.summary(),
        model.interval,
        model.fill,
        cfg.route.name(),
        cfg.max_queue
    );

    let stats = if capacity_mode {
        let target: u64 = args
            .get_parse::<u64>("p99-target")?
            .ok_or("--capacity needs --p99-target CYCLES")?;
        let power_budget: Option<f64> = args.get_parse::<f64>("power-budget-w")?;
        let runner = match args.get("threads") {
            Some(t) => {
                SweepRunner::with_threads(t.parse().map_err(|e| format!("--threads: {e}"))?)
            }
            None => SweepRunner::new(),
        };
        let r = plan_capacity(&model, &cfg, target, max_nodes, power_budget, &runner)?;
        let budget_note = match power_budget {
            Some(b) => format!(", fleet power <= {b} W"),
            None => String::new(),
        };
        let mut t = Table::new(
            format!(
                "capacity search — p99 <= {target} cycles ({} ms){budget_note}, {load}",
                fnum(ms(target as f64), 2)
            ),
            &["nodes", "p99 (cycles)", "rejected", "power (W)", "meets SLO"],
        );
        for p in &r.evaluated {
            t.row(&[
                p.nodes.to_string(),
                p.p99.to_string(),
                p.rejected.to_string(),
                p.power_w.map(|w| fnum(w, 1)).unwrap_or_else(|| "-".into()),
                if p.meets { "yes" } else { "no" }.into(),
            ]);
        }
        t.print();
        println!(
            "minimum fleet: {} nodes (confirmed by direct simulation below)",
            r.nodes
        );
        r.stats
    } else if let Some(path) = args.get("trace-out") {
        let mut sink = RecordingSink::new();
        let s = simulate_with_sink(&model, &cfg, &mut sink);
        write_trace(path, &sink)?;
        s
    } else {
        simulate_with_sink(&model, &cfg, &mut NullSink)
    };

    let mut t = Table::new(
        format!(
            "cluster stats — {} offered, seed {:#x}",
            stats.offered, cfg.seed
        ),
        &["metric", "value"],
    );
    t.row(&["completed".into(), stats.completed.to_string()]);
    t.row(&["rejected".into(), stats.rejected.to_string()]);
    t.row(&[
        "rejection rate".into(),
        format!("{:.2} %", 100.0 * stats.rejection_rate()),
    ]);
    t.row(&[
        "throughput (req/s)".into(),
        fnum(stats.throughput_rps(a.logical_cycle_ns), 1),
    ]);
    for (label, cycles) in [
        ("latency mean", stats.latency.mean()),
        ("latency p50", stats.latency.p50() as f64),
        ("latency p95", stats.latency.p95() as f64),
        ("latency p99", stats.latency.p99() as f64),
        ("latency p999", stats.latency.p999() as f64),
        ("latency max", stats.latency.max() as f64),
        ("queueing p99", stats.queueing.p99() as f64),
    ] {
        t.row(&[
            format!("{label} (cycles | ms)"),
            format!("{} | {}", fnum(cycles, 1), fnum(ms(cycles), 3)),
        ]);
    }
    t.row(&[
        "mean node utilization".into(),
        format!("{:.1} %", 100.0 * stats.mean_utilization()),
    ]);
    let util_cells: Vec<String> = stats
        .node_utilization
        .iter()
        .map(|u| format!("{:.0}%", 100.0 * u))
        .collect();
    t.row(&["per-node utilization".into(), util_cells.join(" ")]);
    t.row(&[
        "calendar events | peak depth".into(),
        format!("{} | {}", stats.events_processed, stats.peak_calendar_depth),
    ]);
    if let Some(e) = &stats.energy {
        t.row(&[
            "energy / image (mJ)".into(),
            fnum(e.joules_per_image() * 1e3, 2),
        ]);
        t.row(&["fleet avg power (W)".into(), fnum(e.avg_power_w(), 2)]);
        t.row(&["fleet TOPS/W".into(), fnum(e.tops_per_watt(), 4)]);
        t.row(&[
            "energy dynamic | idle (J)".into(),
            format!("{} | {}", fnum(e.dynamic_j, 2), fnum(e.idle_j, 2)),
        ]);
        t.row(&["padding waste (J)".into(), fnum(e.padding_waste_j, 3)]);
    }
    t.print();

    if let Some(path) = args.get("json") {
        let doc = stats.to_json(a.logical_cycle_ns);
        std::fs::write(path, doc.render_pretty())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `smart-pim cluster --tenants name[:weight],...`: multi-tenant serving
/// over the same fleet. Each tenant is a full workload priced end to end
/// (Fig. 7 plan for the VGGs, unreplicated otherwise), including its
/// ReRAM weight-programming [`WriteCost`](smart_pim::power::WriteCost) —
/// what a reprogram-on-miss model swap costs in latency and energy.
fn cluster_tenants_cmd(args: &Args, a: &ArchConfig) -> Result<(), String> {
    use smart_pim::cluster::{
        rate_from_qps, simulate_tenants_with_sink, ArrivalProcess, MixMode, NodeModel,
        Residency, RouteImpl, TenantConfig, TenantRoute, TenantWorkload,
    };
    use smart_pim::mapping::NetworkMapping;
    use smart_pim::obs::trace::{NullSink, RecordingSink};
    use smart_pim::power::WriteCost;

    for opt in [
        "network", "plan", "mapping", "p99-target", "max-nodes", "power-budget-w", "threads",
    ] {
        if args.get(opt).is_some() {
            return Err(format!("--{opt} does not apply with --tenants"));
        }
    }
    if args.flag("capacity") {
        return Err(
            "--capacity does not apply with --tenants (the tenant capacity \
             ladder is `cluster::tenant_capacity_ladder`)"
                .into(),
        );
    }

    // Parse `name[:weight],...` into priced workloads. Every tenant runs
    // its own validated replication plan, and its write cost is derived
    // from the *mapped* footprint — the same subarrays the plan programs.
    let spec = args.get("tenants").expect("branch guarded on --tenants");
    let mut tenants: Vec<TenantWorkload> = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let part = part.trim();
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => (
                n,
                w.parse::<f64>()
                    .map_err(|e| format!("--tenants weight {w:?} for {n}: {e}"))?,
            ),
            None => (part, 1.0),
        };
        if weight <= 0.0 || !weight.is_finite() {
            return Err(format!(
                "--tenants weight for {name:?} must be positive, got {weight}"
            ));
        }
        let net = smart_pim::cnn::workload(name)?;
        let plan = match net.name.parse::<VggVariant>() {
            Ok(v) => ReplicationPlan::fig7(v),
            Err(_) => ReplicationPlan::none(&net),
        };
        let model = NodeModel::from_workload(&net, a, &plan)?;
        let mapping = NetworkMapping::build(&net, a, &plan)?;
        let write = WriteCost::of_mapping(&net, &mapping, a);
        tenants.push(TenantWorkload::from_model(&net.name, weight, &model, write));
    }
    if tenants.is_empty() {
        return Err("--tenants needs at least one workload (name[:weight],...)".into());
    }

    let qps: f64 = args.get_parse_or("qps", 500.0)?;
    if qps <= 0.0 || !qps.is_finite() {
        return Err(format!("--qps must be positive, got {qps}"));
    }
    let pattern = match args.get("trace") {
        Some(path) => {
            if args.get("pattern").is_some_and(|p| p != "trace") {
                return Err(format!(
                    "--pattern {} conflicts with --trace (a trace replaces \
                     the synthetic pattern); drop one of them",
                    args.get("pattern").unwrap_or_default()
                ));
            }
            if args.get("qps").is_some() {
                return Err(
                    "--qps conflicts with --trace (the trace fixes every \
                     arrival time); drop one of them"
                        .into(),
                );
            }
            ArrivalProcess::from_trace_file(path)?
        }
        None => {
            let p = args.get_or("pattern", "poisson");
            if p == "trace" {
                return Err("--pattern trace needs --trace FILE".into());
            }
            ArrivalProcess::from_name(p)?
        }
    };
    let nodes: usize = args.get_parse_or("nodes", 4usize)?;
    if nodes == 0 {
        return Err("--nodes must be at least 1".into());
    }
    let horizon_default = if matches!(pattern, ArrivalProcess::Trace(_)) {
        u64::MAX
    } else {
        5_000_000
    };
    let fixed_requests: Option<usize> = args.get_parse::<usize>("requests")?;
    if let Some(n) = fixed_requests {
        if n == 0 {
            return Err("--requests must be at least 1".into());
        }
        if args.get("horizon").is_some() {
            return Err(
                "--horizon conflicts with --requests (a fixed population \
                 ignores the horizon); drop one of them"
                    .into(),
            );
        }
    }
    let mix = MixMode::from_name(
        args.get_or("mix", "static"),
        args.get_parse_or("mix-period", 1_000_000u64)?,
    )?;
    let cfg = TenantConfig {
        nodes,
        residency: args.get_or("residency", "reprogram").parse::<Residency>()?,
        route: args.get_or("route", "jsq").parse::<TenantRoute>()?,
        route_impl: args.get_or("route-impl", "indexed").parse::<RouteImpl>()?,
        pattern,
        rate_per_cycle: rate_from_qps(qps, a.logical_cycle_ns),
        mix,
        max_queue: args.get_parse_or("max-queue", 64u64)?,
        horizon_cycles: args.get_parse_or("horizon", horizon_default)?,
        fixed_requests,
        seed: args.get_parse_or("seed", 0xC105_7E4u64)?,
    };
    let ms = |cycles: f64| cycles * a.logical_cycle_ns / 1e6;

    let load = if matches!(cfg.pattern, ArrivalProcess::Trace(_)) {
        "trace-driven arrivals".to_string()
    } else if let Some(n) = cfg.fixed_requests {
        format!("{qps} qps {} arrivals (fixed {n} requests)", cfg.pattern.name())
    } else {
        format!("{qps} qps {} arrivals", cfg.pattern.name())
    };
    println!(
        "cluster tenants: {} nodes, {} residency, {} route, {} mix, {load}, max queue {}",
        cfg.nodes,
        cfg.residency.name(),
        cfg.route.name(),
        cfg.mix.name(),
        cfg.max_queue
    );
    for t in &tenants {
        println!(
            "  {} (weight {}): interval {} cycles, fill {} cycles, reprogram \
             {} rows = {} cycles / {} J",
            t.name,
            t.weight,
            t.interval,
            t.fill,
            t.write.rows,
            t.write.latency_cycles,
            fnum(t.write.energy_j, 3)
        );
    }

    let stats = if let Some(path) = args.get("trace-out") {
        let mut sink = RecordingSink::new();
        let s = simulate_tenants_with_sink(&tenants, &cfg, &mut sink)?;
        write_trace(path, &sink)?;
        s
    } else {
        simulate_tenants_with_sink(&tenants, &cfg, &mut NullSink)?
    };

    let mut t = Table::new(
        format!(
            "per-tenant stats — {} offered, seed {:#x} (latency in cycles)",
            stats.offered, cfg.seed
        ),
        &[
            "tenant", "offered", "completed", "rejected", "p50", "p95", "p99", "p999",
            "swaps", "swap energy (J)",
        ],
    );
    for ts in &stats.tenants {
        t.row(&[
            ts.name.clone(),
            ts.offered.to_string(),
            ts.completed.to_string(),
            format!("{} ({:.2} %)", ts.rejected, 100.0 * ts.rejection_rate()),
            ts.latency.p50().to_string(),
            ts.latency.p95().to_string(),
            ts.latency.p99().to_string(),
            ts.latency.p999().to_string(),
            ts.swaps.to_string(),
            fnum(ts.swap_energy_j, 3),
        ]);
    }
    t.print();

    let mut f = Table::new("fleet summary", &["metric", "value"]);
    f.row(&["completed".into(), stats.completed.to_string()]);
    f.row(&["rejected".into(), stats.rejected.to_string()]);
    f.row(&["model swaps".into(), stats.total_swaps().to_string()]);
    f.row(&[
        "swap energy (J)".into(),
        fnum(stats.total_swap_energy_j(), 3),
    ]);
    if let Some(p) = &stats.partition {
        let cells: Vec<String> = stats
            .tenants
            .iter()
            .zip(p)
            .map(|(ts, n)| format!("{}:{}", ts.name, n))
            .collect();
        f.row(&["partition (nodes per tenant)".into(), cells.join(" ")]);
    }
    let mean_util = if stats.node_utilization.is_empty() {
        0.0
    } else {
        stats.node_utilization.iter().sum::<f64>() / stats.node_utilization.len() as f64
    };
    f.row(&[
        "mean node utilization".into(),
        format!("{:.1} %", 100.0 * mean_util),
    ]);
    f.row(&[
        "drained at (cycles | ms)".into(),
        format!(
            "{} | {}",
            stats.drained_at,
            fnum(ms(stats.drained_at as f64), 3)
        ),
    ]);
    f.row(&[
        "calendar events | peak depth".into(),
        format!("{} | {}", stats.events_processed, stats.peak_calendar_depth),
    ]);
    if let Some(e) = &stats.energy {
        f.row(&[
            "energy / image (mJ)".into(),
            fnum(e.joules_per_image() * 1e3, 2),
        ]);
        f.row(&[
            "energy dynamic | idle (J)".into(),
            format!("{} | {}", fnum(e.dynamic_j, 2), fnum(e.idle_j, 2)),
        ]);
        f.row(&[
            "energy weight writes (J)".into(),
            fnum(e.weight_writes_j, 3),
        ]);
    }
    f.print();

    if let Some(path) = args.get("json") {
        let doc = stats.to_json(a.logical_cycle_ns);
        std::fs::write(path, doc.render_pretty())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `smart-pim profile`: a canned micro-suite over the crate's profiled
/// hot paths (NoC sweep points, a planner search, both cluster engines),
/// reported as wall-clock section timings. Simulated results are
/// discarded — this command measures the simulator, not the paper.
fn profile_cmd(args: &Args) -> Result<(), String> {
    use smart_pim::cluster::{
        rate_from_qps, simulate_tenants_with_sink, simulate_with_sink, ArrivalProcess,
        ClusterConfig, NodeModel, TenantConfig, TenantWorkload,
    };
    use smart_pim::mapping::NetworkMapping;
    use smart_pim::obs::trace::NullSink;
    use smart_pim::power::WriteCost;

    args.check_known(&["json", "config"])?;
    let a = arch();
    println!("profile micro-suite (wall-clock; virtual-time results discarded)");

    // NoC sweep points: a few synthetic 8x8 runs through the SweepRunner,
    // so `sweep.point` shows per-point cost.
    {
        let kind: NocKind = "smart".parse()?;
        let mode: StepMode = "event".parse()?;
        let pattern: Pattern = "uniform_random".parse()?;
        let mesh = Mesh::new(8, 8);
        let rates = [0.02f64, 0.06, 0.10];
        let runner = SweepRunner::with_threads(1);
        let _ = runner.run(&rates, |i, &rate| {
            let cfg = SyntheticConfig {
                pattern,
                injection_rate: rate,
                measure: 2_000,
                seed: 0xA5A5 + i as u64,
                ..Default::default()
            };
            smart_pim::noc::run_synthetic_traced(kind, mesh, &cfg, a.hpc_max, mode, None)
        });
    }

    // Planner search on a non-VGG workload (`planner.search` /
    // `planner.round`).
    {
        let net = smart_pim::cnn::workload("resnet18")?;
        let _ = smart_pim::planner::plan_for_mapped(&net, &a, 0, MappingMode::Im2col)?;
    }

    // Cluster event loop (`cluster.simulate`) on the VGG-E anchor.
    let (net, model) = {
        let net = smart_pim::cnn::workload("vggE")?;
        let plan = ReplicationPlan::fig7(net.name.parse::<VggVariant>().expect("vggE"));
        let model = NodeModel::from_workload(&net, &a, &plan)?;
        (net, model)
    };
    {
        let cfg = ClusterConfig {
            nodes: 4,
            rate_per_cycle: rate_from_qps(2_000.0, a.logical_cycle_ns),
            pattern: ArrivalProcess::from_name("poisson")?,
            horizon_cycles: 2_000_000,
            seed: 0xC105_7E4,
            ..ClusterConfig::default()
        };
        let _ = simulate_with_sink(&model, &cfg, &mut NullSink);
    }

    // Multi-tenant loop (`tenant.simulate`): two tenants sharing the fleet
    // under reprogram-on-miss, so swap costs are exercised too.
    {
        let net_b = smart_pim::cnn::workload("vggA")?;
        let plan_b = ReplicationPlan::fig7(net_b.name.parse::<VggVariant>().expect("vggA"));
        let model_b = NodeModel::from_workload(&net_b, &a, &plan_b)?;
        let tenants = vec![
            TenantWorkload::from_model(
                &net.name,
                1.0,
                &model,
                WriteCost::of_mapping(
                    &net,
                    &NetworkMapping::build(&net, &a, &ReplicationPlan::fig7(VggVariant::E))?,
                    &a,
                ),
            ),
            TenantWorkload::from_model(
                &net_b.name,
                1.0,
                &model_b,
                WriteCost::of_mapping(
                    &net_b,
                    &NetworkMapping::build(&net_b, &a, &plan_b)?,
                    &a,
                ),
            ),
        ];
        let cfg = TenantConfig {
            nodes: 4,
            residency: "reprogram".parse()?,
            route: "jsq".parse()?,
            route_impl: "indexed".parse()?,
            pattern: ArrivalProcess::from_name("poisson")?,
            rate_per_cycle: rate_from_qps(1_000.0, a.logical_cycle_ns),
            mix: smart_pim::cluster::MixMode::from_name("alternate", 250_000)?,
            max_queue: 64,
            horizon_cycles: 1_000_000,
            fixed_requests: None,
            seed: 0xC105_7E4,
        };
        let _ = simulate_tenants_with_sink(&tenants, &cfg, &mut NullSink)?;
    }

    print!("{}", smart_pim::obs::profile::report_table());
    if let Some(path) = args.get("json") {
        let doc = smart_pim::obs::profile::report_json();
        std::fs::write(path, doc.render_pretty())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<(), String> {
    args.check_known(&["requests", "artifacts", "seed", "config", "plan-variant", "tiles"])?;
    let n: usize = args.get_parse_or("requests", 32usize)?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let seed: u64 = args.get_parse_or("seed", 7u64)?;
    let policy = BatchPolicy::default();

    // Startup planning: derive the PIM node's replication plan from the
    // live batching configuration (largest executable batch = the batch
    // depth the pipeline will see), instead of replaying Fig. 7.
    let a = arch();
    let plan_variant: VggVariant = args.get_or("plan-variant", "E").parse()?;
    let budget: usize = args.get_parse_or("tiles", a.total_tiles())?;
    // Planning is advisory for the serve path (the PJRT model is the
    // tiny-VGG, the plan describes the simulated full-scale node), so a
    // node too small for the planned variant must not stop serving.
    match startup_plan(plan_variant, &a, &policy, budget) {
        Ok(sp) => {
            println!(
                "startup plan: {} on {} tiles (budget {}), batch depth {} -> \
                 interval {} cycles modeled / {} measured, fill {} cycles",
                sp.variant.name(),
                sp.candidate.assessment.tiles,
                sp.tile_budget,
                sp.batch_depth,
                sp.candidate.assessment.interval,
                sp.candidate
                    .measured_interval
                    .map(|m| fnum(m, 0))
                    .unwrap_or_else(|| "-".into()),
                sp.candidate.assessment.fill_cycles,
            );
            // The dispatcher enforces the plan's hazard-free injection beat.
            use smart_pim::coordinator::Dispatcher;
            let mut d = Dispatcher::new(sp.shape.clone());
            for i in 0..n as u64 {
                d.admit(i);
            }
            d.verify_no_hazard()?;
            println!(
                "dispatcher: {} admissions at min interval {} cycles, hazard-free",
                n,
                sp.min_interval()
            );
        }
        Err(e) => println!("startup plan unavailable ({e}); serving without one"),
    }

    let mut server = Server::start(dir, policy).map_err(|e| format!("{e:#}"))?;
    let mut rng = Rng::new(seed);
    println!("serving {n} synthetic images through the PJRT-compiled tiny-VGG ...");
    let mut pending = Vec::new();
    for _ in 0..n {
        let image: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.next_f64() as f32).collect();
        pending.push(server.submit(image));
    }
    let mut classes = vec![0u64; 10];
    for rx in pending {
        let resp = rx.recv().map_err(|_| "worker died".to_string())??;
        classes[resp.class] += 1;
    }
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches (hist 1:{} 2:{} 3:{} 4:{})",
        stats.served,
        stats.batches,
        stats.batch_hist[1],
        stats.batch_hist[2],
        stats.batch_hist[3],
        stats.batch_hist[4]
    );
    println!(
        "throughput {} req/s, latency mean {} ms, p50 {} ms, p99 {} ms",
        fnum(stats.throughput(), 1),
        fnum(stats.mean_latency_ms(), 2),
        fnum(stats.latency_percentile_ms(50.0), 2),
        fnum(stats.latency_percentile_ms(99.0), 2)
    );
    println!("class histogram: {classes:?}");
    // Simulated fabric-crossing cost of the request path, through the same
    // NocBackend trait the sweeps use (the coordinator's ingress model).
    let topo = AnyTopology::for_node(&a);
    let mut noc = build_backend(NocKind::Smart, topo, a.hpc_max, 1, a.buffer_depth);
    let ing = assess_ingress(noc.as_mut(), 0, topo.nodes() / 2, n as u64, 4, 4);
    println!(
        "simulated ingress (I/O tile -> entry tile over SMART mesh): \
         mean {} NoC cycles, max {} ({}/{} delivered)",
        fnum(ing.mean_latency_cycles, 1),
        fnum(ing.max_latency_cycles, 0),
        ing.delivered,
        ing.offered
    );
    Ok(())
}

fn report_all(args: &Args) -> Result<(), String> {
    fig4()?;
    println!();
    fig7()?;
    println!();
    let a = arch();
    planner_table(
        &a,
        &smart_pim::metrics::all_workloads(),
        a.total_tiles(),
        8,
        MappingMode::Auto,
        &SweepRunner::new(),
    )?
    .print();
    println!();
    fig5(args)?;
    println!();
    fig6(args)?;
    println!();
    fig8()?;
    println!();
    fig9()?;
    println!();
    cluster_table(&a, &SweepRunner::new())?.print();
    println!();
    tenant_table(&a, &SweepRunner::new())?.print();
    println!();
    fig10_11(args, true)?;
    fig10_11(args, false)?;
    Ok(())
}
