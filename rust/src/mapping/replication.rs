//! Weight replication (Sec. VI-C, Fig. 7).
//!
//! Pooling between layers starves the inter-layer pipeline, so early
//! high-resolution layers are replicated more: the paper replicates
//! 16/8/4/2/1x following the five down-sampling steps, hand-tuned per VGG
//! variant so the whole network fits in 320 tiles. This module carries the
//! paper's Fig. 7 table verbatim plus an automatic planner that derives a
//! balanced plan for any network under a tile budget.

use crate::cnn::{Network, VggVariant};
use crate::config::ArchConfig;

use super::backend::{pack_layer, MappingKind, MappingSelection};
use super::subarray::SubarrayDemand;

/// Replication factors, one per layer (convs then FCs), aligned with
/// `Network::layers()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicationPlan {
    /// Per-layer replication factors, aligned with `Network::layers()`.
    pub factors: Vec<usize>,
}

impl ReplicationPlan {
    /// All-ones plan (scenarios (1) and (2)).
    pub fn none(net: &Network) -> Self {
        Self {
            factors: vec![1; net.len()],
        }
    }

    /// The paper's Fig. 7 plan for a VGG variant (scenarios (3) and (4)).
    pub fn fig7(variant: VggVariant) -> Self {
        let conv: &[usize] = match variant {
            VggVariant::A => &[16, 8, 4, 4, 2, 2, 1, 1],
            VggVariant::B => &[16, 16, 8, 8, 4, 4, 2, 2, 1, 1],
            VggVariant::C => &[16, 16, 8, 8, 4, 4, 4, 2, 2, 2, 1, 1, 1],
            VggVariant::D => &[16, 16, 8, 8, 4, 4, 4, 2, 2, 2, 1, 1, 1],
            VggVariant::E => &[16, 16, 8, 8, 4, 4, 4, 4, 2, 2, 2, 2, 1, 1, 1, 1],
        };
        let mut factors = conv.to_vec();
        factors.extend_from_slice(&[1, 1, 1]); // fc1..3 (Fig. 7 bottom rows)
        Self { factors }
    }

    /// Derive a plan automatically: start from the pooling-trend ideal
    /// (factor = IFM area ratio to the last conv, capped at `max_factor`)
    /// and degrade the cheapest layers until the tile budget holds.
    ///
    /// This is the planner a user would call for a non-VGG network; for the
    /// paper's VGGs it reproduces Fig. 7's shape (checked in tests).
    pub fn auto(net: &Network, arch: &ArchConfig, max_factor: usize) -> Self {
        let layers = net.layers();
        // Ideal factor: proportional to output pixels of the layer relative
        // to the deepest conv, rounded down to a power of two (the paper
        // replicates in powers of two following the 2x2 pool trend).
        let min_pixels = layers
            .iter()
            .filter(|l| l.is_conv())
            .map(|l| l.out_pixels())
            .min()
            .unwrap_or(1)
            .max(1);
        let mut factors: Vec<usize> = layers
            .iter()
            .map(|l| {
                if !l.is_conv() {
                    return 1;
                }
                let ratio = (l.out_pixels() / min_pixels).max(1) as usize;
                let mut f = 1;
                while f * 2 <= ratio && f * 2 <= max_factor {
                    f *= 2;
                }
                f
            })
            .collect();
        // Degrade until within budget: repeatedly halve the factor of the
        // layer whose halving saves the most tiles per lost throughput
        // (cheapest = largest tile saving relative to its occupancy growth).
        let budget = arch.total_tiles();
        loop {
            let total = plan_tiles(net, arch, &factors);
            if total <= budget {
                break;
            }
            // Pick the halvable layer with the largest tile footprint.
            let victim = (0..layers.len())
                .filter(|&i| factors[i] > 1)
                .max_by_key(|&i| {
                    SubarrayDemand::of(&layers[i], arch).tiles(factors[i], arch)
                });
            match victim {
                Some(i) => factors[i] /= 2,
                None => break, // nothing left to shrink; caller validates
            }
        }
        Self { factors }
    }

    /// Derive a plan by *search* (the replacement for the hand-tuned Fig. 7
    /// table): greedy bottleneck-lifting with a small beam over the slowest
    /// stage, priced by the pipeline occupancy model, under `tile_budget`
    /// tiles (0 = the node's full tile count). For the paper's VGGs at the
    /// 320-tile budget the searched plan meets or beats the Fig. 7 plan's
    /// modeled steady-state interval (pinned by
    /// `rust/tests/golden_planner.rs`). Errors when the network does not
    /// fit the budget even unreplicated.
    ///
    /// See [`crate::planner`] for the full search result (Pareto frontier,
    /// batch-depth-aware costs, engine confirmation).
    pub fn searched(
        net: &Network,
        arch: &ArchConfig,
        tile_budget: usize,
    ) -> Result<Self, String> {
        Ok(crate::planner::plan_for(net, arch, tile_budget)?.best.plan)
    }

    /// Factor for layer index `i`.
    pub fn factor(&self, i: usize) -> usize {
        self.factors[i]
    }

    /// Number of per-layer factors.
    pub fn len(&self) -> usize {
        self.factors.len()
    }

    /// True when the plan covers no layers.
    pub fn is_empty(&self) -> bool {
        self.factors.is_empty()
    }
}

/// The per-layer tile-accounting rule, in one place for both the
/// planner's budget pre-check ([`plan_tiles`]) and the real mapping
/// ([`super::layout::NetworkMapping::build`]): returns `(tiles,
/// reload_rounds)` for `r` replicas of `layer`.
///
/// - conv layers own whole tiles for all replicas;
/// - FC layers time-multiplex their crossbars over `fc_reload_rounds`
///   rounds (DESIGN.md §1, substitution for the paper's unexplained FC
///   capacity) and are charged 1/rounds of their full demand;
/// - dataflow stages (merge nodes, global pooling) hold no weights and
///   own one buffer tile whose S&A/OR path executes them.
pub fn layer_tiles(
    layer: &crate::cnn::Layer,
    r: usize,
    arch: &ArchConfig,
) -> (usize, u64) {
    layer_tiles_with(layer, r, arch, MappingKind::Im2col)
}

/// [`layer_tiles`] under an explicit mapping backend. Only conv layers are
/// backend-sensitive: FC layers have no spatial window to vary and dataflow
/// stages hold no weights, so both ignore `kind`.
pub fn layer_tiles_with(
    layer: &crate::cnn::Layer,
    r: usize,
    arch: &ArchConfig,
    kind: MappingKind,
) -> (usize, u64) {
    if layer.is_conv() {
        (pack_layer(kind, layer, arch).demand.tiles(r, arch), 1)
    } else if layer.is_fc() {
        let t = SubarrayDemand::of(layer, arch)
            .subarrays_replicated(r)
            .div_ceil(arch.fc_reload_rounds as usize)
            .div_ceil(arch.subarrays_per_tile())
            .max(1);
        (t, arch.fc_reload_rounds)
    } else {
        (1, 1)
    }
}

/// Total tiles consumed by a plan (each layer owns whole tiles).
pub fn plan_tiles(net: &Network, arch: &ArchConfig, factors: &[usize]) -> usize {
    assert_eq!(factors.len(), net.len());
    net.layers()
        .iter()
        .zip(factors)
        .map(|(l, &r)| layer_tiles(l, r, arch).0)
        .sum()
}

/// [`plan_tiles`] under a per-layer mapping selection.
pub fn plan_tiles_with(
    net: &Network,
    arch: &ArchConfig,
    factors: &[usize],
    selection: &MappingSelection,
) -> usize {
    assert_eq!(factors.len(), net.len());
    assert_eq!(selection.len(), net.len());
    net.layers()
        .iter()
        .enumerate()
        .zip(factors)
        .map(|((i, l), &r)| layer_tiles_with(l, r, arch, selection.kind(i)).0)
        .sum()
}

/// Validate a plan: arity, positivity, and the 320-tile constraint.
pub fn validate_plan(
    net: &Network,
    arch: &ArchConfig,
    plan: &ReplicationPlan,
) -> Result<usize, String> {
    validate_plan_with(net, arch, plan, &MappingSelection::im2col(net.len()))
}

/// [`validate_plan`] under a per-layer mapping selection.
pub fn validate_plan_with(
    net: &Network,
    arch: &ArchConfig,
    plan: &ReplicationPlan,
    selection: &MappingSelection,
) -> Result<usize, String> {
    if plan.len() != net.len() {
        return Err(format!(
            "plan arity {} != network {} layers",
            plan.len(),
            net.len()
        ));
    }
    if selection.len() != net.len() {
        return Err(format!(
            "mapping selection arity {} != network {} layers",
            selection.len(),
            net.len()
        ));
    }
    if plan.factors.iter().any(|&f| f == 0) {
        return Err("replication factors must be >= 1".into());
    }
    let tiles = plan_tiles_with(net, arch, &plan.factors, selection);
    if tiles > arch.total_tiles() {
        return Err(format!(
            "plan needs {tiles} tiles > budget {}",
            arch.total_tiles()
        ));
    }
    Ok(tiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::vgg;

    #[test]
    fn fig7_matches_conv_counts() {
        for v in VggVariant::ALL {
            let net = vgg::build(v);
            let plan = ReplicationPlan::fig7(v);
            assert_eq!(plan.len(), net.len(), "{}", v.name());
        }
    }

    #[test]
    fn fig7_plans_fit_320_tiles() {
        // Sec. VI-C: "All schemes meet the constraint that there are a
        // maximum of 320 tiles available."
        let arch = ArchConfig::paper_node();
        for v in VggVariant::ALL {
            let net = vgg::build(v);
            let plan = ReplicationPlan::fig7(v);
            let tiles = validate_plan(&net, &arch, &plan)
                .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
            assert!(tiles <= 320, "{}: {tiles} tiles", v.name());
        }
    }

    #[test]
    fn fig7_first_layer_is_16x() {
        for v in VggVariant::ALL {
            assert_eq!(ReplicationPlan::fig7(v).factor(0), 16);
        }
    }

    #[test]
    fn fig7_decreasing_with_depth() {
        for v in VggVariant::ALL {
            let plan = ReplicationPlan::fig7(v);
            for w in plan.factors.windows(2) {
                assert!(w[1] <= w[0], "{:?} not non-increasing", plan.factors);
            }
        }
    }

    #[test]
    fn none_plan_is_all_ones() {
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        assert!(plan.factors.iter().all(|&f| f == 1));
        validate_plan(&net, &ArchConfig::paper_node(), &plan).unwrap();
    }

    #[test]
    fn auto_plan_fits_budget_and_tracks_pool_trend() {
        let arch = ArchConfig::paper_node();
        for v in VggVariant::ALL {
            let net = vgg::build(v);
            let plan = ReplicationPlan::auto(&net, &arch, 16);
            let tiles = validate_plan(&net, &arch, &plan)
                .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
            assert!(tiles <= arch.total_tiles());
            // First conv is the most replicated.
            assert!(plan.factor(0) >= *plan.factors.iter().max().unwrap() / 2);
        }
    }

    #[test]
    fn searched_plan_validates() {
        // One variant: the all-VGG domination sweep is
        // rust/tests/golden_planner.rs's job; this only covers the
        // mapping-layer API path.
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::searched(&net, &arch, 320).unwrap();
        let tiles = validate_plan(&net, &arch, &plan).unwrap();
        assert!(tiles <= 320, "{tiles}");
        assert!(plan.factors.iter().all(|&f| f.is_power_of_two()));
    }

    #[test]
    fn with_variants_default_to_seed() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let sel = MappingSelection::im2col(net.len());
        assert_eq!(
            plan_tiles_with(&net, &arch, &plan.factors, &sel),
            plan_tiles(&net, &arch, &plan.factors)
        );
        assert_eq!(
            validate_plan_with(&net, &arch, &plan, &sel).unwrap(),
            validate_plan(&net, &arch, &plan).unwrap()
        );
    }

    #[test]
    fn vwsdk_fig7_plans_still_fit_320_tiles() {
        // The enlarged stem windows grow conv1's per-copy footprint; the
        // whole Fig. 7 plan must still fit the node under VW-SDK.
        let arch = ArchConfig::paper_node();
        for v in VggVariant::ALL {
            let net = vgg::build(v);
            let sel = MappingSelection::uniform(MappingKind::VwSdk, net.len());
            let tiles = validate_plan_with(&net, &arch, &ReplicationPlan::fig7(v), &sel)
                .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
            assert!(tiles <= 320, "{}: {tiles}", v.name());
        }
    }

    #[test]
    fn invalid_plans_rejected() {
        let net = vgg::build(VggVariant::A);
        let arch = ArchConfig::paper_node();
        let bad = ReplicationPlan {
            factors: vec![1; 3],
        };
        assert!(validate_plan(&net, &arch, &bad).is_err());
        let zeros = ReplicationPlan {
            factors: vec![0; net.len()],
        };
        assert!(validate_plan(&net, &arch, &zeros).is_err());
        let huge = ReplicationPlan {
            factors: vec![64; net.len()],
        };
        assert!(validate_plan(&net, &arch, &huge).is_err());
    }
}
