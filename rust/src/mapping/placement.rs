//! Tile placement: map the layout's linear tile ids onto mesh coordinates.
//!
//! Layers are allocated contiguous id runs; a boustrophedon (snake) walk of
//! the mesh keeps consecutive ids — and therefore producer/consumer layer
//! pairs — physically adjacent, which is what a sane mapper does and what
//! keeps the baseline NoC comparison fair (the paper's gains must come from
//! flow control, not from a strawman placement).
//!
//! Placement is topology-aware ([`Placement::for_topology`]): the snake
//! walk is right for the mesh and torus (grid-adjacent ⇒ link-adjacent),
//! but Parallel-Prism's dedicated forward links follow *linear chain
//! order*, so there a row-major walk puts pipeline-adjacent layers on the
//! one-hop chain links.

use crate::config::{ArchConfig, TopologyKind};

/// (x, y) mesh coordinate of a tile/router.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    /// Mesh column.
    pub x: usize,
    /// Mesh row.
    pub y: usize,
}

impl Coord {
    /// Manhattan distance == minimal XY-route hop count.
    pub fn hops(&self, other: &Coord) -> usize {
        self.x.abs_diff(other.x) + self.y.abs_diff(other.y)
    }
}

/// Placement of linear tile ids onto the mesh.
#[derive(Debug, Clone)]
pub struct Placement {
    coords: Vec<Coord>,
    /// Mesh width in tiles.
    pub width: usize,
    /// Mesh height in tiles.
    pub height: usize,
}

impl Placement {
    /// Snake order: row 0 left→right, row 1 right→left, ...
    pub fn snake(arch: &ArchConfig) -> Self {
        let (w, h) = (arch.tiles_x, arch.tiles_y);
        let mut coords = Vec::with_capacity(w * h);
        for y in 0..h {
            if y % 2 == 0 {
                for x in 0..w {
                    coords.push(Coord { x, y });
                }
            } else {
                for x in (0..w).rev() {
                    coords.push(Coord { x, y });
                }
            }
        }
        Self {
            coords,
            width: w,
            height: h,
        }
    }

    /// Placement matched to `arch.topology`: snake for the mesh and torus
    /// (consecutive ids stay one grid link apart), row-major for
    /// Parallel-Prism (node id == chain position, so consecutive ids sit
    /// on the dedicated one-hop forward chain links — including across row
    /// ends, where the mesh would pay a full row of hops).
    pub fn for_topology(arch: &ArchConfig) -> Self {
        match arch.topology {
            TopologyKind::Mesh | TopologyKind::Torus => Self::snake(arch),
            TopologyKind::Prism => Self::row_major(arch),
        }
    }

    /// Row-major order (for comparison/ablation).
    pub fn row_major(arch: &ArchConfig) -> Self {
        let (w, h) = (arch.tiles_x, arch.tiles_y);
        let coords = (0..w * h)
            .map(|i| Coord { x: i % w, y: i / w })
            .collect();
        Self {
            coords,
            width: w,
            height: h,
        }
    }

    /// Mesh coordinate of a linear tile id.
    pub fn coord(&self, tile_id: usize) -> Coord {
        self.coords[tile_id]
    }

    /// Number of placed tiles.
    pub fn len(&self) -> usize {
        self.coords.len()
    }

    /// True when the placement covers no tiles.
    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    /// Router index (y * width + x) for the NoC simulator.
    pub fn node_of(&self, tile_id: usize) -> usize {
        let c = self.coord(tile_id);
        c.y * self.width + c.x
    }

    /// Mean Manhattan distance between two id sets (layer i tiles → layer
    /// i+1 tiles), the hop-count input of Eq. (3).
    pub fn mean_hops(&self, from: &[usize], to: &[usize]) -> f64 {
        if from.is_empty() || to.is_empty() {
            return 0.0;
        }
        let mut sum = 0usize;
        for &a in from {
            for &b in to {
                sum += self.coord(a).hops(&self.coord(b));
            }
        }
        sum as f64 / (from.len() * to.len()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snake_covers_mesh_once() {
        let arch = ArchConfig::paper_node();
        let p = Placement::snake(&arch);
        assert_eq!(p.len(), 320);
        let mut seen = std::collections::HashSet::new();
        for i in 0..p.len() {
            assert!(seen.insert(p.coord(i)), "duplicate coord at id {i}");
        }
    }

    #[test]
    fn snake_adjacent_ids_are_adjacent_tiles() {
        let arch = ArchConfig::paper_node();
        let p = Placement::snake(&arch);
        for i in 1..p.len() {
            assert_eq!(
                p.coord(i - 1).hops(&p.coord(i)),
                1,
                "ids {} and {} not mesh-adjacent",
                i - 1,
                i
            );
        }
    }

    #[test]
    fn row_major_wraps_with_long_hop() {
        let arch = ArchConfig::paper_node();
        let p = Placement::row_major(&arch);
        // End of row 0 to start of row 1 is 15+1 hops: snake beats row-major.
        assert_eq!(p.coord(15).hops(&p.coord(16)), 16);
    }

    #[test]
    fn for_topology_matches_fabric() {
        use crate::noc::{AnyTopology, Mesh};
        let mut arch = ArchConfig::test_node(); // 4x4
        arch.topology = TopologyKind::Mesh;
        let pm = Placement::for_topology(&arch);
        assert_eq!(pm.coord(5), Placement::snake(&arch).coord(5));
        arch.topology = TopologyKind::Torus;
        let pt = Placement::for_topology(&arch);
        assert_eq!(pt.coord(7), Placement::snake(&arch).coord(7));
        arch.topology = TopologyKind::Prism;
        let pp = Placement::for_topology(&arch);
        // Row-major: consecutive ids are consecutive chain positions, so
        // every producer/consumer pair is one prism hop — even across row
        // ends where a mesh placement would pay a full row of hops.
        let prism = AnyTopology::new(TopologyKind::Prism, arch.tiles_x, arch.tiles_y);
        for i in 1..pp.len() {
            assert_eq!(pp.node_of(i), pp.node_of(i - 1) + 1);
            assert_eq!(prism.hops(pp.node_of(i - 1), pp.node_of(i)), 1);
        }
        let mesh = Mesh::new(arch.tiles_x, arch.tiles_y);
        assert_eq!(mesh.hops(pp.node_of(3), pp.node_of(4)), 4);
    }

    #[test]
    fn hops_is_manhattan() {
        let a = Coord { x: 0, y: 0 };
        let b = Coord { x: 3, y: 4 };
        assert_eq!(a.hops(&b), 7);
        assert_eq!(b.hops(&a), 7);
        assert_eq!(a.hops(&a), 0);
    }

    #[test]
    fn mean_hops_between_runs() {
        let arch = ArchConfig::test_node(); // 4x4
        let p = Placement::snake(&arch);
        let h = p.mean_hops(&[0], &[1]);
        assert_eq!(h, 1.0);
        assert_eq!(p.mean_hops(&[], &[1]), 0.0);
    }

    #[test]
    fn node_of_is_consistent() {
        let arch = ArchConfig::test_node();
        let p = Placement::snake(&arch);
        for id in 0..p.len() {
            let c = p.coord(id);
            assert_eq!(p.node_of(id), c.y * p.width + c.x);
        }
    }
}
