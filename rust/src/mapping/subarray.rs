//! Weight-matrix → subarray packing arithmetic (Sec. III).
//!
//! A layer's kernel matrix has `K = c*l*l` rows and `N` output channels;
//! each 16-bit weight occupies 8 x 2-bit cells across 8 adjacent bit lines,
//! so the physical column demand is `N * 8`. The matrix tiles over 128x128
//! subarrays: `ceil(K/128)` row blocks x `ceil(N*8/128)` column blocks.
//!
//! [`SubarrayDemand::of`] is the *seed* (one-window im2col) packing rule;
//! it is also exposed behind the mapping-backend trait as
//! [`super::backend::Im2col`], the golden-pinned reference that alternative
//! packings ([`super::backend::VwSdk`]) are measured against.

use crate::cnn::Layer;
use crate::config::ArchConfig;

/// Resource demand of one replica of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubarrayDemand {
    /// Row blocks (subarrays stacked over the GEMM reduction dim).
    pub row_blocks: usize,
    /// Column blocks (subarrays side by side over output channels).
    pub col_blocks: usize,
}

impl SubarrayDemand {
    /// Demand for one copy of `layer` under `arch`.
    pub fn of(layer: &Layer, arch: &ArchConfig) -> Self {
        let k = layer.gemm_k();
        let phys_cols = layer.gemm_n() * arch.slices_per_weight();
        Self {
            row_blocks: k.div_ceil(arch.subarray_rows),
            col_blocks: phys_cols.div_ceil(arch.subarray_cols),
        }
    }

    /// Total subarrays for one copy.
    pub fn subarrays(&self) -> usize {
        self.row_blocks * self.col_blocks
    }

    /// Subarrays for `r` replicas.
    pub fn subarrays_replicated(&self, r: usize) -> usize {
        self.subarrays() * r
    }

    /// Whole tiles needed for `r` replicas (layers do not share tiles: each
    /// pipeline stage owns its tiles so stages never contend for a bus).
    pub fn tiles(&self, r: usize, arch: &ArchConfig) -> usize {
        self.subarrays_replicated(r).div_ceil(arch.subarrays_per_tile()).max(1)
    }

    /// Does one replica fit in a single tile? Picks the 24/29 vs 26/31-cycle
    /// intra-layer pipeline variant (Sec. IV-A).
    pub fn single_tile(&self, r: usize, arch: &ArchConfig) -> bool {
        self.subarrays_replicated(r) <= arch.subarrays_per_tile()
    }
}

/// Cell utilization of a packing: useful cells / allocated cells.
pub fn utilization(layer: &Layer, arch: &ArchConfig) -> f64 {
    let d = SubarrayDemand::of(layer, arch);
    let useful = (layer.gemm_k() * layer.gemm_n() * arch.slices_per_weight()) as f64;
    let allocated = (d.subarrays() * arch.subarray_rows * arch.subarray_cols) as f64;
    useful / allocated
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::Layer;

    fn arch() -> ArchConfig {
        ArchConfig::paper_node()
    }

    #[test]
    fn vgg_conv1_demand() {
        // conv1: K = 27, N = 64 -> phys cols 512 -> 1 x 4 subarrays.
        let l = Layer::conv("c1", (224, 224), 3, 64, 3, true);
        let d = SubarrayDemand::of(&l, &arch());
        assert_eq!(d.row_blocks, 1);
        assert_eq!(d.col_blocks, 4);
        assert_eq!(d.subarrays(), 4);
        assert!(d.single_tile(16, &arch())); // 64 <= 96
        assert_eq!(d.tiles(16, &arch()), 1);
    }

    #[test]
    fn vgg_deep_conv_demand() {
        // conv on 512 channels: K = 4608 -> 36 row blocks; N*8 = 4096 -> 32.
        let l = Layer::conv("c", (14, 14), 512, 512, 3, false);
        let d = SubarrayDemand::of(&l, &arch());
        assert_eq!(d.row_blocks, 36);
        assert_eq!(d.col_blocks, 32);
        assert_eq!(d.subarrays(), 1152);
        assert_eq!(d.tiles(1, &arch()), 12);
        assert!(!d.single_tile(1, &arch()));
    }

    #[test]
    fn fc1_demand_exceeds_node() {
        // fc1 is the paper's capacity hole (DESIGN.md §1): 196 x 256 blocks.
        let l = Layer::fc("fc1", 25088, 4096);
        let d = SubarrayDemand::of(&l, &arch());
        assert_eq!(d.row_blocks, 196);
        assert_eq!(d.col_blocks, 256);
        assert!(d.subarrays() > arch().total_subarrays());
    }

    #[test]
    fn tiles_at_least_one() {
        let l = Layer::conv("t", (8, 8), 1, 1, 3, false);
        let d = SubarrayDemand::of(&l, &arch());
        assert_eq!(d.tiles(1, &arch()), 1);
    }

    #[test]
    fn utilization_in_unit_interval() {
        for (k, n) in [(27, 64), (4608, 512), (100, 7)] {
            let l = Layer::fc("x", k, n);
            let u = utilization(&l, &arch());
            assert!(u > 0.0 && u <= 1.0, "utilization {u} for {k}x{n}");
        }
    }
}
