//! Mapping backends: how a conv layer's weights become subarrays.
//!
//! The seed mapping (Sec. III) unrolls one im2col window: `K = c*l*l` rows
//! by `N*8` physical columns, one OFM pixel position per logical cycle.
//! VW-SDK (arxiv 2112.11282) generalizes the window: a *parallel window*
//! covering `p x q` output positions maps `c*wh*ww` input rows
//! (`wh = l + (p-1)s`, `ww = l + (q-1)s`) against `p*q*N` shifted duplicate
//! kernels, emitting `p*q` OFM pixel positions per cycle from one copy.
//!
//! Both live behind the object-safe [`MappingBackend`] trait (the PR-1
//! `NocBackend` pattern): [`Im2col`] is the golden-pinned seed rule,
//! [`VwSdk`] picks the best parallel window per layer.
//!
//! # The column-conservation law (why VW-SDK ties on the paper node)
//!
//! Every OFM value emitted per cycle needs its own group of
//! `slices_per_weight` physical columns — columns are the MAC lanes, and
//! no mapping can share them. Per unit emission rate a packing therefore
//! costs `ceil(c*wh*ww/rows) * ceil(8N/cols) / 1` subarrays with the window
//! rows shared across all `p*q` duplicates, versus im2col's
//! `ceil(c*l*l/rows) * ceil(8N/cols)`. On the paper node (128 columns,
//! 8 slices) every VGG/ResNet channel count is a multiple of 16, so the
//! column term is *exact* and the comparison reduces to row blocks alone —
//! which only grow with the window. Hence VW-SDK can **tie** im2col's
//! per-rate subarray cost (it does, on the stem convs, where the enlarged
//! window still fits one row block) but never strictly beat it; the strict
//! wins reported by the VW-SDK paper come entirely from column slack
//! (`8N % cols != 0`), which this geometry does not have. The golden tests
//! pin both facts: equality on the paper node, strict savings on a
//! column-slack node (`rust/tests/golden_mapping.rs`).
//!
//! The tie is still worth taking: a tied `p x q` packing emits `p*q`
//! pixels per cycle from *one* copy, so at low replication the mapping
//! itself buys interval (VGG-A unreplicated: 50176 -> 12544 cycles;
//! ResNet: 12544 -> 3136) at identical subarrays-per-rate.

use crate::cnn::Layer;
use crate::config::ArchConfig;

use super::subarray::SubarrayDemand;

/// Hard cap on parallel windows per copy: the OR/IR datapath moves at most
/// this many OFM pixel positions per logical cycle out of one copy —
/// matching the paper's maximum replication granularity (16x, Fig. 7).
pub const MAX_PARALLEL_WINDOWS: usize = 16;

/// Which packing rule maps a layer onto subarrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MappingKind {
    /// The seed rule: one im2col window per cycle (golden-pinned).
    Im2col,
    /// Variable-window + shifted-duplicate-kernel packing.
    VwSdk,
}

impl std::fmt::Display for MappingKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MappingKind::Im2col => "im2col",
            MappingKind::VwSdk => "vwsdk",
        })
    }
}

/// How the planner treats the mapping axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingMode {
    /// Every layer uses the seed im2col rule (the default everywhere).
    Im2col,
    /// Every conv layer uses the VW-SDK backend.
    VwSdk,
    /// The planner searches per-layer backend choice jointly with
    /// replication.
    Auto,
}

impl std::fmt::Display for MappingMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MappingMode::Im2col => "im2col",
            MappingMode::VwSdk => "vwsdk",
            MappingMode::Auto => "auto",
        })
    }
}

impl std::str::FromStr for MappingMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "im2col" | "seed" => Ok(MappingMode::Im2col),
            "vwsdk" | "vw-sdk" | "vw_sdk" => Ok(MappingMode::VwSdk),
            "auto" | "joint" => Ok(MappingMode::Auto),
            other => Err(format!(
                "unknown mapping {other:?} (im2col | vwsdk | auto)"
            )),
        }
    }
}

/// Per-layer backend choice, aligned with `Network::layers()`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MappingSelection {
    /// Backend per layer (non-crossbar layers ignore their entry).
    pub kinds: Vec<MappingKind>,
}

impl MappingSelection {
    /// The all-im2col selection (seed behavior).
    pub fn im2col(n_layers: usize) -> Self {
        Self {
            kinds: vec![MappingKind::Im2col; n_layers],
        }
    }

    /// One backend for every layer.
    pub fn uniform(kind: MappingKind, n_layers: usize) -> Self {
        Self {
            kinds: vec![kind; n_layers],
        }
    }

    /// Backend for layer `i`.
    pub fn kind(&self, i: usize) -> MappingKind {
        self.kinds[i]
    }

    /// Number of per-layer entries.
    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    /// True when the selection covers no layers.
    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Short human-readable form: `im2col`, `vwsdk`, or `mixed(k/n vwsdk)`.
    pub fn summary(&self) -> String {
        let vw = self
            .kinds
            .iter()
            .filter(|&&k| k == MappingKind::VwSdk)
            .count();
        if vw == 0 {
            "im2col".into()
        } else if vw == self.kinds.len() {
            "vwsdk".into()
        } else {
            format!("mixed({vw}/{} vwsdk)", self.kinds.len())
        }
    }
}

/// Resolved packing of one copy of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerPacking {
    /// Subarray blocks of one copy (window rows x duplicated-kernel cols).
    pub demand: SubarrayDemand,
    /// OFM pixel positions one copy emits per logical cycle (`p*q`; 1 for
    /// im2col and every non-conv layer).
    pub parallel_windows: u64,
    /// IFM window spatial dims `(wh, ww)` feeding one copy per cycle —
    /// `(l, l)` for im2col; drives the inter-layer input-demand head.
    pub window: (usize, usize),
}

/// An object-safe layer -> subarray packing rule.
pub trait MappingBackend {
    /// Which rule this is.
    fn kind(&self) -> MappingKind;
    /// Pack one copy of `layer` under `arch`.
    fn pack(&self, layer: &Layer, arch: &ArchConfig) -> LayerPacking;
}

/// Packing for every non-conv (and every im2col) layer: the seed rule,
/// one window per cycle.
fn seed_packing(layer: &Layer, arch: &ArchConfig) -> LayerPacking {
    let k = layer.ksize();
    LayerPacking {
        demand: SubarrayDemand::of(layer, arch),
        parallel_windows: 1,
        window: (k, k),
    }
}

/// The seed im2col rule behind the trait — bit-identical to
/// [`SubarrayDemand::of`] (golden-pinned in `rust/tests/golden_mapping.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Im2col;

impl MappingBackend for Im2col {
    fn kind(&self) -> MappingKind {
        MappingKind::Im2col
    }

    fn pack(&self, layer: &Layer, arch: &ArchConfig) -> LayerPacking {
        seed_packing(layer, arch)
    }
}

/// Variable-window + shifted-duplicate-kernel packing.
///
/// Candidate windows cover `p x q` output positions with `p` dividing the
/// conv's output height and `q` its width (so every cycle's emission block
/// tiles the OFM exactly and the steady-state occupancy stays integral),
/// `p*q <= MAX_PARALLEL_WINDOWS`. Among candidates the backend minimizes
/// subarrays per unit emission rate, breaking ties toward the *largest*
/// window (free intra-copy parallelism) and then the smallest `p`. `(1,1)`
/// is always a candidate, so VW-SDK never costs more per rate than im2col.
#[derive(Debug, Clone, Copy, Default)]
pub struct VwSdk;

impl MappingBackend for VwSdk {
    fn kind(&self) -> MappingKind {
        MappingKind::VwSdk
    }

    fn pack(&self, layer: &Layer, arch: &ArchConfig) -> LayerPacking {
        let crate::cnn::LayerKind::Conv { ksize, stride, .. } = layer.kind else {
            return seed_packing(layer, arch);
        };
        let (oh, ow) = layer.conv_out_hw();
        let c = layer.in_ch;
        let phys_cols_per_window = layer.gemm_n() * arch.slices_per_weight();
        let mut best: Option<(LayerPacking, usize, usize)> = None;
        for p in 1..=oh {
            if oh % p != 0 || p > MAX_PARALLEL_WINDOWS {
                continue;
            }
            for q in 1..=ow {
                let pq = p * q;
                if ow % q != 0 || pq > MAX_PARALLEL_WINDOWS {
                    continue;
                }
                let wh = ksize + (p - 1) * stride;
                let ww = ksize + (q - 1) * stride;
                let demand = SubarrayDemand {
                    row_blocks: (c * wh * ww).div_ceil(arch.subarray_rows),
                    col_blocks: (pq * phys_cols_per_window)
                        .div_ceil(arch.subarray_cols),
                };
                let cand = LayerPacking {
                    demand,
                    parallel_windows: pq as u64,
                    window: (wh, ww),
                };
                // Minimize subarrays per unit rate (cross-multiplied to stay
                // in integers); ties -> larger window, then smaller p.
                let better = match &best {
                    None => true,
                    Some((b, b_pq, b_p)) => {
                        let lhs = cand.demand.subarrays() * b_pq;
                        let rhs = b.demand.subarrays() * pq;
                        lhs < rhs || (lhs == rhs && (pq > *b_pq || (pq == *b_pq && p < *b_p)))
                    }
                };
                if better {
                    best = Some((cand, pq, p));
                }
            }
        }
        best.expect("(1,1) always qualifies").0
    }
}

/// The backend implementing `kind` (both are stateless).
pub fn backend_for(kind: MappingKind) -> &'static dyn MappingBackend {
    match kind {
        MappingKind::Im2col => &Im2col,
        MappingKind::VwSdk => &VwSdk,
    }
}

/// Convenience: pack `layer` with the backend for `kind`.
pub fn pack_layer(kind: MappingKind, layer: &Layer, arch: &ArchConfig) -> LayerPacking {
    backend_for(kind).pack(layer, arch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{vgg, Layer, VggVariant};

    fn arch() -> ArchConfig {
        ArchConfig::paper_node()
    }

    #[test]
    fn im2col_backend_is_the_seed_rule() {
        let net = vgg::build(VggVariant::E);
        for l in net.layers() {
            let p = pack_layer(MappingKind::Im2col, l, &arch());
            assert_eq!(p.demand, SubarrayDemand::of(l, &arch()), "{}", l.name);
            assert_eq!(p.parallel_windows, 1);
            assert_eq!(p.window, (l.ksize(), l.ksize()));
        }
    }

    #[test]
    fn vwsdk_never_worse_per_rate_and_ties_on_stem() {
        // Column conservation: per unit rate vwsdk <= im2col on every conv
        // layer (the module doc's law), with the enlarged window chosen on
        // the stem conv where the row block ties.
        let net = vgg::build(VggVariant::E);
        for l in net.layers() {
            if !l.is_conv() {
                continue;
            }
            let i = pack_layer(MappingKind::Im2col, l, &arch());
            let v = pack_layer(MappingKind::VwSdk, l, &arch());
            assert!(
                v.demand.subarrays() as u64
                    <= i.demand.subarrays() as u64 * v.parallel_windows,
                "{}: vwsdk {} subs @ pw {} vs im2col {}",
                l.name,
                v.demand.subarrays(),
                v.parallel_windows,
                i.demand.subarrays()
            );
        }
        // VGG stem: c=3, l=3 -> (2,8) window, 120 rows in one block, 16
        // pixel positions per cycle at im2col's exact per-rate cost.
        let stem = &net.layers()[0];
        let v = pack_layer(MappingKind::VwSdk, stem, &arch());
        assert_eq!(v.parallel_windows, 16);
        assert_eq!(v.window, (4, 10));
        assert_eq!(v.demand.row_blocks, 1);
        assert_eq!(v.demand.subarrays(), 64); // == 4 * 16
    }

    #[test]
    fn vwsdk_falls_back_to_im2col_on_deep_convs() {
        // c=512 3x3: any window growth multiplies row blocks past the
        // duplicate count -> (1,1) is per-rate optimal.
        let l = Layer::conv("c", (14, 14), 512, 512, 3, false);
        let v = pack_layer(MappingKind::VwSdk, &l, &arch());
        assert_eq!(v.parallel_windows, 1);
        assert_eq!(v.demand, SubarrayDemand::of(&l, &arch()));
    }

    #[test]
    fn vwsdk_non_conv_is_seed() {
        let l = Layer::fc("fc", 25088, 4096);
        let v = pack_layer(MappingKind::VwSdk, &l, &arch());
        assert_eq!(v.demand, SubarrayDemand::of(&l, &arch()));
        assert_eq!(v.parallel_windows, 1);
    }

    #[test]
    fn vwsdk_wins_strictly_with_column_slack() {
        // Shrink subarrays to 192 columns: 8N = 512 leaves 64 slack columns
        // per block, and the (4,4) window amortizes the slack across 16
        // duplicates — the geometry class where VW-SDK's strict savings
        // live (the paper's 512-wide arrays with N <= 256).
        let mut a = arch();
        a.subarray_cols = 192;
        a.validate().expect("192-column node validates");
        let stem = Layer::conv("c1", (224, 224), 3, 64, 3, true);
        let i = pack_layer(MappingKind::Im2col, &stem, &a);
        let v = pack_layer(MappingKind::VwSdk, &stem, &a);
        assert!(v.parallel_windows > 1);
        assert!(
            v.demand.subarrays() as u64
                < i.demand.subarrays() as u64 * v.parallel_windows,
            "vwsdk {} subs @ pw {} vs im2col {} per window",
            v.demand.subarrays(),
            v.parallel_windows,
            i.demand.subarrays()
        );
    }

    #[test]
    fn selection_summary_forms() {
        let mut s = MappingSelection::im2col(4);
        assert_eq!(s.summary(), "im2col");
        s.kinds[1] = MappingKind::VwSdk;
        assert_eq!(s.summary(), "mixed(1/4 vwsdk)");
        let u = MappingSelection::uniform(MappingKind::VwSdk, 3);
        assert_eq!(u.summary(), "vwsdk");
        assert_eq!(u.kind(2), MappingKind::VwSdk);
        assert_eq!("auto".parse::<MappingMode>().unwrap(), MappingMode::Auto);
        assert!("bogus".parse::<MappingMode>().is_err());
    }
}
