//! Layer → tile allocation: turns a network + replication plan into the
//! per-layer resource map the pipeline simulator and the NoC traffic
//! extractor consume.

use crate::cnn::Network;
use crate::config::ArchConfig;

use super::backend::{pack_layer, MappingKind, MappingSelection};
use super::replication::{validate_plan_with, ReplicationPlan};
use super::subarray::SubarrayDemand;

/// Resolved mapping of one layer.
#[derive(Debug, Clone)]
pub struct LayerMapping {
    /// Index into `Network::layers()`.
    pub layer_idx: usize,
    /// Layer name (mirrors `Layer::name`).
    pub name: String,
    /// Replication factor `r`.
    pub replication: usize,
    /// Subarray demand of one replica (one packed copy under the layer's
    /// mapping backend; the seed window under im2col).
    pub demand: SubarrayDemand,
    /// Tiles owned by this layer (ids into the placement order).
    pub tile_ids: Vec<usize>,
    /// True if all replicas fit one tile (picks the 24/29-cycle intra-layer
    /// pipeline variants; multi-tile layers use 26/31).
    pub single_tile: bool,
    /// FC layers time-multiplex crossbars over this many reload rounds.
    pub reload_rounds: u64,
    /// Backend that produced this packing.
    pub mapping: MappingKind,
    /// OFM pixel positions one copy emits per logical cycle (`p*q` under
    /// VW-SDK; 1 under im2col and for every non-conv layer). The stage's
    /// emission rate is `replication * parallel_windows`.
    pub parallel_windows: u64,
    /// IFM window spatial dims `(wh, ww)` one copy consumes per cycle
    /// (`(l, l)` under im2col) — drives the inter-layer input-demand head.
    pub window: (usize, usize),
}

/// Whole-network mapping.
#[derive(Debug, Clone)]
pub struct NetworkMapping {
    /// Per-layer mappings, aligned with `Network::layers()`.
    pub layers: Vec<LayerMapping>,
    /// Tiles consumed by the whole network.
    pub total_tiles: usize,
}

impl NetworkMapping {
    /// Allocate tiles to layers in order. Layers own disjoint, contiguous
    /// runs of tile ids; the placement module maps ids to mesh coordinates
    /// so that consecutive layers are physically adjacent.
    pub fn build(
        net: &Network,
        arch: &ArchConfig,
        plan: &ReplicationPlan,
    ) -> Result<Self, String> {
        Self::build_with(net, arch, plan, &MappingSelection::im2col(net.len()))
    }

    /// [`NetworkMapping::build`] under a per-layer mapping selection. The
    /// all-im2col selection is bit-identical to the seed path (golden-pinned
    /// in `rust/tests/golden_mapping.rs`).
    pub fn build_with(
        net: &Network,
        arch: &ArchConfig,
        plan: &ReplicationPlan,
        selection: &MappingSelection,
    ) -> Result<Self, String> {
        validate_plan_with(net, arch, plan, selection)?;
        let mut layers = Vec::with_capacity(net.len());
        let mut next_tile = 0usize;
        for (i, layer) in net.layers().iter().enumerate() {
            let r = plan.factor(i);
            let kind = if layer.is_conv() {
                selection.kind(i)
            } else {
                MappingKind::Im2col // FC/dataflow layers are backend-blind
            };
            let packing = pack_layer(kind, layer, arch);
            // One accounting rule for planner pre-checks and real mapping:
            // see `replication::layer_tiles_with` (conv / FC reload rounds /
            // one-buffer-tile dataflow stages).
            let (tiles, reload_rounds) =
                super::replication::layer_tiles_with(layer, r, arch, kind);
            let tile_ids: Vec<usize> = (next_tile..next_tile + tiles).collect();
            next_tile += tiles;
            layers.push(LayerMapping {
                layer_idx: i,
                name: layer.name.clone(),
                replication: r,
                demand: packing.demand,
                single_tile: tiles == 1,
                tile_ids,
                reload_rounds,
                mapping: kind,
                parallel_windows: packing.parallel_windows,
                window: packing.window,
            });
        }
        if next_tile > arch.total_tiles() {
            return Err(format!(
                "mapping needs {next_tile} tiles > {}",
                arch.total_tiles()
            ));
        }
        Ok(Self {
            layers,
            total_tiles: next_tile,
        })
    }

    /// Convenience accessor.
    pub fn layer(&self, i: usize) -> &LayerMapping {
        &self.layers[i]
    }
}

impl LayerMapping {
    /// Crossbars a node keeps *programmed* for this layer: all
    /// `replication` copies for conv layers, one reload round's share for
    /// FC layers (the rounds time-multiplex the same physical arrays), and
    /// nothing for weightless dataflow stages. This is the footprint the
    /// weight-write cost model ([`crate::power::WriteCost`]) charges when
    /// a multi-tenant node swaps models.
    pub fn resident_subarrays(&self, layer: &crate::cnn::Layer) -> usize {
        if !(layer.is_conv() || layer.is_fc()) {
            return 0;
        }
        self.demand
            .subarrays_replicated(self.replication)
            .div_ceil(self.reload_rounds as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::vgg;
    use crate::cnn::VggVariant;
    use crate::mapping::backend::{MappingKind, MappingSelection};

    #[test]
    fn vgg_e_fig7_mapping_builds() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        assert_eq!(m.layers.len(), net.len());
        assert!(m.total_tiles <= 320, "tiles = {}", m.total_tiles);
        // Tile runs are disjoint and contiguous.
        let mut seen = vec![false; m.total_tiles];
        for lm in &m.layers {
            for &t in &lm.tile_ids {
                assert!(!seen[t], "tile {t} double-assigned");
                seen[t] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn conv1_single_tile_under_fig7() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let plan = ReplicationPlan::fig7(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        // conv1 at r=16 needs 64 subarrays <= 96 -> single tile.
        assert!(m.layer(0).single_tile);
        assert_eq!(m.layer(0).tile_ids.len(), 1);
    }

    #[test]
    fn all_vggs_map_under_budget() {
        let arch = ArchConfig::paper_node();
        for v in VggVariant::ALL {
            let net = vgg::build(v);
            for plan in [ReplicationPlan::none(&net), ReplicationPlan::fig7(v)] {
                let m = NetworkMapping::build(&net, &arch, &plan)
                    .unwrap_or_else(|e| panic!("{}: {e}", v.name()));
                assert!(m.total_tiles <= 320, "{}: {}", v.name(), m.total_tiles);
            }
        }
    }

    #[test]
    fn plan_tiles_agrees_with_built_mapping() {
        // The planner's budget pre-check and the real mapping share one
        // accounting rule (replication::layer_tiles); pin the agreement on
        // a branching workload and a replicated chain.
        use crate::cnn::{resnet, vgg, ResNetVariant, VggVariant};
        use crate::mapping::plan_tiles;
        let arch = ArchConfig::paper_node();
        for (net, plan) in [
            {
                let n = resnet::build(ResNetVariant::R18);
                let p = ReplicationPlan::none(&n);
                (n, p)
            },
            (
                vgg::build(VggVariant::E),
                ReplicationPlan::fig7(VggVariant::E),
            ),
        ] {
            let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
            assert_eq!(
                m.total_tiles,
                plan_tiles(&net, &arch, &plan.factors),
                "{}",
                net.name
            );
        }
    }

    #[test]
    fn resnet18_maps_with_single_tile_dataflow_stages() {
        use crate::cnn::{resnet, ResNetVariant};
        let arch = ArchConfig::paper_node();
        let net = resnet::build(ResNetVariant::R18);
        let plan = ReplicationPlan::none(&net);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        assert!(m.total_tiles <= 320, "tiles = {}", m.total_tiles);
        for lm in &m.layers {
            let l = &net.layers()[lm.layer_idx];
            if !l.is_crossbar() {
                assert_eq!(lm.tile_ids.len(), 1, "{}", lm.name);
                assert_eq!(lm.reload_rounds, 1, "{}", lm.name);
                assert_eq!(lm.demand.subarrays(), 0, "{}", lm.name);
            }
        }
    }

    #[test]
    fn build_default_is_im2col_everywhere() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::E);
        let m = NetworkMapping::build(&net, &arch, &ReplicationPlan::fig7(VggVariant::E)).unwrap();
        for lm in &m.layers {
            assert_eq!(lm.mapping, MappingKind::Im2col, "{}", lm.name);
            assert_eq!(lm.parallel_windows, 1, "{}", lm.name);
            let k = net.layers()[lm.layer_idx].ksize();
            assert_eq!(lm.window, (k, k), "{}", lm.name);
        }
    }

    #[test]
    fn build_with_vwsdk_records_windows() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        let sel = MappingSelection::uniform(MappingKind::VwSdk, net.len());
        let m = NetworkMapping::build_with(&net, &arch, &plan, &sel).unwrap();
        // VGG stem: (2,8) parallel window over a 4x10 IFM patch.
        assert_eq!(m.layer(0).parallel_windows, 16);
        assert_eq!(m.layer(0).window, (4, 10));
        assert_eq!(m.layer(0).mapping, MappingKind::VwSdk);
        // FC layers stay on the seed rule regardless of selection.
        let fc = m
            .layers
            .iter()
            .find(|lm| net.layers()[lm.layer_idx].is_fc())
            .unwrap();
        assert_eq!(fc.mapping, MappingKind::Im2col);
        assert_eq!(fc.parallel_windows, 1);
    }

    #[test]
    fn fc_layers_record_reload_rounds() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        for lm in &m.layers {
            let is_conv = net.layers()[lm.layer_idx].is_conv();
            assert_eq!(lm.reload_rounds, if is_conv { 1 } else { 8 });
        }
    }

    #[test]
    fn resident_subarrays_charge_one_reload_round() {
        let arch = ArchConfig::paper_node();
        let net = vgg::build(VggVariant::A);
        let plan = ReplicationPlan::none(&net);
        let m = NetworkMapping::build(&net, &arch, &plan).unwrap();
        for lm in &m.layers {
            let layer = &net.layers()[lm.layer_idx];
            let full = lm.demand.subarrays_replicated(lm.replication);
            let resident = lm.resident_subarrays(layer);
            if layer.is_conv() {
                assert_eq!(resident, full, "{}", lm.name);
            } else {
                // fc1: 196x256 blocks / 8 rounds = 6272 resident arrays.
                assert_eq!(resident, full.div_ceil(8), "{}", lm.name);
            }
        }
        let fc1 = m
            .layers
            .iter()
            .find(|lm| net.layers()[lm.layer_idx].is_fc())
            .unwrap();
        assert_eq!(fc1.resident_subarrays(&net.layers()[fc1.layer_idx]), 6272);
    }
}
